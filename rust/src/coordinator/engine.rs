//! Inference engine: executes batches against the trained model zoo with
//! the in-tree quantized engines.
//!
//! The engine is the boundary between L3 (request coordination) and the
//! numeric core: it marshals a batch of same-`(model, k, scheme)` requests
//! into one matrix, runs the reduced-precision forward pass under the
//! requested rounding scheme, and reads back logits. Model state ([`Zoo`])
//! is shared across all serving shards behind an `Arc`; each shard owns its
//! own `Engine`, whose per-engine seed counter decorrelates the
//! stochastic/dither rounding streams between shards without any
//! cross-shard synchronization.
//!
//! Each engine additionally owns a **byte-bounded LRU plan cache** of
//! [`PreparedModel`]s keyed by [`PlanKey`] (the
//! [`crate::nn::QuantInferenceConfig`] fingerprint): hot scheme/bit
//! configurations skip all weight-side planning and requantization, paying
//! only for the activation side of each request. The cache is per shard —
//! shards specialize on the configurations their connections actually
//! send, instead of all sharing one view of the zoo — and it is bounded by
//! accumulated [`PreparedModel::memory_bytes`], so a handful of large
//! configurations cannot blow a memory budget that many small ones fit in.
//!
//! The engine is also where **shadow sampling** lives: when configured
//! with a [`ShadowSampler`], a deterministic fraction of request rows is
//! re-run through the exact f64 forward pass next to the quantized one,
//! and every logit's signed error feeds the shard's [`FidelityShard`]
//! estimators — the live bias/MSE measurement behind `stats.fidelity` and
//! the `"scheme":"auto"` controller.

use crate::fidelity::{FidelityShard, ShadowSampler};
use crate::linalg::{Matrix, Variant};
use crate::nn::{quantized_forward, PlanKey, PreparedModel, QuantInferenceConfig};
use crate::rounding::SchemeId;
use crate::trace::BatchStageTimes;
use crate::train::{ModelSpec, Zoo, ZooModel};
use crate::util::error::Result;
use crate::{bail, err};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default per-engine plan-cache byte budget (64 MiB). The full prewarm
/// grid (2 models × 3 schemes × the default bit widths) is well under
/// 10 MiB, leaving headroom for request-driven configurations.
pub const DEFAULT_PLAN_CACHE_BYTES: usize = 64 << 20;

/// Byte-bounded LRU over prepared models: eviction is driven by the
/// accumulated [`PreparedModel::memory_bytes`] of resident entries, not by
/// entry count. Capacity 0 disables retention: every lookup is a miss that
/// builds fresh plans (the cache-miss baseline the `bench_e2e` plan-cache
/// comparison measures). A single plan larger than the whole budget is
/// evicted immediately — the budget is respected strictly rather than
/// letting one oversized configuration pin arbitrary memory.
struct PlanCache {
    capacity_bytes: usize,
    /// Accumulated `memory_bytes` of resident entries.
    bytes: usize,
    /// Front = most recently used; each entry carries its byte size so
    /// eviction accounting never re-walks the plans.
    entries: VecDeque<(PlanKey, Arc<PreparedModel>, usize)>,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl PlanCache {
    fn new(capacity_bytes: usize) -> PlanCache {
        PlanCache {
            capacity_bytes,
            bytes: 0,
            entries: VecDeque::new(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn get(&mut self, key: &PlanKey) -> Option<Arc<PreparedModel>> {
        let idx = self.entries.iter().position(|(k, _, _)| k == key)?;
        let entry = self.entries.remove(idx).expect("index from position");
        let plans = entry.1.clone();
        self.entries.push_front(entry);
        self.hits += 1;
        Some(plans)
    }

    fn insert(&mut self, key: PlanKey, plans: Arc<PreparedModel>) {
        if self.capacity_bytes == 0 {
            return;
        }
        if let Some(idx) = self.entries.iter().position(|(k, _, _)| k == &key) {
            let (_, _, old_bytes) = self.entries.remove(idx).expect("index from position");
            self.bytes -= old_bytes;
        }
        let size = plans.memory_bytes();
        self.entries.push_front((key, plans, size));
        self.bytes += size;
        while self.bytes > self.capacity_bytes {
            let Some((_, _, evicted)) = self.entries.pop_back() else {
                break;
            };
            self.bytes -= evicted;
            self.evictions += 1;
        }
    }
}

/// Observable plan-cache counters (tests, benches, ops logging).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that built fresh plans.
    pub misses: u64,
    /// Entries shed by the byte-budget LRU since startup — the SLO
    /// evaluator's eviction-storm signal differences this.
    pub evictions: u64,
    /// Resident entries.
    pub len: usize,
    /// Accumulated `memory_bytes` of resident entries.
    pub bytes: usize,
    /// Configured byte budget (0 = caching disabled).
    pub capacity_bytes: usize,
}

/// The serving engine: shared model zoo + a private rounding-seed stream +
/// a per-engine prepared-plan cache.
pub struct Engine {
    zoo: Arc<Zoo>,
    seed_counter: AtomicU64,
    /// Seed for freezing dither weight draws in prepared plans (stable per
    /// engine so repeated cache misses rebuild identical plans).
    prep_seed: u64,
    /// Configured plan-cache byte budget, mirrored outside the mutex:
    /// capacity is fixed at construction, so the hot path can route the
    /// capacity-0 baseline without taking the cache lock.
    plan_cache_capacity: usize,
    plans: Mutex<PlanCache>,
    /// Which request rows additionally run the exact shadow forward pass
    /// (rate 0 — the default — short-circuits the whole path).
    shadow: ShadowSampler,
    /// Where shadow-sampled logit errors are recorded. The shard pool
    /// points this at the shard's metrics-owned estimators; standalone
    /// engines get a private table.
    fidelity: Arc<FidelityShard>,
}

/// Result of one request within a batch.
#[derive(Clone, Debug)]
pub struct InferenceOutput {
    /// Predicted class.
    pub pred: u8,
    /// Raw logits.
    pub logits: Vec<f64>,
}

impl Engine {
    /// Engine over an already-loaded zoo (the serving path: one zoo, one
    /// engine per shard). `seed` seeds this engine's rounding stream; give
    /// each shard a distinct value.
    pub fn from_zoo(zoo: Arc<Zoo>, seed: u64) -> Engine {
        Engine::with_plan_cache(zoo, seed, DEFAULT_PLAN_CACHE_BYTES)
    }

    /// Engine with an explicit plan-cache byte budget (0 disables caching
    /// so every request replans the weight side — the cache-miss
    /// baseline).
    pub fn with_plan_cache(zoo: Arc<Zoo>, seed: u64, plan_cache_bytes: usize) -> Engine {
        Engine {
            zoo,
            seed_counter: AtomicU64::new(seed),
            prep_seed: seed,
            plan_cache_capacity: plan_cache_bytes,
            plans: Mutex::new(PlanCache::new(plan_cache_bytes)),
            shadow: ShadowSampler::new(0.0),
            fidelity: Arc::new(FidelityShard::new()),
        }
    }

    /// Enable shadow sampling: `rate` of request rows (deterministic
    /// stride) re-run the exact f64 forward pass, and each logit's error
    /// is recorded into `sink`. The shard pool hands every engine its
    /// shard's metrics-owned [`FidelityShard`] so the estimates surface in
    /// `stats` and drive the per-shard auto-precision controller.
    pub fn with_shadow(mut self, rate: f64, sink: Arc<FidelityShard>) -> Engine {
        self.shadow = ShadowSampler::new(rate);
        self.fidelity = sink;
        self
    }

    /// Override the plan-preparation seed (the frozen dither weight draw).
    /// The shard pool points every engine at the seed the zoo prewarmed
    /// with, so a plan rebuilt after eviction is bit-identical to the
    /// prewarmed one it replaces.
    pub fn with_prep_seed(mut self, prep_seed: u64) -> Engine {
        self.prep_seed = prep_seed;
        self
    }

    /// Standalone engine that loads (or trains + caches) its own zoo.
    /// `train_n` is the training-set size used on cache miss.
    pub fn new(train_n: usize, seed: u64) -> Engine {
        Engine::from_zoo(Arc::new(Zoo::load(train_n, seed)), seed)
    }

    /// The shared model zoo.
    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }

    /// Float (unquantized) test accuracy of a model family.
    pub fn float_accuracy(&self, model: &str) -> Option<f64> {
        self.zoo.get(model).map(|m| m.float_accuracy)
    }

    /// Plan-cache counters.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        let cache = self.plans.lock().unwrap();
        PlanCacheStats {
            hits: cache.hits,
            misses: cache.misses,
            evictions: cache.evictions,
            len: cache.entries.len(),
            bytes: cache.bytes,
            capacity_bytes: cache.capacity_bytes,
        }
    }

    /// True when the configuration's plans are cache-resident right now.
    /// A pure peek: LRU order and hit/miss counters are untouched, so the
    /// batcher can poll residency without distorting cache behaviour.
    pub fn plan_resident(&self, key: &PlanKey) -> bool {
        self.plans.lock().unwrap().entries.iter().any(|(k, _, _)| k == key)
    }

    /// The fidelity estimators this engine's shadow path records into.
    pub fn fidelity(&self) -> &Arc<FidelityShard> {
        &self.fidelity
    }

    /// A merged, point-in-time copy of this engine's fidelity estimators,
    /// in the table form the SLO controller resolves against. Offline
    /// consumers (benches, replay tooling) snapshot once and price many
    /// budgets deterministically against it.
    pub fn fidelity_table(&self) -> crate::fidelity::EstimateTable {
        crate::fidelity::EstimateTable::from_shard(&self.fidelity)
    }

    /// Configured shadow-sampling fraction.
    pub fn shadow_rate(&self) -> f64 {
        self.shadow.rate()
    }

    /// Install an externally prepared model (zoo-level prewarming: build
    /// the plans once at startup, share them across every shard's cache).
    pub fn install_prepared(&self, key: PlanKey, plans: Arc<PreparedModel>) {
        self.plans.lock().unwrap().insert(key, plans);
    }

    /// Prewarm this engine's cache for the given bit widths and schemes
    /// across every zoo model (startup path for standalone engines).
    pub fn prewarm(&self, bits: &[u32], modes: &[SchemeId]) {
        let prepared = self
            .zoo
            .prewarm_plans(bits, modes, Variant::Separate, self.prep_seed);
        for (key, plans) in prepared {
            self.install_prepared(key, plans);
        }
    }

    /// Fetch the prepared model for a configuration, building (and caching,
    /// capacity permitting) on miss.
    fn prepared_for(&self, key: &PlanKey, mlp: &crate::nn::Mlp) -> Arc<PreparedModel> {
        let mut cache = self.plans.lock().unwrap();
        if let Some(plans) = cache.get(key) {
            return plans;
        }
        cache.misses += 1;
        let plans = Arc::new(PreparedModel::prepare(
            mlp,
            key.bits,
            key.scheme,
            key.variant,
            self.prep_seed,
        ));
        cache.insert(key.clone(), plans.clone());
        plans
    }

    /// Validate a batch and marshal it into one input matrix.
    fn marshal<'z>(
        &'z self,
        model: &str,
        k: u32,
        pixels: &[&[f64]],
    ) -> Result<(&'z crate::train::ZooModel, Matrix)> {
        if !(1..=16).contains(&k) {
            bail!("k={k} out of range 1..=16");
        }
        let state = self
            .zoo
            .get(model)
            .ok_or_else(|| err!("unknown model family {model:?}"))?;
        let dim = state.mlp.layers[0].in_dim();
        let mut x = Matrix::zeros(pixels.len(), dim);
        for (i, row) in pixels.iter().enumerate() {
            if row.len() != dim {
                bail!(
                    "request {i}: expected {dim} pixels for {model}, got {}",
                    row.len()
                );
            }
            x.row_mut(i).copy_from_slice(row);
        }
        Ok((state, x))
    }

    /// Draw one batch seed and assemble the serving inference config (the
    /// single derivation both the planned and unplanned paths share).
    fn batch_config(&self, k: u32, mode: SchemeId) -> QuantInferenceConfig {
        // One seed per batch: deterministic mode never reads it, the
        // unbiased modes get a fresh rounding stream each call.
        let seed = self.seed_counter.fetch_add(1, Ordering::Relaxed);
        QuantInferenceConfig {
            bits: k,
            mode,
            variant: Variant::Separate,
            seed,
        }
    }

    /// Shadow path: re-run the exact f64 forward pass for the sampled
    /// rows of this batch and record every logit's signed error
    /// (quantized − exact) into the fidelity estimators.
    ///
    /// The sampler strides over *rows* (each row is one client request),
    /// so a `--shadow-rate` of 0.1 shadows 10% of requests regardless of
    /// how they were batched. Runs on the shard worker thread after the
    /// quantized forward — the estimators' single-writer contract.
    fn shadow_observe(
        &self,
        model: &str,
        k: u32,
        mode: SchemeId,
        state: &ZooModel,
        x: &Matrix,
        quantized: &Matrix,
    ) {
        if !self.shadow.enabled() {
            return;
        }
        let sampled: Vec<usize> = (0..x.rows).filter(|_| self.shadow.take()).collect();
        if sampled.is_empty() {
            return;
        }
        let Some(spec) = ModelSpec::from_name(model) else {
            return;
        };
        let slot = spec.index();
        let mut sub = Matrix::zeros(sampled.len(), x.cols);
        for (si, &r) in sampled.iter().enumerate() {
            sub.row_mut(si).copy_from_slice(x.row(r));
        }
        let exact = state.exact_logits(&sub);
        for (si, &r) in sampled.iter().enumerate() {
            for j in 0..exact.cols {
                self.fidelity
                    .record(slot, mode, k, quantized.get(r, j) - exact.get(si, j));
            }
        }
    }

    /// Read logits back into per-request outputs.
    fn read_back(logits_matrix: &Matrix) -> Vec<InferenceOutput> {
        let mut out = Vec::with_capacity(logits_matrix.rows);
        for i in 0..logits_matrix.rows {
            let logits = logits_matrix.row(i).to_vec();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j as u8)
                .unwrap_or(0);
            out.push(InferenceOutput { pred, logits });
        }
        out
    }

    /// Execute a batch of same-(model, k, scheme) requests.
    ///
    /// Deterministic rounding ignores the seed stream, so its outputs are
    /// bit-reproducible across engines and calls; stochastic and dither
    /// rounding consume one seed per batch, so repeated calls sample fresh
    /// rounding noise (the unbiased-in-expectation serving behaviour the
    /// paper's §VII comparison needs). The weight side of every layer comes
    /// from the plan cache; only the activation side is planned per call.
    pub fn infer_batch(
        &self,
        model: &str,
        k: u32,
        mode: SchemeId,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        self.infer_batch_timed(model, k, mode, pixels, None)
    }

    /// [`Engine::infer_batch`] with optional stage timing: when the shard
    /// worker is carrying at least one traced request, it passes a
    /// [`BatchStageTimes`] here and the engine stamps the plan / kernel /
    /// shadow intervals it spent on this batch. With `None` (the
    /// trace-rate-0 path) no clock is read beyond the untimed baseline.
    pub fn infer_batch_timed(
        &self,
        model: &str,
        k: u32,
        mode: SchemeId,
        pixels: &[&[f64]],
        timings: Option<&mut BatchStageTimes>,
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        // Capacity 0 disables plan caching entirely: serve through the
        // plan-per-call baseline (the A/B path) instead of building
        // throwaway plans, counting each call as a miss. The capacity
        // mirror keeps the planned hot path off the cache lock here.
        if self.plan_cache_capacity == 0 {
            self.plans.lock().unwrap().misses += 1;
            return self.infer_unplanned_inner(model, k, mode, pixels, timings);
        }
        let (state, x) = self.marshal(model, k, pixels)?;
        let cfg = self.batch_config(k, mode);
        let timing = timings.is_some();
        let t_plan = timing.then(Instant::now);
        let prepared = self.prepared_for(&cfg.plan_key(model), &state.mlp);
        let t_kernel = timing.then(Instant::now);
        let logits_matrix = prepared.forward(&state.mlp, &x, &state.ranges, cfg.seed);
        let t_shadow = timing.then(Instant::now);
        self.shadow_observe(model, k, mode, state, &x, &logits_matrix);
        if let Some(t) = timings {
            let end = Instant::now();
            t.plan = Some((t_plan.unwrap(), t_kernel.unwrap()));
            t.kernel = Some((t_kernel.unwrap(), t_shadow.unwrap()));
            t.shadow = self.shadow.enabled().then_some((t_shadow.unwrap(), end));
        }
        Ok(Engine::read_back(&logits_matrix))
    }

    /// The direct (plan-both-sides-per-call) forward pass for one batch —
    /// the pre-plan-cache baseline. [`Engine::infer_batch`] routes here
    /// when the plan cache is disabled (capacity 0), and benches/tests
    /// call it directly for A/B checks; either way it shadow-samples like
    /// the planned path.
    pub fn infer_batch_unplanned(
        &self,
        model: &str,
        k: u32,
        mode: SchemeId,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        self.infer_unplanned_inner(model, k, mode, pixels, None)
    }

    /// The unplanned forward with optional stage timing. Plan and kernel
    /// work are fused inside [`quantized_forward`], so the whole call is
    /// stamped as the kernel interval and no plan span is reported.
    fn infer_unplanned_inner(
        &self,
        model: &str,
        k: u32,
        mode: SchemeId,
        pixels: &[&[f64]],
        timings: Option<&mut BatchStageTimes>,
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        let (state, x) = self.marshal(model, k, pixels)?;
        let cfg = self.batch_config(k, mode);
        let timing = timings.is_some();
        let t_kernel = timing.then(Instant::now);
        let logits_matrix = quantized_forward(&state.mlp, &x, &state.ranges, &cfg);
        let t_shadow = timing.then(Instant::now);
        // The baseline path feeds the fidelity estimators exactly like
        // the planned path, so A/B serving (plan cache capped at 0) keeps
        // `stats.fidelity` and the auto controller alive.
        self.shadow_observe(model, k, mode, state, &x, &logits_matrix);
        if let Some(t) = timings {
            let end = Instant::now();
            t.kernel = Some((t_kernel.unwrap(), t_shadow.unwrap()));
            t.shadow = self.shadow.enabled().then_some((t_shadow.unwrap(), end));
        }
        Ok(Engine::read_back(&logits_matrix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine() -> Engine {
        Engine::new(200, 7)
    }

    #[test]
    fn deterministic_is_reproducible_and_unbiased_modes_vary() {
        let engine = tiny_engine();
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Digits, 4, 0xE19);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        let a = engine
            .infer_batch("digits_linear", 3, SchemeId::Deterministic, &pixels)
            .unwrap();
        let b = engine
            .infer_batch("digits_linear", 3, SchemeId::Deterministic, &pixels)
            .unwrap();
        assert!(a.iter().zip(&b).all(|(x, y)| x.logits == y.logits));
        let c = engine
            .infer_batch("digits_linear", 3, SchemeId::Dither, &pixels)
            .unwrap();
        let d = engine
            .infer_batch("digits_linear", 3, SchemeId::Dither, &pixels)
            .unwrap();
        assert!(
            c.iter().zip(&d).any(|(x, y)| x.logits != y.logits),
            "dither logits should vary across batches (seed advances)"
        );
    }

    #[test]
    fn planned_deterministic_matches_direct_path() {
        // The acceptance bit-identity at the serving boundary: cached plans
        // must reproduce the plan-per-call path exactly for deterministic
        // rounding.
        let engine = tiny_engine();
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Fashion, 6, 0xE20);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        for k in [1u32, 4, 8] {
            let planned = engine
                .infer_batch("fashion_mlp", k, SchemeId::Deterministic, &pixels)
                .unwrap();
            let direct = engine
                .infer_batch_unplanned("fashion_mlp", k, SchemeId::Deterministic, &pixels)
                .unwrap();
            assert!(
                planned
                    .iter()
                    .zip(&direct)
                    .all(|(p, d)| p.logits == d.logits && p.pred == d.pred),
                "k={k}"
            );
        }
    }

    #[test]
    fn plan_cache_lru_evicts_oldest() {
        let zoo = Arc::new(Zoo::load(200, 7));
        // Byte budget sized for exactly two digits_linear deterministic
        // plans (one frozen 784×10 weight matrix ≈ 62.7 KB each).
        let engine = Engine::with_plan_cache(zoo, 7, 130_000);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        for k in [2u32, 3, 4] {
            engine
                .infer_batch("digits_linear", k, SchemeId::Deterministic, &rows)
                .unwrap();
        }
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.capacity_bytes, 130_000);
        assert!(stats.bytes <= 130_000, "bytes {} over budget", stats.bytes);
        assert_eq!(stats.len, 2, "bounded cache must not grow past its byte budget");
        assert_eq!((stats.hits, stats.misses), (0, 3));
        // k=3 and k=4 are resident; re-serving them hits.
        for k in [3u32, 4] {
            engine
                .infer_batch("digits_linear", k, SchemeId::Deterministic, &rows)
                .unwrap();
        }
        assert_eq!(engine.plan_cache_stats().hits, 2);
        // k=2 was the LRU victim: serving it again is a rebuild, and it
        // evicts the now-oldest k=3.
        engine
            .infer_batch("digits_linear", 2, SchemeId::Deterministic, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.misses, 4, "evicted configuration must rebuild");
        assert_eq!(stats.len, 2);
        assert!(stats.evictions >= 2, "LRU sheds must be counted: {stats:?}");
        engine
            .infer_batch("digits_linear", 4, SchemeId::Deterministic, &rows)
            .unwrap();
        assert_eq!(engine.plan_cache_stats().hits, 3, "k=4 must still be resident");
    }

    #[test]
    fn plan_cache_evicts_by_bytes_not_entries() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::with_plan_cache(zoo, 7, 2_000_000);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        // Two large fashion_mlp stochastic preparations (~1.75 MB of
        // per-call tables each) overflow a 2 MB budget at entry count 2.
        engine
            .infer_batch("fashion_mlp", 4, SchemeId::Stochastic, &rows)
            .unwrap();
        let one = engine.plan_cache_stats();
        assert_eq!(one.len, 1);
        assert!(one.bytes > 1_000_000, "fashion plan should be large, got {}", one.bytes);
        engine
            .infer_batch("fashion_mlp", 5, SchemeId::Stochastic, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.len, 1, "few large plans must still overflow the byte budget");
        assert!(stats.bytes <= 2_000_000);
        // A small digits plan fits alongside the resident large one — the
        // budget is bytes, not a slot count.
        engine
            .infer_batch("digits_linear", 4, SchemeId::Stochastic, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.len, 2);
        assert!(stats.bytes <= 2_000_000);
        // The resident large plan hits; the byte-evicted one rebuilds.
        engine
            .infer_batch("fashion_mlp", 5, SchemeId::Stochastic, &rows)
            .unwrap();
        assert_eq!(engine.plan_cache_stats().hits, 1);
        engine
            .infer_batch("fashion_mlp", 4, SchemeId::Stochastic, &rows)
            .unwrap();
        assert_eq!(engine.plan_cache_stats().misses, 4);
    }

    #[test]
    fn oversized_plan_is_not_retained() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::with_plan_cache(zoo, 7, 1_000_000);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        engine
            .infer_batch("fashion_mlp", 4, SchemeId::Stochastic, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!(
            (stats.len, stats.bytes),
            (0, 0),
            "a plan larger than the whole budget must not pin memory"
        );
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn plan_resident_peeks_without_touching_counters() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::from_zoo(zoo, 7);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &rows)
            .unwrap();
        let key = PlanKey {
            model: "digits_linear".to_string(),
            bits: 4,
            scheme: SchemeId::Dither,
            variant: Variant::Separate,
        };
        let before = engine.plan_cache_stats();
        assert!(engine.plan_resident(&key));
        let mut cold = key.clone();
        cold.bits = 9;
        assert!(!engine.plan_resident(&cold));
        assert_eq!(engine.plan_cache_stats(), before, "peek must not count as a hit");
    }

    #[test]
    fn shadow_sampling_records_logit_errors() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let sink = Arc::new(crate::fidelity::FidelityShard::new());
        let engine = Engine::from_zoo(zoo, 7).with_shadow(1.0, sink.clone());
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Digits, 6, 0xE33);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        engine
            .infer_batch("digits_linear", 8, SchemeId::Dither, &pixels)
            .unwrap();
        let est = sink.estimate(ModelSpec::DigitsLinear.index(), SchemeId::Dither, 8);
        assert_eq!(est.samples, 6 * 10, "rate 1.0 shadows every row's logits");
        assert!(est.mse() > 0.0, "quantized logits should differ from exact");
        assert!(est.mse() < 1.0, "k=8 dither error should be small, mse {}", est.mse());
        // The default engine (rate 0) records nothing.
        let quiet = Engine::new(200, 7);
        quiet
            .infer_batch("digits_linear", 8, SchemeId::Dither, &pixels)
            .unwrap();
        assert_eq!(quiet.fidelity().total_samples(), 0);
        assert_eq!(quiet.shadow_rate(), 0.0);
    }

    #[test]
    fn unplanned_baseline_feeds_shadow_estimators() {
        // Regression: the A/B baseline used to bypass shadow_observe, so
        // serving with the plan cache capped at 0 left stats.fidelity
        // empty and the auto controller stuck on its prior.
        let zoo = Arc::new(Zoo::load(200, 7));
        let sink = Arc::new(crate::fidelity::FidelityShard::new());
        let engine = Engine::with_plan_cache(zoo, 7, 0).with_shadow(1.0, sink.clone());
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Digits, 4, 0xE44);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        // Cap 0 routes infer_batch through the unplanned baseline.
        engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &pixels)
            .unwrap();
        assert_eq!(sink.total_samples(), 4 * 10, "every row's logits shadowed");
        let stats = engine.plan_cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.len), (0, 1, 0));
        // Direct A/B calls record too.
        engine
            .infer_batch_unplanned("digits_linear", 4, SchemeId::Dither, &pixels)
            .unwrap();
        assert_eq!(sink.total_samples(), 8 * 10);
    }

    #[test]
    fn zero_capacity_disables_retention() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::with_plan_cache(zoo, 7, 0);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        for _ in 0..3 {
            engine
                .infer_batch("digits_linear", 4, SchemeId::Dither, &rows)
                .unwrap();
        }
        let stats = engine.plan_cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.len), (0, 3, 0));
    }

    #[test]
    fn prewarm_populates_cache() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::from_zoo(zoo, 7);
        engine.prewarm(&[2, 4], &SchemeId::PAPER);
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.len, 2 * 2 * 3, "models × bits × schemes");
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 0), "prewarmed config must hit");
    }

    #[test]
    fn timed_batches_report_stage_intervals() {
        let engine = tiny_engine();
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        let mut times = BatchStageTimes::default();
        engine
            .infer_batch_timed("digits_linear", 4, SchemeId::Dither, &rows, Some(&mut times))
            .unwrap();
        let (ps, pe) = times.plan.expect("plan interval on the planned path");
        let (ks, ke) = times.kernel.expect("kernel interval");
        assert!(pe >= ps && ke >= ks);
        assert!(ks >= pe, "kernel starts after planning ends");
        assert!(times.shadow.is_none(), "shadow interval only when sampling is on");
        // The unplanned baseline (capacity 0) fuses planning into the
        // kernel interval and stamps shadow when sampling runs.
        let zoo = Arc::new(Zoo::load(200, 7));
        let sink = Arc::new(crate::fidelity::FidelityShard::new());
        let baseline = Engine::with_plan_cache(zoo, 7, 0).with_shadow(1.0, sink);
        let mut times = BatchStageTimes::default();
        baseline
            .infer_batch_timed("digits_linear", 4, SchemeId::Dither, &rows, Some(&mut times))
            .unwrap();
        assert!(times.plan.is_none(), "no separate plan stage without a cache");
        assert!(times.kernel.is_some());
        assert!(times.shadow.is_some(), "shadow interval stamped at rate 1.0");
        // The untimed entry point leaves no residue and still serves.
        let out = engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &rows)
            .unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn rejects_bad_inputs() {
        let engine = tiny_engine();
        let short = vec![0.0f64; 10];
        let rows: Vec<&[f64]> = vec![&short];
        assert!(engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &rows)
            .is_err());
        let ok = vec![0.0f64; 784];
        let rows: Vec<&[f64]> = vec![&ok];
        assert!(engine
            .infer_batch("no_such_model", 4, SchemeId::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 0, SchemeId::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 17, SchemeId::Dither, &rows)
            .is_err());
        let empty: Vec<&[f64]> = Vec::new();
        assert!(engine
            .infer_batch("digits_linear", 4, SchemeId::Dither, &empty)
            .unwrap()
            .is_empty());
    }
}
