//! Inference engine: executes batches against the trained model zoo with
//! the in-tree quantized engines.
//!
//! The engine is the boundary between L3 (request coordination) and the
//! numeric core: it marshals a batch of same-`(model, k, scheme)` requests
//! into one matrix, runs the reduced-precision forward pass
//! ([`crate::nn::quantized_forward`]) under the requested rounding scheme,
//! and reads back logits. Model state ([`Zoo`]) is shared across all
//! serving shards behind an `Arc`; each shard owns its own `Engine`, whose
//! per-engine seed counter decorrelates the stochastic/dither rounding
//! streams between shards without any cross-shard synchronization.

use crate::linalg::{Matrix, Variant};
use crate::nn::{quantized_forward, QuantInferenceConfig};
use crate::rounding::RoundingMode;
use crate::train::Zoo;
use crate::util::error::Result;
use crate::{bail, err};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// The serving engine: shared model zoo + a private rounding-seed stream.
pub struct Engine {
    zoo: Arc<Zoo>,
    seed_counter: AtomicU64,
}

/// Result of one request within a batch.
#[derive(Clone, Debug)]
pub struct InferenceOutput {
    /// Predicted class.
    pub pred: u8,
    /// Raw logits.
    pub logits: Vec<f64>,
}

impl Engine {
    /// Engine over an already-loaded zoo (the serving path: one zoo, one
    /// engine per shard). `seed` seeds this engine's rounding stream; give
    /// each shard a distinct value.
    pub fn from_zoo(zoo: Arc<Zoo>, seed: u64) -> Engine {
        Engine {
            zoo,
            seed_counter: AtomicU64::new(seed),
        }
    }

    /// Standalone engine that loads (or trains + caches) its own zoo.
    /// `train_n` is the training-set size used on cache miss.
    pub fn new(train_n: usize, seed: u64) -> Engine {
        Engine::from_zoo(Arc::new(Zoo::load(train_n, seed)), seed)
    }

    /// The shared model zoo.
    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }

    /// Float (unquantized) test accuracy of a model family.
    pub fn float_accuracy(&self, model: &str) -> Option<f64> {
        self.zoo.get(model).map(|m| m.float_accuracy)
    }

    /// Execute a batch of same-(model, k, scheme) requests.
    ///
    /// Deterministic rounding ignores the seed stream, so its outputs are
    /// bit-reproducible across engines and calls; stochastic and dither
    /// rounding consume one seed per batch, so repeated calls sample fresh
    /// rounding noise (the unbiased-in-expectation serving behaviour the
    /// paper's §VII comparison needs).
    pub fn infer_batch(
        &self,
        model: &str,
        k: u32,
        mode: RoundingMode,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        if !(1..=16).contains(&k) {
            bail!("k={k} out of range 1..=16");
        }
        let state = self
            .zoo
            .get(model)
            .ok_or_else(|| err!("unknown model family {model:?}"))?;
        let dim = state.mlp.layers[0].in_dim();
        let mut x = Matrix::zeros(pixels.len(), dim);
        for (i, row) in pixels.iter().enumerate() {
            if row.len() != dim {
                bail!(
                    "request {i}: expected {dim} pixels for {model}, got {}",
                    row.len()
                );
            }
            x.row_mut(i).copy_from_slice(row);
        }
        // One seed per batch: deterministic mode never reads it, the
        // unbiased modes get a fresh rounding stream each call.
        let seed = self.seed_counter.fetch_add(1, Ordering::Relaxed);
        let cfg = QuantInferenceConfig {
            bits: k,
            mode,
            variant: Variant::Separate,
            seed,
        };
        let logits_matrix = quantized_forward(&state.mlp, &x, &state.ranges, &cfg);
        let mut out = Vec::with_capacity(pixels.len());
        for i in 0..pixels.len() {
            let logits = logits_matrix.row(i).to_vec();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j as u8)
                .unwrap_or(0);
            out.push(InferenceOutput { pred, logits });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine() -> Engine {
        Engine::new(200, 7)
    }

    #[test]
    fn deterministic_is_reproducible_and_unbiased_modes_vary() {
        let engine = tiny_engine();
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Digits, 4, 0xE19);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        let a = engine
            .infer_batch("digits_linear", 3, RoundingMode::Deterministic, &pixels)
            .unwrap();
        let b = engine
            .infer_batch("digits_linear", 3, RoundingMode::Deterministic, &pixels)
            .unwrap();
        assert!(a.iter().zip(&b).all(|(x, y)| x.logits == y.logits));
        let c = engine
            .infer_batch("digits_linear", 3, RoundingMode::Dither, &pixels)
            .unwrap();
        let d = engine
            .infer_batch("digits_linear", 3, RoundingMode::Dither, &pixels)
            .unwrap();
        assert!(
            c.iter().zip(&d).any(|(x, y)| x.logits != y.logits),
            "dither logits should vary across batches (seed advances)"
        );
    }

    #[test]
    fn rejects_bad_inputs() {
        let engine = tiny_engine();
        let short = vec![0.0f64; 10];
        let rows: Vec<&[f64]> = vec![&short];
        assert!(engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &rows)
            .is_err());
        let ok = vec![0.0f64; 784];
        let rows: Vec<&[f64]> = vec![&ok];
        assert!(engine
            .infer_batch("no_such_model", 4, RoundingMode::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 0, RoundingMode::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 17, RoundingMode::Dither, &rows)
            .is_err());
        let empty: Vec<&[f64]> = Vec::new();
        assert!(engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &empty)
            .unwrap()
            .is_empty());
    }
}
