//! Inference engine: owns the trained models and the PJRT runtime, and
//! executes batches against the AOT artifacts.
//!
//! The engine is the boundary between L3 (request coordination) and L2/L1
//! (the compiled JAX/Pallas computation): it marshals a batch of requests
//! into input literals — weights, scalars, calibrated ranges — and reads
//! back logits. Python is never involved.

use crate::coordinator::protocol::mode_code;
use crate::data::{Dataset, Task};
use crate::nn::{ActivationRanges, Mlp};
use crate::rounding::RoundingMode;
use crate::runtime::client::{
    f32_scalar, i32_scalar, matrix_literal, padded_batch_literal, u32_scalar, vec_literal,
};
use crate::runtime::Runtime;
use crate::train::{trained_model, ModelSpec};
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// One model family's serving state.
struct ModelState {
    mlp: Mlp,
    /// Hidden-layer half-ranges (fashion only; empty for linear).
    hidden_half_ranges: Vec<f64>,
    /// Float test accuracy at load time (reported in logs).
    float_accuracy: f64,
}

/// The serving engine.
pub struct Engine {
    runtime: Runtime,
    digits: ModelState,
    fashion: ModelState,
    seed_counter: AtomicU64,
}

/// Result of one request within a batch.
#[derive(Clone, Debug)]
pub struct InferenceOutput {
    /// Predicted class.
    pub pred: u8,
    /// Raw logits.
    pub logits: Vec<f64>,
}

impl Engine {
    /// Build the engine: PJRT client + artifacts + trained models (cached
    /// under `artifacts/weights/`, trained on first run).
    pub fn new(artifacts_dir: &str, train_n: usize, seed: u64) -> Result<Engine> {
        let runtime = Runtime::cpu(artifacts_dir)?;
        let digits = load_state(ModelSpec::DigitsLinear, train_n, seed)?;
        let fashion = load_state(ModelSpec::FashionMlp, train_n, seed)?;
        Ok(Engine {
            runtime,
            digits,
            fashion,
            seed_counter: AtomicU64::new(seed),
        })
    }

    /// The underlying runtime (for reporting).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    /// Float (unquantized) test accuracy of a model family.
    pub fn float_accuracy(&self, model: &str) -> Option<f64> {
        match model {
            "digits_linear" => Some(self.digits.float_accuracy),
            "fashion_mlp" => Some(self.fashion.float_accuracy),
            _ => None,
        }
    }

    /// Execute a batch of same-(model, k, mode) requests.
    pub fn infer_batch(
        &self,
        model: &str,
        k: u32,
        mode: RoundingMode,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        let artifact = self.runtime.pick_batch_artifact(model, pixels.len())?;
        let loaded = self.runtime.load(&artifact)?;
        let batch = loaded.meta.batch;
        // Oversized batches are split recursively.
        if pixels.len() > batch {
            let (head, tail) = pixels.split_at(batch);
            let mut out = self.infer_batch(model, k, mode, head)?;
            out.extend(self.infer_batch(model, k, mode, tail)?);
            return Ok(out);
        }
        let seed = self.seed_counter.fetch_add(1, Ordering::Relaxed) as u32;
        let x = padded_batch_literal(pixels, 784, batch)?;
        let state = match model {
            "digits_linear" => &self.digits,
            "fashion_mlp" => &self.fashion,
            other => bail!("unknown model family {other:?}"),
        };
        let mut inputs: Vec<xla::Literal> = vec![x];
        for layer in &state.mlp.layers {
            inputs.push(matrix_literal(&layer.weights)?);
            inputs.push(vec_literal(&layer.bias));
        }
        inputs.push(i32_scalar(k as i32));
        inputs.push(i32_scalar(mode_code(mode)));
        inputs.push(u32_scalar(seed));
        for &r in &state.hidden_half_ranges {
            inputs.push(f32_scalar(r as f32));
        }
        let (_rows, cols, data) = loaded.run_f32(&inputs)?;
        let mut out = Vec::with_capacity(pixels.len());
        for i in 0..pixels.len() {
            let logits: Vec<f64> = data[i * cols..(i + 1) * cols]
                .iter()
                .map(|&v| v as f64)
                .collect();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j as u8)
                .unwrap_or(0);
            out.push(InferenceOutput { pred, logits });
        }
        Ok(out)
    }
}

fn load_state(spec: ModelSpec, train_n: usize, seed: u64) -> Result<ModelState> {
    let (mlp, _test, float_accuracy) = trained_model(spec, train_n, train_n / 5, seed);
    // Calibrate hidden ranges on a small synthetic batch.
    let calib = Dataset::synthesize(spec.task(), 64, seed ^ 0xCA11B);
    let ranges = ActivationRanges::calibrate(&mlp, &calib.images);
    let hidden_half_ranges: Vec<f64> =
        ranges.per_layer[1..].iter().map(|&(_, hi)| hi).collect();
    let _ = Task::Digits; // (Task used via spec.task())
    Ok(ModelState {
        mlp,
        hidden_half_ranges,
        float_accuracy,
    })
}

#[cfg(test)]
mod tests {
    // Engine tests live in rust/tests/integration_serving.rs (they need the
    // artifacts directory built by `make artifacts`). Unit coverage for the
    // pieces lives in runtime::client and coordinator::protocol.
}
