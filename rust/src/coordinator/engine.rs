//! Inference engine: executes batches against the trained model zoo with
//! the in-tree quantized engines.
//!
//! The engine is the boundary between L3 (request coordination) and the
//! numeric core: it marshals a batch of same-`(model, k, scheme)` requests
//! into one matrix, runs the reduced-precision forward pass under the
//! requested rounding scheme, and reads back logits. Model state ([`Zoo`])
//! is shared across all serving shards behind an `Arc`; each shard owns its
//! own `Engine`, whose per-engine seed counter decorrelates the
//! stochastic/dither rounding streams between shards without any
//! cross-shard synchronization.
//!
//! Each engine additionally owns a **bounded LRU plan cache** of
//! [`PreparedModel`]s keyed by [`PlanKey`] (the
//! [`crate::nn::QuantInferenceConfig`] fingerprint): hot scheme/bit
//! configurations skip all weight-side planning and requantization, paying
//! only for the activation side of each request. The cache is per shard —
//! shards specialize on the configurations their connections actually
//! send, instead of all sharing one view of the zoo.

use crate::linalg::{Matrix, Variant};
use crate::nn::{quantized_forward, PlanKey, PreparedModel, QuantInferenceConfig};
use crate::rounding::RoundingMode;
use crate::train::Zoo;
use crate::util::error::Result;
use crate::{bail, err};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Default per-engine plan-cache capacity (entries). Sized for the full
/// prewarm grid (2 models × 3 schemes × a handful of bit widths) plus
/// headroom for request-driven configurations.
pub const DEFAULT_PLAN_CACHE: usize = 32;

/// Bounded LRU over prepared models. Capacity 0 disables retention: every
/// lookup is a miss that builds fresh plans (the cache-miss baseline the
/// `bench_e2e` plan-cache comparison measures).
struct PlanCache {
    capacity: usize,
    /// Front = most recently used.
    entries: VecDeque<(PlanKey, Arc<PreparedModel>)>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    fn new(capacity: usize) -> PlanCache {
        PlanCache {
            capacity,
            entries: VecDeque::new(),
            hits: 0,
            misses: 0,
        }
    }

    fn get(&mut self, key: &PlanKey) -> Option<Arc<PreparedModel>> {
        let idx = self.entries.iter().position(|(k, _)| k == key)?;
        let entry = self.entries.remove(idx).expect("index from position");
        let plans = entry.1.clone();
        self.entries.push_front(entry);
        self.hits += 1;
        Some(plans)
    }

    fn insert(&mut self, key: PlanKey, plans: Arc<PreparedModel>) {
        if self.capacity == 0 {
            return;
        }
        if let Some(idx) = self.entries.iter().position(|(k, _)| k == &key) {
            self.entries.remove(idx);
        }
        self.entries.push_front((key, plans));
        while self.entries.len() > self.capacity {
            self.entries.pop_back();
        }
    }
}

/// Observable plan-cache counters (tests, benches, ops logging).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups served from the cache.
    pub hits: u64,
    /// Lookups that built fresh plans.
    pub misses: u64,
    /// Resident entries.
    pub len: usize,
    /// Configured capacity (0 = caching disabled).
    pub capacity: usize,
}

/// The serving engine: shared model zoo + a private rounding-seed stream +
/// a per-engine prepared-plan cache.
pub struct Engine {
    zoo: Arc<Zoo>,
    seed_counter: AtomicU64,
    /// Seed for freezing dither weight draws in prepared plans (stable per
    /// engine so repeated cache misses rebuild identical plans).
    prep_seed: u64,
    plans: Mutex<PlanCache>,
}

/// Result of one request within a batch.
#[derive(Clone, Debug)]
pub struct InferenceOutput {
    /// Predicted class.
    pub pred: u8,
    /// Raw logits.
    pub logits: Vec<f64>,
}

impl Engine {
    /// Engine over an already-loaded zoo (the serving path: one zoo, one
    /// engine per shard). `seed` seeds this engine's rounding stream; give
    /// each shard a distinct value.
    pub fn from_zoo(zoo: Arc<Zoo>, seed: u64) -> Engine {
        Engine::with_plan_cache(zoo, seed, DEFAULT_PLAN_CACHE)
    }

    /// Engine with an explicit plan-cache capacity (entries; 0 disables
    /// caching so every request replans the weight side — the cache-miss
    /// baseline).
    pub fn with_plan_cache(zoo: Arc<Zoo>, seed: u64, plan_cache_cap: usize) -> Engine {
        Engine {
            zoo,
            seed_counter: AtomicU64::new(seed),
            prep_seed: seed,
            plans: Mutex::new(PlanCache::new(plan_cache_cap)),
        }
    }

    /// Override the plan-preparation seed (the frozen dither weight draw).
    /// The shard pool points every engine at the seed the zoo prewarmed
    /// with, so a plan rebuilt after eviction is bit-identical to the
    /// prewarmed one it replaces.
    pub fn with_prep_seed(mut self, prep_seed: u64) -> Engine {
        self.prep_seed = prep_seed;
        self
    }

    /// Standalone engine that loads (or trains + caches) its own zoo.
    /// `train_n` is the training-set size used on cache miss.
    pub fn new(train_n: usize, seed: u64) -> Engine {
        Engine::from_zoo(Arc::new(Zoo::load(train_n, seed)), seed)
    }

    /// The shared model zoo.
    pub fn zoo(&self) -> &Zoo {
        &self.zoo
    }

    /// Float (unquantized) test accuracy of a model family.
    pub fn float_accuracy(&self, model: &str) -> Option<f64> {
        self.zoo.get(model).map(|m| m.float_accuracy)
    }

    /// Plan-cache counters.
    pub fn plan_cache_stats(&self) -> PlanCacheStats {
        let cache = self.plans.lock().unwrap();
        PlanCacheStats {
            hits: cache.hits,
            misses: cache.misses,
            len: cache.entries.len(),
            capacity: cache.capacity,
        }
    }

    /// Install an externally prepared model (zoo-level prewarming: build
    /// the plans once at startup, share them across every shard's cache).
    pub fn install_prepared(&self, key: PlanKey, plans: Arc<PreparedModel>) {
        self.plans.lock().unwrap().insert(key, plans);
    }

    /// Prewarm this engine's cache for the given bit widths and schemes
    /// across every zoo model (startup path for standalone engines).
    pub fn prewarm(&self, bits: &[u32], modes: &[RoundingMode]) {
        let prepared = self
            .zoo
            .prewarm_plans(bits, modes, Variant::Separate, self.prep_seed);
        for (key, plans) in prepared {
            self.install_prepared(key, plans);
        }
    }

    /// Fetch the prepared model for a configuration, building (and caching,
    /// capacity permitting) on miss.
    fn prepared_for(&self, key: &PlanKey, mlp: &crate::nn::Mlp) -> Arc<PreparedModel> {
        let mut cache = self.plans.lock().unwrap();
        if let Some(plans) = cache.get(key) {
            return plans;
        }
        cache.misses += 1;
        let plans = Arc::new(PreparedModel::prepare(
            mlp,
            key.bits,
            key.mode,
            key.variant,
            self.prep_seed,
        ));
        cache.insert(key.clone(), plans.clone());
        plans
    }

    /// Validate a batch and marshal it into one input matrix.
    fn marshal<'z>(
        &'z self,
        model: &str,
        k: u32,
        pixels: &[&[f64]],
    ) -> Result<(&'z crate::train::ZooModel, Matrix)> {
        if !(1..=16).contains(&k) {
            bail!("k={k} out of range 1..=16");
        }
        let state = self
            .zoo
            .get(model)
            .ok_or_else(|| err!("unknown model family {model:?}"))?;
        let dim = state.mlp.layers[0].in_dim();
        let mut x = Matrix::zeros(pixels.len(), dim);
        for (i, row) in pixels.iter().enumerate() {
            if row.len() != dim {
                bail!(
                    "request {i}: expected {dim} pixels for {model}, got {}",
                    row.len()
                );
            }
            x.row_mut(i).copy_from_slice(row);
        }
        Ok((state, x))
    }

    /// Draw one batch seed and assemble the serving inference config (the
    /// single derivation both the planned and unplanned paths share).
    fn batch_config(&self, k: u32, mode: RoundingMode) -> QuantInferenceConfig {
        // One seed per batch: deterministic mode never reads it, the
        // unbiased modes get a fresh rounding stream each call.
        let seed = self.seed_counter.fetch_add(1, Ordering::Relaxed);
        QuantInferenceConfig {
            bits: k,
            mode,
            variant: Variant::Separate,
            seed,
        }
    }

    /// Read logits back into per-request outputs.
    fn read_back(logits_matrix: &Matrix) -> Vec<InferenceOutput> {
        let mut out = Vec::with_capacity(logits_matrix.rows);
        for i in 0..logits_matrix.rows {
            let logits = logits_matrix.row(i).to_vec();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(j, _)| j as u8)
                .unwrap_or(0);
            out.push(InferenceOutput { pred, logits });
        }
        out
    }

    /// Execute a batch of same-(model, k, scheme) requests.
    ///
    /// Deterministic rounding ignores the seed stream, so its outputs are
    /// bit-reproducible across engines and calls; stochastic and dither
    /// rounding consume one seed per batch, so repeated calls sample fresh
    /// rounding noise (the unbiased-in-expectation serving behaviour the
    /// paper's §VII comparison needs). The weight side of every layer comes
    /// from the plan cache; only the activation side is planned per call.
    pub fn infer_batch(
        &self,
        model: &str,
        k: u32,
        mode: RoundingMode,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        let (state, x) = self.marshal(model, k, pixels)?;
        let cfg = self.batch_config(k, mode);
        let prepared = self.prepared_for(&cfg.plan_key(model), &state.mlp);
        let logits_matrix = prepared.forward(&state.mlp, &x, &state.ranges, cfg.seed);
        Ok(Engine::read_back(&logits_matrix))
    }

    /// The direct (plan-both-sides-per-call) forward pass for one batch —
    /// the pre-plan-cache serving path, kept for A/B checks and benches.
    pub fn infer_batch_unplanned(
        &self,
        model: &str,
        k: u32,
        mode: RoundingMode,
        pixels: &[&[f64]],
    ) -> Result<Vec<InferenceOutput>> {
        if pixels.is_empty() {
            return Ok(Vec::new());
        }
        let (state, x) = self.marshal(model, k, pixels)?;
        let cfg = self.batch_config(k, mode);
        let logits_matrix = quantized_forward(&state.mlp, &x, &state.ranges, &cfg);
        Ok(Engine::read_back(&logits_matrix))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_engine() -> Engine {
        Engine::new(200, 7)
    }

    #[test]
    fn deterministic_is_reproducible_and_unbiased_modes_vary() {
        let engine = tiny_engine();
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Digits, 4, 0xE19);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        let a = engine
            .infer_batch("digits_linear", 3, RoundingMode::Deterministic, &pixels)
            .unwrap();
        let b = engine
            .infer_batch("digits_linear", 3, RoundingMode::Deterministic, &pixels)
            .unwrap();
        assert!(a.iter().zip(&b).all(|(x, y)| x.logits == y.logits));
        let c = engine
            .infer_batch("digits_linear", 3, RoundingMode::Dither, &pixels)
            .unwrap();
        let d = engine
            .infer_batch("digits_linear", 3, RoundingMode::Dither, &pixels)
            .unwrap();
        assert!(
            c.iter().zip(&d).any(|(x, y)| x.logits != y.logits),
            "dither logits should vary across batches (seed advances)"
        );
    }

    #[test]
    fn planned_deterministic_matches_direct_path() {
        // The acceptance bit-identity at the serving boundary: cached plans
        // must reproduce the plan-per-call path exactly for deterministic
        // rounding.
        let engine = tiny_engine();
        let ds = crate::data::Dataset::synthesize(crate::data::Task::Fashion, 6, 0xE20);
        let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
        for k in [1u32, 4, 8] {
            let planned = engine
                .infer_batch("fashion_mlp", k, RoundingMode::Deterministic, &pixels)
                .unwrap();
            let direct = engine
                .infer_batch_unplanned("fashion_mlp", k, RoundingMode::Deterministic, &pixels)
                .unwrap();
            assert!(
                planned
                    .iter()
                    .zip(&direct)
                    .all(|(p, d)| p.logits == d.logits && p.pred == d.pred),
                "k={k}"
            );
        }
    }

    #[test]
    fn plan_cache_lru_evicts_oldest() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::with_plan_cache(zoo, 7, 2);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        for k in [2u32, 3, 4] {
            engine
                .infer_batch("digits_linear", k, RoundingMode::Deterministic, &rows)
                .unwrap();
        }
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.capacity, 2);
        assert_eq!(stats.len, 2, "bounded cache must not grow past capacity");
        assert_eq!((stats.hits, stats.misses), (0, 3));
        // k=3 and k=4 are resident; re-serving them hits.
        for k in [3u32, 4] {
            engine
                .infer_batch("digits_linear", k, RoundingMode::Deterministic, &rows)
                .unwrap();
        }
        assert_eq!(engine.plan_cache_stats().hits, 2);
        // k=2 was the LRU victim: serving it again is a rebuild, and it
        // evicts the now-oldest k=3.
        engine
            .infer_batch("digits_linear", 2, RoundingMode::Deterministic, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.misses, 4, "evicted configuration must rebuild");
        assert_eq!(stats.len, 2);
        engine
            .infer_batch("digits_linear", 4, RoundingMode::Deterministic, &rows)
            .unwrap();
        assert_eq!(engine.plan_cache_stats().hits, 3, "k=4 must still be resident");
    }

    #[test]
    fn zero_capacity_disables_retention() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::with_plan_cache(zoo, 7, 0);
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        for _ in 0..3 {
            engine
                .infer_batch("digits_linear", 4, RoundingMode::Dither, &rows)
                .unwrap();
        }
        let stats = engine.plan_cache_stats();
        assert_eq!((stats.hits, stats.misses, stats.len), (0, 3, 0));
    }

    #[test]
    fn prewarm_populates_cache() {
        let zoo = Arc::new(Zoo::load(200, 7));
        let engine = Engine::from_zoo(zoo, 7);
        engine.prewarm(&[2, 4], &RoundingMode::ALL);
        let stats = engine.plan_cache_stats();
        assert_eq!(stats.len, 2 * 2 * 3, "models × bits × schemes");
        let px = vec![0.3f64; 784];
        let rows: Vec<&[f64]> = vec![&px];
        engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &rows)
            .unwrap();
        let stats = engine.plan_cache_stats();
        assert_eq!((stats.hits, stats.misses), (1, 0), "prewarmed config must hit");
    }

    #[test]
    fn rejects_bad_inputs() {
        let engine = tiny_engine();
        let short = vec![0.0f64; 10];
        let rows: Vec<&[f64]> = vec![&short];
        assert!(engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &rows)
            .is_err());
        let ok = vec![0.0f64; 784];
        let rows: Vec<&[f64]> = vec![&ok];
        assert!(engine
            .infer_batch("no_such_model", 4, RoundingMode::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 0, RoundingMode::Dither, &rows)
            .is_err());
        assert!(engine
            .infer_batch("digits_linear", 17, RoundingMode::Dither, &rows)
            .is_err());
        let empty: Vec<&[f64]> = Vec::new();
        assert!(engine
            .infer_batch("digits_linear", 4, RoundingMode::Dither, &empty)
            .unwrap()
            .is_empty());
    }
}
