//! Wire protocol for the inference server: newline-delimited JSON.
//!
//! Request:
//! ```json
//! {"id": 1, "model": "digits_linear", "k": 4, "scheme": "dither",
//!  "pixels": [784 floats in 0..1]}
//! ```
//! `"scheme"` names any registered rounding scheme (the `hello` reply
//! lists them). The `"mode"` request field is **deprecated**: it is still
//! accepted as an alias for `"scheme"` so older clients keep working, but
//! each use is counted in `stats.deprecated_fields` and the alias will be
//! removed in a future protocol revision.
//! **Auto precision**: `"scheme": "auto"` (or `"k": 0`) plus at least one
//! budget — a positive `"max_mse"` error budget, a `"max_latency_us"`
//! latency SLO, or both — asks the server to pick the cheapest
//! `(scheme, k)` meeting every budget, walking candidates by *measured*
//! recent latency once the windows are warm (see
//! [`crate::fidelity::controller`]); any concrete `scheme`/`k` in an auto
//! request is ignored — the controller chooses both. A budget-less auto
//! request is a non-retryable error.
//! Response (every reply echoes the concrete `scheme` and `k` served;
//! auto-resolved requests additionally carry `"auto": true`, plus
//! `"measured": true` when the choice was backed by live measurements
//! rather than priors and static cost order):
//! ```json
//! {"id": 1, "pred": 7, "scheme": "dither", "k": 4, "logits": [...],
//!  "latency_us": 412, "batch": 8, "shard": 2}
//! ```
//! Control: `{"cmd": "ping"}`, `{"cmd": "hello"}` (feature handshake),
//! `{"cmd": "stats"}`, `{"cmd": "trace"}` (query the slow/sampled trace
//! ring, filters `min_us` / `model` / `scheme` / `limit`),
//! `{"cmd": "metrics"}` (Prometheus text exposition wrapped in one JSON
//! line), `{"cmd": "watch"}` / `{"cmd": "unwatch"}` (event
//! subscriptions, below), `{"cmd": "shutdown"}`. Control verbs are
//! answered outside the in-flight window — monitoring keeps working
//! during overload, which is exactly when it matters.
//!
//! **Events (protocol v4)**: `{"cmd":"watch"}` registers a long-lived
//! per-connection subscription to the process's ops-event journal, with
//! optional filters `"severity"` (minimum: `info`/`warn`/`error`) and
//! `"kinds"` (array of event-kind wire names). The server acks with
//! `{"subscribed":true,"watch":<id>}` and then streams matching events as
//! out-of-order lines `{"watch":<id>,"event":{...}}` interleaved with
//! replies on the same connection (see [`crate::obs`] for the event
//! shape and the bounded drop-oldest delivery queue semantics).
//! `{"cmd":"unwatch","watch":<id>}` tears one subscription down
//! (`{"unwatched":<id>,"removed":bool}`); disconnect tears all down.
//! Delivery is stream-only — no replay — so a re-subscribing client can
//! never observe a duplicate event.
//!
//! **Tracing (protocol v3)**: a request line may carry
//! `"trace": "<16-hex id>:<flags>"` — a trace context propagated by the
//! cluster proxy so one request's timeline stitches across processes.
//! Servers that predate v3 ignore the field; a malformed tag downgrades
//! to "no trace" rather than rejecting the request.
//!
//! **Errors**: every failure reply has one shape, across the server, the
//! cluster proxy, and the watchdog alike:
//! `{"id": 1, "error": "...", "retryable": false}`. `retryable` tells the
//! client whether resending the identical request can ever succeed —
//! `false` for malformed lines and unknown schemes, `true` for transient
//! conditions (overload, shutdown, timeout). Overload replies
//! additionally keep the legacy marker:
//! `{"id": 1, "error": "overloaded", "overloaded": true, "retryable": true}`.
//!
//! **Pipelining**: the protocol is fully pipelined — a client may write
//! any number of request lines without reading replies, and responses
//! come back in *completion* order, not submission order. The `id` echo
//! on every reply (successes, errors, and overloads alike) is what lets a
//! client match them up; [`Reassembler`] is the client-side helper. The
//! `{"cmd":"hello"}` handshake (protocol v4) advertises the feature set,
//! the server's per-connection in-flight window, `"proto": 4`, and
//! `"schemes": [...]` — the registered rounding schemes this endpoint can
//! serve; clients that never send it can keep the old lockstep discipline
//! (one request, then one reply) unchanged.

use crate::fidelity::FidelityEstimate;
use crate::obs::{EventKind, Severity};
use crate::rounding::SchemeId;
use crate::util::json::Json;
use std::collections::HashMap;

/// Current protocol revision: v4 = v3 (trace propagation) plus the
/// `watch`/`unwatch` event-subscription verbs and the `"events"` feature
/// flag in the `hello` reply.
pub const PROTO_VERSION: f64 = 4.0;

/// A parsed inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    /// Client-chosen id echoed back in the response.
    pub id: u64,
    /// Model family: `digits_linear` or `fashion_mlp`.
    pub model: String,
    /// Quantizer bit width. For an auto request this is a placeholder
    /// until the precision controller resolves it pre-batching.
    pub k: u32,
    /// Rounding scheme (placeholder for auto requests, see `k`).
    pub scheme: SchemeId,
    /// True for `"scheme":"auto"` / `"k":0` requests: the server picks
    /// `(scheme, k)` from `max_mse` before the request reaches a batcher,
    /// and the response is tagged `"auto": true`.
    pub auto: bool,
    /// True when the scheme arrived via the deprecated `"mode"` request
    /// field — the server bumps `stats.deprecated_fields` per use.
    pub deprecated_mode: bool,
    /// Per-request MSE budget (auto requests only; at least one of
    /// `max_mse` / `max_latency_us` is present on a parsed auto request).
    pub max_mse: Option<f64>,
    /// Per-request latency SLO in microseconds against the measured
    /// recent windows (auto requests only).
    pub max_latency_us: Option<u64>,
    /// Upstream trace context `(trace_id, flags)` from the `"trace"`
    /// wire field (protocol v3; `None` when absent or malformed).
    pub trace: Option<(u64, u8)>,
    /// Flattened image pixels.
    pub pixels: Vec<f64>,
}

/// Filters for a `{"cmd":"trace"}` ring-buffer query. All optional: the
/// zero value ([`TraceQuery::default`]) returns every resident trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceQuery {
    /// Only traces with `total_us >= min_us`.
    pub min_us: u64,
    /// Only traces for this model family.
    pub model: Option<String>,
    /// Only traces served by this scheme (wire name).
    pub scheme: Option<String>,
    /// At most this many traces, newest first (0 = no cap).
    pub limit: usize,
}

/// Build a `{"cmd":"trace"}` query line — the client side the cluster
/// proxy also uses when it fans a trace query out to its backends.
pub fn format_trace_query(q: &TraceQuery) -> String {
    let mut pairs = vec![("cmd", Json::Str("trace".to_string()))];
    if q.min_us > 0 {
        pairs.push(("min_us", Json::Num(q.min_us as f64)));
    }
    if let Some(model) = &q.model {
        pairs.push(("model", Json::Str(model.clone())));
    }
    if let Some(scheme) = &q.scheme {
        pairs.push(("scheme", Json::Str(scheme.clone())));
    }
    if q.limit > 0 {
        pairs.push(("limit", Json::Num(q.limit as f64)));
    }
    Json::obj(pairs).to_string()
}

/// Build a `{"cmd":"trace"}` reply line: the matching traces (newest
/// first) plus their count. The proxy emits the same shape with each
/// proxy trace carrying an `"upstream"` array of backend timelines.
pub fn format_traces(traces: &[crate::trace::Trace]) -> String {
    Json::obj(vec![
        (
            "traces",
            Json::Arr(traces.iter().map(crate::trace::Trace::to_json).collect()),
        ),
        ("count", Json::Num(traces.len() as f64)),
    ])
    .to_string()
}

/// Parse a `{"cmd":"trace"}` reply back into traces — the proxy re-parses
/// backend dumps with this to stitch cluster timelines, and clients use
/// it to inspect what the ring retained. Individual malformed records are
/// skipped (same downgrade-not-reject stance as the `"trace"` field).
pub fn parse_traces(line: &str) -> Result<Vec<crate::trace::Trace>, String> {
    let json = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    let arr = json
        .get("traces")
        .and_then(Json::as_arr)
        .ok_or("reply has no 'traces' array")?;
    Ok(arr.iter().filter_map(crate::trace::Trace::from_json).collect())
}

/// Wrap a Prometheus text exposition into the one-line JSON reply of the
/// `{"cmd":"metrics"}` verb (the newline-delimited protocol cannot carry
/// the multi-line exposition raw; JSON string escaping does it for free).
pub fn format_metrics_reply(exposition: &str) -> String {
    Json::obj(vec![("metrics", Json::Str(exposition.to_string()))]).to_string()
}

/// Unwrap a `{"cmd":"metrics"}` reply back into the exposition text.
pub fn parse_metrics_reply(line: &str) -> Result<String, String> {
    Json::parse(line.trim())
        .map_err(|e| e.to_string())?
        .get("metrics")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| "reply has no 'metrics' field".to_string())
}

/// Filters for a `{"cmd":"watch"}` event subscription. The zero value
/// ([`WatchQuery::default`]) subscribes to every event.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WatchQuery {
    /// Minimum severity delivered (`None` = everything).
    pub severity: Option<Severity>,
    /// Only these event kinds (empty = all kinds).
    pub kinds: Vec<EventKind>,
}

/// Build a `{"cmd":"watch"}` subscription line — also the client side the
/// cluster proxy uses against its backends.
pub fn format_watch(q: &WatchQuery) -> String {
    let mut pairs = vec![("cmd", Json::Str("watch".to_string()))];
    if let Some(severity) = q.severity {
        pairs.push(("severity", Json::Str(severity.wire_name().to_string())));
    }
    if !q.kinds.is_empty() {
        pairs.push((
            "kinds",
            Json::Arr(
                q.kinds
                    .iter()
                    .map(|k| Json::Str(k.wire_name().to_string()))
                    .collect(),
            ),
        ));
    }
    Json::obj(pairs).to_string()
}

/// Build the `{"cmd":"watch"}` ack: `{"subscribed":true,"watch":<id>}`.
pub fn format_watch_ack(id: u64) -> String {
    Json::obj(vec![
        ("subscribed", Json::Bool(true)),
        ("watch", Json::Num(id as f64)),
    ])
    .to_string()
}

/// Parse a watch ack back into the subscription id.
pub fn parse_watch_ack(line: &str) -> Result<u64, String> {
    let json = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    if json.get("subscribed").and_then(Json::as_bool) != Some(true) {
        return Err(format!("not a watch ack: {line}"));
    }
    json.get("watch")
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .ok_or_else(|| format!("watch ack without id: {line}"))
}

/// Build a `{"cmd":"unwatch"}` line for subscription `id`.
pub fn format_unwatch(id: u64) -> String {
    Json::obj(vec![
        ("cmd", Json::Str("unwatch".to_string())),
        ("watch", Json::Num(id as f64)),
    ])
    .to_string()
}

/// Build the unwatch ack: `{"unwatched":<id>,"removed":bool}` —
/// `removed` says whether the id named a live subscription (unwatch is
/// idempotent, a stale id is not an error).
pub fn format_unwatch_ack(id: u64, removed: bool) -> String {
    Json::obj(vec![
        ("unwatched", Json::Num(id as f64)),
        ("removed", Json::Bool(removed)),
    ])
    .to_string()
}

/// A parsed incoming message.
#[derive(Clone, Debug)]
pub enum Message {
    /// Run inference.
    Infer(InferenceRequest),
    /// Liveness check.
    Ping,
    /// Feature handshake: the reply advertises pipelining and the
    /// per-connection in-flight window.
    Hello,
    /// Metrics snapshot request.
    Stats,
    /// Query the slow/sampled trace ring buffer.
    Trace(TraceQuery),
    /// Prometheus text exposition request.
    Metrics,
    /// Subscribe this connection to the ops-event journal (protocol v4).
    Watch(WatchQuery),
    /// Tear down one of this connection's subscriptions by id.
    Unwatch(u64),
    /// Graceful shutdown.
    Shutdown,
}

/// Parse one request line.
pub fn parse_message(line: &str) -> Result<Message, String> {
    let json = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = json.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Ok(Message::Ping),
            "hello" => Ok(Message::Hello),
            "stats" => Ok(Message::Stats),
            "trace" => Ok(Message::Trace(TraceQuery {
                min_us: json
                    .get("min_us")
                    .and_then(Json::as_f64)
                    .map(|v| v.max(0.0) as u64)
                    .unwrap_or(0),
                model: json
                    .get("model")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                scheme: json
                    .get("scheme")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                limit: json.get("limit").and_then(Json::as_usize).unwrap_or(0),
            })),
            "metrics" => Ok(Message::Metrics),
            "watch" => {
                let severity = match json.get("severity").and_then(Json::as_str) {
                    Some(s) => Some(
                        Severity::from_wire(s)
                            .ok_or_else(|| format!("unknown severity {s:?}"))?,
                    ),
                    None => None,
                };
                let mut kinds = Vec::new();
                for v in json.get("kinds").and_then(Json::as_arr).unwrap_or(&[]) {
                    let name = v.as_str().ok_or("non-string entry in 'kinds'")?;
                    kinds.push(
                        EventKind::from_wire(name)
                            .ok_or_else(|| format!("unknown event kind {name:?}"))?,
                    );
                }
                Ok(Message::Watch(WatchQuery { severity, kinds }))
            }
            "unwatch" => {
                let id = json
                    .get("watch")
                    .and_then(Json::as_f64)
                    .ok_or("unwatch without a 'watch' id")? as u64;
                Ok(Message::Unwatch(id))
            }
            "shutdown" => Ok(Message::Shutdown),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let id = json
        .get("id")
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(0);
    let model = json
        .get("model")
        .and_then(Json::as_str)
        .unwrap_or("digits_linear")
        .to_string();
    // "scheme" is the documented field; "mode" remains a deprecated alias
    // that callers count via `deprecated_mode`.
    let deprecated_mode = json.get("scheme").is_none() && json.get("mode").is_some();
    let scheme_raw = json
        .get("scheme")
        .or_else(|| json.get("mode"))
        .and_then(Json::as_str);
    let auto_scheme = scheme_raw == Some("auto");
    let k = match json.get("k").and_then(Json::as_usize) {
        Some(k) => k as u32,
        // `"scheme":"auto"` makes `k` optional — the controller picks it.
        None if auto_scheme => 0,
        None => return Err("missing 'k'".to_string()),
    };
    let auto = auto_scheme || k == 0;
    let (scheme, k, max_mse, max_latency_us) = if auto {
        let max_mse = match json.get("max_mse").and_then(Json::as_f64) {
            Some(budget) => {
                if !budget.is_finite() || budget <= 0.0 {
                    return Err(format!("max_mse={budget} must be positive and finite"));
                }
                Some(budget)
            }
            None => None,
        };
        let max_latency_us = match json.get("max_latency_us").and_then(Json::as_f64) {
            Some(budget) => {
                if !budget.is_finite() || budget < 1.0 {
                    return Err(format!(
                        "max_latency_us={budget} must be at least 1 microsecond"
                    ));
                }
                Some(budget as u64)
            }
            None => None,
        };
        if max_mse.is_none() && max_latency_us.is_none() {
            return Err("\"scheme\":\"auto\" / \"k\":0 requires a 'max_mse' or \
                        'max_latency_us' budget"
                .to_string());
        }
        // Placeholders: the server's precision controller overwrites both
        // before the request is batched.
        (SchemeId::Dither, 0, max_mse, max_latency_us)
    } else {
        if !(1..=16).contains(&k) {
            return Err(format!("k={k} out of range 1..=16"));
        }
        let scheme = match scheme_raw {
            Some(s) => s.parse::<SchemeId>().map_err(|e| e.to_string())?,
            None => return Err("missing 'scheme'".to_string()),
        };
        (scheme, k, None, None)
    };
    let pixels = json
        .get("pixels")
        .and_then(Json::as_f64_vec)
        .ok_or("missing 'pixels'")?;
    if pixels.len() != 784 {
        return Err(format!("expected 784 pixels, got {}", pixels.len()));
    }
    // Malformed tags downgrade to "no trace": observability must never
    // fail a request that would otherwise serve.
    let trace = json
        .get("trace")
        .and_then(Json::as_str)
        .and_then(crate::trace::decode_wire);
    Ok(Message::Infer(InferenceRequest {
        id,
        model,
        k,
        scheme,
        auto,
        deprecated_mode,
        max_mse,
        max_latency_us,
        trace,
        pixels,
    }))
}

/// Build a request line — the client side of [`parse_message`]. Every
/// in-tree client (examples, load generator, tests, benches) goes through
/// this so a protocol change cannot leave a stale hand-built copy behind.
pub fn format_request(id: u64, model: &str, k: u32, scheme: SchemeId, pixels: &[f64]) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("model", Json::Str(model.to_string())),
        ("k", Json::Num(k as f64)),
        ("scheme", Json::Str(scheme.to_string())),
        ("pixels", Json::nums(pixels)),
    ])
    .to_string()
}

/// Build an auto-precision request line: no `(scheme, k)`, just an MSE
/// budget the server's controller satisfies as cheaply as it can.
pub fn format_request_auto(id: u64, model: &str, max_mse: f64, pixels: &[f64]) -> String {
    format_request_auto_slo(id, model, Some(max_mse), None, pixels)
}

/// Build an auto request line carrying any combination of SLO budgets: an
/// error budget (`max_mse`), a latency budget (`max_latency_us`), or
/// both. Passing neither builds a line the server rejects as a
/// non-retryable error — tests use that spelling deliberately.
pub fn format_request_auto_slo(
    id: u64,
    model: &str,
    max_mse: Option<f64>,
    max_latency_us: Option<u64>,
    pixels: &[f64],
) -> String {
    let mut pairs = vec![
        ("id", Json::Num(id as f64)),
        ("model", Json::Str(model.to_string())),
        ("scheme", Json::Str("auto".to_string())),
    ];
    if let Some(budget) = max_mse {
        pairs.push(("max_mse", Json::Num(budget)));
    }
    if let Some(budget) = max_latency_us {
        pairs.push(("max_latency_us", Json::Num(budget as f64)));
    }
    pairs.push(("pixels", Json::nums(pixels)));
    Json::obj(pairs).to_string()
}

/// Successful inference response line. `scheme`/`k` are the concrete
/// configuration that served the request; `auto` tags replies whose
/// configuration the precision controller chose, and `measured`
/// additionally tags auto replies whose choice was backed by live
/// measurements (a warm MSE cell or latency window) rather than priors
/// and static cost order — ignored for non-auto replies, whose wire
/// bytes stay identical to the pre-SLO protocol.
#[allow(clippy::too_many_arguments)]
pub fn format_response(
    id: u64,
    pred: u8,
    scheme: SchemeId,
    k: u32,
    logits: &[f64],
    latency_us: u64,
    batch: usize,
    shard: usize,
    auto: bool,
    measured: bool,
) -> String {
    let mut pairs = vec![
        ("id", Json::Num(id as f64)),
        ("pred", Json::Num(pred as f64)),
        ("scheme", Json::Str(scheme.to_string())),
        ("k", Json::Num(f64::from(k))),
        ("logits", Json::nums(logits)),
        ("latency_us", Json::Num(latency_us as f64)),
        ("batch", Json::Num(batch as f64)),
        ("shard", Json::Num(shard as f64)),
    ];
    if auto {
        pairs.push(("auto", Json::Bool(true)));
        if measured {
            pairs.push(("measured", Json::Bool(true)));
        }
    }
    Json::obj(pairs).to_string()
}

/// Error response line — the one failure shape every serving path emits.
/// `retryable` tells the client whether resending the identical request
/// can ever succeed: `false` for malformed lines and unknown schemes,
/// `true` for transient conditions (overload, shutdown, timeout).
pub fn format_error(id: u64, error: &str, retryable: bool) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(error.to_string())),
        ("retryable", Json::Bool(retryable)),
    ])
    .to_string()
}

/// Overload (backpressure) response line: the shard's bounded queue was
/// full, the client should back off and retry. Keeps the legacy
/// `"overloaded"` marker alongside the unified `retryable` flag.
pub fn format_overloaded(id: u64) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str("overloaded".to_string())),
        ("overloaded", Json::Bool(true)),
        ("retryable", Json::Bool(true)),
    ])
    .to_string()
}

/// Handshake response (protocol v4 — v3 plus the `watch`/`unwatch`
/// event-subscription verbs, advertised as the `"events"` feature):
/// advertises the pipelined protocol,
/// the server's per-connection in-flight window (requests beyond it are
/// answered `overloaded` immediately), the rounding schemes this
/// endpoint serves — the server passes the registry's list, the cluster
/// proxy the intersection across its healthy backends — and the compute
/// kernel the process selected at startup (`"kernel":"scalar"|"wide"`).
/// The wire format of every other message is unchanged, so clients that
/// never send `hello` keep working in lockstep.
pub fn format_hello(max_inflight: usize, schemes: &[&str], kernel: &str) -> String {
    Json::obj(vec![
        ("hello", Json::Bool(true)),
        ("proto", Json::Num(PROTO_VERSION)),
        (
            "features",
            Json::Arr(vec![
                Json::Str("pipelined".to_string()),
                Json::Str("events".to_string()),
            ]),
        ),
        ("max_inflight", Json::Num(max_inflight as f64)),
        (
            "schemes",
            Json::Arr(schemes.iter().map(|s| Json::Str((*s).to_string())).collect()),
        ),
        ("kernel", Json::Str(kernel.to_string())),
    ])
    .to_string()
}

/// Client-side view of a `hello` reply.
#[derive(Clone, Debug)]
pub struct HelloInfo {
    /// Protocol revision (1 when the server predates the field).
    pub proto: u32,
    /// Per-connection in-flight window.
    pub max_inflight: usize,
    /// Rounding schemes the endpoint serves. A v1 server advertises no
    /// list; it serves exactly the paper's trio, so that is the default.
    pub schemes: Vec<String>,
    /// Compute kernel the endpoint selected at startup (`None` when the
    /// server predates the field).
    pub kernel: Option<String>,
}

/// Parse a `hello` reply line into a [`HelloInfo`].
pub fn parse_hello(line: &str) -> Result<HelloInfo, String> {
    let json = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    if json.get("hello").and_then(Json::as_bool) != Some(true) {
        return Err(format!("not a hello reply: {line}"));
    }
    let proto = json
        .get("proto")
        .and_then(Json::as_usize)
        .unwrap_or(1) as u32;
    let max_inflight = json
        .get("max_inflight")
        .and_then(Json::as_usize)
        .ok_or("hello reply without 'max_inflight'")?;
    let schemes = match json.get("schemes").and_then(Json::as_arr) {
        Some(arr) => arr
            .iter()
            .filter_map(Json::as_str)
            .map(str::to_string)
            .collect(),
        None => SchemeId::PAPER.iter().map(|s| s.to_string()).collect(),
    };
    let kernel = json
        .get("kernel")
        .and_then(Json::as_str)
        .map(str::to_string);
    Ok(HelloInfo {
        proto,
        max_inflight,
        schemes,
        kernel,
    })
}

/// Best-effort id extraction from a request line that failed to parse as
/// a [`Message`]. Error replies echo it so a pipelined client can match
/// the failure back to the request it wrote (0 when the line carries no
/// usable id — such failures cannot be attributed).
pub fn line_id(line: &str) -> u64 {
    Json::parse(line)
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_f64))
        .map(|v| v as u64)
        .unwrap_or(0)
}

/// The id echoed by a response line (success, error, or overload reply
/// alike). Errors on lines that carry no id, which a pipelined client
/// cannot attribute to any request.
pub fn response_id(line: &str) -> Result<u64, String> {
    Json::parse(line)
        .map_err(|e| e.to_string())?
        .get("id")
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .ok_or_else(|| format!("response has no id: {line}"))
}

/// Client-side reassembly for pipelined connections: responses arrive in
/// completion order, so a client files each line under its echoed id and
/// picks replies up by the id it is waiting on. Filing two replies for
/// one id is an error — the protocol guarantees exactly one reply per
/// accepted request, and tests use this to catch double answers.
#[derive(Debug, Default)]
pub struct Reassembler {
    by_id: HashMap<u64, String>,
}

impl Reassembler {
    /// Empty reassembler.
    pub fn new() -> Reassembler {
        Reassembler::default()
    }

    /// File one response line under its echoed id; returns that id. A
    /// duplicate id is an error and leaves the originally filed reply
    /// untouched.
    pub fn insert(&mut self, line: &str) -> Result<u64, String> {
        let id = response_id(line)?;
        if self.by_id.contains_key(&id) {
            return Err(format!("duplicate response for id {id}"));
        }
        self.by_id.insert(id, line.trim().to_string());
        Ok(id)
    }

    /// Take the response for a request id, if it has arrived.
    pub fn take(&mut self, id: u64) -> Option<String> {
        self.by_id.remove(&id)
    }

    /// Responses filed and not yet taken.
    pub fn len(&self) -> usize {
        self.by_id.len()
    }

    /// True when no responses are waiting.
    pub fn is_empty(&self) -> bool {
        self.by_id.is_empty()
    }
}

/// Client-side view of one `stats.fidelity` cell: the `(model, scheme, k)`
/// label plus the Welford estimate reconstructed from the emitted
/// `samples`/`bias`/`variance` fields (`m2 = variance · samples`), so
/// cells scraped from different server processes can be merged with
/// [`FidelityEstimate::merge`] — the cluster proxy's cross-node view.
#[derive(Clone, Debug)]
pub struct FidelityCell {
    /// Model family name.
    pub model: String,
    /// Rounding scheme.
    pub scheme: SchemeId,
    /// Quantizer bit width.
    pub k: u32,
    /// Reconstructed Welford estimate.
    pub estimate: FidelityEstimate,
}

/// One per-scheme `stats.recent` cell as seen on the wire: the request
/// count plus the raw log₂ window buckets a merging consumer sums across
/// backends (empty for servers that predate bucket emission).
#[derive(Clone, Debug, Default)]
pub struct RecentCell {
    /// Scheme wire name the cell belongs to.
    pub scheme: String,
    /// Requests in the recent window.
    pub requests: u64,
    /// Raw log₂ latency buckets for the window.
    pub buckets: Vec<u64>,
}

/// Client-side parse of a `stats` reply: the counters and fidelity cells a
/// merging consumer (the cluster proxy's cluster-wide scrape, the load
/// generator's sum checks) needs. Counter fields absent from older
/// servers parse as zero.
#[derive(Clone, Debug, Default)]
pub struct StatsSummary {
    /// Completed requests.
    pub requests: u64,
    /// Protocol/execution errors (cancellations included).
    pub errors: u64,
    /// Overload rejections (queue or in-flight window).
    pub rejected: u64,
    /// Watchdog-answered requests.
    pub timeouts: u64,
    /// Requests that used a deprecated request field (the `"mode"` alias
    /// for `"scheme"`).
    pub deprecated_fields: u64,
    /// Executed batches.
    pub batches: u64,
    /// Requests served inside those batches (recovered from `mean_batch`).
    pub batched_requests: u64,
    /// Total end-to-end latency (recovered from `mean_us`).
    pub latency_sum_us: f64,
    /// Lifetime latency percentiles (µs).
    pub p50_us: f64,
    /// 95th percentile (µs).
    pub p95_us: f64,
    /// 99th percentile (µs).
    pub p99_us: f64,
    /// Server uptime in seconds.
    pub uptime_s: f64,
    /// Serving shards in the process.
    pub shards: usize,
    /// Per-shard completed-request counts.
    pub per_shard_requests: Vec<f64>,
    /// Writer-side coalesced flushes.
    pub writer_flushes: u64,
    /// Reply lines delivered across those flushes.
    pub writer_flushed_lines: u64,
    /// Latency samples whose `(model, k)` label fell outside the bounded
    /// recent-window space (dropped from measured-cost resolution).
    pub recent_dropped: u64,
    /// Auto requests that carried a `max_latency_us` budget.
    pub auto_slo_requests: u64,
    /// Auto requests resolved from live measurements.
    pub auto_measured: u64,
    /// Compute kernel the server reported (`None` for older servers).
    pub kernel: Option<String>,
    /// Raw lifetime log₂ latency buckets (empty for older servers). When
    /// present, these — not the backend's point percentiles — are what a
    /// cluster merge should sum.
    pub latency_buckets: Vec<u64>,
    /// Per-scheme recent-window cells with raw buckets.
    pub recent: Vec<RecentCell>,
    /// Observed `(model, scheme, k)` fidelity cells.
    pub fidelity: Vec<FidelityCell>,
}

/// Parse a JSON number array into bucket counts (absent/odd values → 0).
fn parse_buckets(json: Option<&Json>) -> Vec<u64> {
    json.and_then(Json::as_f64_vec)
        .map(|v| v.iter().map(|&b| b.max(0.0).round() as u64).collect())
        .unwrap_or_default()
}

/// Parse a `stats` reply line into a [`StatsSummary`].
pub fn parse_stats(line: &str) -> Result<StatsSummary, String> {
    let json = Json::parse(line.trim()).map_err(|e| e.to_string())?;
    let num = |key: &str| json.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    let count = |key: &str| num(key).max(0.0).round() as u64;
    let requests = count("requests");
    let batches = count("batches");
    let mut recent = Vec::new();
    if let Some(Json::Obj(map)) = json.get("recent") {
        for (scheme, cell) in map {
            recent.push(RecentCell {
                scheme: scheme.clone(),
                requests: cell
                    .get("requests")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0)
                    .max(0.0)
                    .round() as u64,
                buckets: parse_buckets(cell.get("buckets")),
            });
        }
    }
    let mut fidelity = Vec::new();
    if let Some(cells) = json.get("fidelity").and_then(Json::as_arr) {
        for cell in cells {
            let model = cell
                .get("model")
                .and_then(Json::as_str)
                .ok_or("fidelity cell without 'model'")?
                .to_string();
            let scheme = cell
                .get("scheme")
                .and_then(Json::as_str)
                .and_then(|s| s.parse::<SchemeId>().ok())
                .ok_or("fidelity cell without a valid 'scheme'")?;
            let k = cell
                .get("k")
                .and_then(Json::as_usize)
                .ok_or("fidelity cell without 'k'")? as u32;
            let samples = cell
                .get("samples")
                .and_then(Json::as_f64)
                .unwrap_or(0.0)
                .max(0.0)
                .round() as u64;
            let bias = cell.get("bias").and_then(Json::as_f64).unwrap_or(0.0);
            let variance = cell.get("variance").and_then(Json::as_f64).unwrap_or(0.0);
            fidelity.push(FidelityCell {
                model,
                scheme,
                k,
                estimate: FidelityEstimate {
                    samples,
                    bias,
                    m2: variance * samples as f64,
                },
            });
        }
    }
    Ok(StatsSummary {
        requests,
        errors: count("errors"),
        rejected: count("rejected"),
        timeouts: count("timeouts"),
        deprecated_fields: count("deprecated_fields"),
        batches,
        batched_requests: (num("mean_batch") * batches as f64).round() as u64,
        latency_sum_us: num("mean_us") * requests as f64,
        p50_us: num("p50_us"),
        p95_us: num("p95_us"),
        p99_us: num("p99_us"),
        uptime_s: num("uptime_s"),
        shards: json.get("shards").and_then(Json::as_usize).unwrap_or(0),
        per_shard_requests: json
            .get("per_shard_requests")
            .and_then(Json::as_f64_vec)
            .unwrap_or_default(),
        writer_flushes: count("writer_flushes"),
        writer_flushed_lines: count("writer_flushed_lines"),
        recent_dropped: count("recent_dropped"),
        auto_slo_requests: count("auto_slo_requests"),
        auto_measured: count("auto_measured"),
        kernel: json
            .get("kernel")
            .and_then(Json::as_str)
            .map(str::to_string),
        latency_buckets: parse_buckets(json.get("latency_buckets")),
        recent,
        fidelity,
    })
}

/// The rounding-mode wire encoding shared with the Pallas kernels
/// (0 = deterministic, 1 = stochastic, 2 = dither). The Rust serving path
/// no longer marshals these codes (the PJRT bridge is gone), but
/// `python/compile/kernels/ref.py` and the AOT artifacts still take them
/// as an input scalar — this function and its test pin the contract until
/// an executable bridge returns (see ROADMAP "Open items"). The literature
/// zoo has no kernel encoding yet, so those schemes return `None`.
pub fn mode_code(scheme: SchemeId) -> Option<i32> {
    match scheme {
        SchemeId::Deterministic => Some(0),
        SchemeId::Stochastic => Some(1),
        SchemeId::Dither => Some(2),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request(k: u32) -> String {
        let pixels: Vec<String> = (0..784).map(|i| format!("{}", i as f64 / 784.0)).collect();
        format!(
            "{{\"id\": 42, \"model\": \"digits_linear\", \"k\": {k}, \"scheme\": \"dither\", \"pixels\": [{}]}}",
            pixels.join(",")
        )
    }

    #[test]
    fn parse_inference_request() {
        let msg = parse_message(&sample_request(4)).unwrap();
        match msg {
            Message::Infer(r) => {
                assert_eq!(r.id, 42);
                assert_eq!(r.k, 4);
                assert_eq!(r.scheme, SchemeId::Dither);
                assert!(!r.deprecated_mode);
                assert_eq!(r.pixels.len(), 784);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn every_registered_scheme_parses_from_the_wire() {
        for id in SchemeId::ALL {
            let line = sample_request(4).replace("\"dither\"", &format!("{:?}", id.to_string()));
            match parse_message(&line).unwrap() {
                Message::Infer(r) => assert_eq!(r.scheme, id),
                other => panic!("wrong message {other:?}"),
            }
        }
    }

    #[test]
    fn mode_is_accepted_as_deprecated_scheme_alias() {
        let line = sample_request(4).replace("\"scheme\"", "\"mode\"");
        match parse_message(&line).unwrap() {
            Message::Infer(r) => {
                assert_eq!(r.scheme, SchemeId::Dither);
                assert!(r.deprecated_mode, "alias use must be flagged");
            }
            other => panic!("wrong message {other:?}"),
        }
        // "scheme" wins when both are present — and counts as the modern
        // spelling.
        let both = sample_request(4).replace(
            "\"scheme\": \"dither\"",
            "\"scheme\": \"stochastic\", \"mode\": \"dither\"",
        );
        match parse_message(&both).unwrap() {
            Message::Infer(r) => {
                assert_eq!(r.scheme, SchemeId::Stochastic);
                assert!(!r.deprecated_mode);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn parse_control_messages() {
        assert!(matches!(parse_message("{\"cmd\":\"ping\"}"), Ok(Message::Ping)));
        assert!(matches!(
            parse_message("{\"cmd\":\"stats\"}"),
            Ok(Message::Stats)
        ));
        assert!(matches!(
            parse_message("{\"cmd\":\"metrics\"}"),
            Ok(Message::Metrics)
        ));
        assert!(matches!(
            parse_message("{\"cmd\":\"shutdown\"}"),
            Ok(Message::Shutdown)
        ));
        assert!(parse_message("{\"cmd\":\"nope\"}").is_err());
    }

    #[test]
    fn trace_query_roundtrips_through_the_wire() {
        // Bare query: every filter at its zero value.
        match parse_message("{\"cmd\":\"trace\"}").unwrap() {
            Message::Trace(q) => assert_eq!(q, TraceQuery::default()),
            other => panic!("wrong message {other:?}"),
        }
        let q = TraceQuery {
            min_us: 500,
            model: Some("fashion_mlp".to_string()),
            scheme: Some("tpdf".to_string()),
            limit: 16,
        };
        match parse_message(&format_trace_query(&q)).unwrap() {
            Message::Trace(parsed) => assert_eq!(parsed, q),
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn trace_and_metrics_replies_roundtrip() {
        use crate::trace::{Span, Stage, Trace};
        let trace = Trace {
            trace_id: 0xFEED_F00D,
            request_id: 7,
            model: "digits_linear".to_string(),
            scheme: "dither".to_string(),
            k: 4,
            shard: Some(1),
            total_us: 900,
            sampled: true,
            slow: false,
            spans: vec![Span {
                stage: Stage::Kernel,
                start_us: 100,
                dur_us: 600,
                note: Some("wide/dither".to_string()),
            }],
        };
        let line = format_traces(std::slice::from_ref(&trace));
        assert!(Json::parse(&line).unwrap().get("count").unwrap().as_f64() == Some(1.0));
        assert_eq!(parse_traces(&line).unwrap(), vec![trace]);
        assert_eq!(parse_traces("{\"traces\":[]}").unwrap(), Vec::new());
        assert!(parse_traces("{\"pong\":true}").is_err());
        // Metrics replies carry the multi-line exposition in one JSON line.
        let exposition = "# HELP x y\n# TYPE x counter\nx 1\n";
        let reply = format_metrics_reply(exposition);
        assert!(!reply.contains('\n'), "reply must stay one line: {reply}");
        assert_eq!(parse_metrics_reply(&reply).unwrap(), exposition);
        assert!(parse_metrics_reply("{\"pong\":true}").is_err());
    }

    #[test]
    fn trace_field_parses_and_downgrades_when_malformed() {
        let tag = crate::trace::encode_wire(0xDEAD_BEEF, 1);
        let line = sample_request(4)
            .replace("\"id\": 42,", &format!("\"id\": 42, \"trace\": \"{tag}\","));
        match parse_message(&line).unwrap() {
            Message::Infer(r) => assert_eq!(r.trace, Some((0xDEAD_BEEF, 1))),
            other => panic!("wrong message {other:?}"),
        }
        // Untagged requests and malformed tags both come through as None —
        // a bad trace tag must never fail an otherwise valid request.
        match parse_message(&sample_request(4)).unwrap() {
            Message::Infer(r) => assert_eq!(r.trace, None),
            other => panic!("wrong message {other:?}"),
        }
        let junk = sample_request(4)
            .replace("\"id\": 42,", "\"id\": 42, \"trace\": \"not-a-tag\",");
        match parse_message(&junk).unwrap() {
            Message::Infer(r) => assert_eq!(r.trace, None),
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_message("not json").is_err());
        assert!(parse_message("{\"k\": 4}").is_err()); // no pixels
        assert!(parse_message(&sample_request(0)).is_err()); // k out of range
        assert!(parse_message(&sample_request(17)).is_err());
        // wrong pixel count
        assert!(parse_message(
            "{\"id\":1,\"k\":4,\"scheme\":\"dither\",\"pixels\":[1,2,3]}"
        )
        .is_err());
        // bad scheme spelling
        assert!(parse_message(
            &sample_request(4).replace("\"dither\"", "\"fuzzy\"")
        )
        .is_err());
    }

    #[test]
    fn request_roundtrip() {
        let pixels: Vec<f64> = (0..784).map(|i| i as f64 / 784.0).collect();
        let line = format_request(11, "fashion_mlp", 6, SchemeId::Stochastic, &pixels);
        match parse_message(&line).unwrap() {
            Message::Infer(r) => {
                assert_eq!(r.id, 11);
                assert_eq!(r.model, "fashion_mlp");
                assert_eq!(r.k, 6);
                assert_eq!(r.scheme, SchemeId::Stochastic);
                assert_eq!(r.pixels.len(), 784);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn response_roundtrip() {
        let line =
            format_response(7, 3, SchemeId::Dither, 4, &[0.1, 0.9], 250, 4, 2, false, false);
        let json = Json::parse(&line).unwrap();
        assert_eq!(json.get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(json.get("pred").unwrap().as_f64(), Some(3.0));
        assert_eq!(json.get("scheme").unwrap().as_str(), Some("dither"));
        assert_eq!(json.get("k").unwrap().as_f64(), Some(4.0));
        assert_eq!(json.get("batch").unwrap().as_f64(), Some(4.0));
        assert_eq!(json.get("shard").unwrap().as_f64(), Some(2.0));
        assert!(json.get("auto").is_none(), "fixed requests carry no auto tag");
        let auto =
            format_response(8, 1, SchemeId::Deterministic, 2, &[0.5], 10, 1, 0, true, false);
        let json = Json::parse(&auto).unwrap();
        assert_eq!(json.get("auto").unwrap().as_bool(), Some(true));
        assert_eq!(json.get("k").unwrap().as_f64(), Some(2.0));
        assert!(
            json.get("measured").is_none(),
            "prior-resolved auto replies carry no measured tag"
        );
        let warm =
            format_response(8, 1, SchemeId::Deterministic, 2, &[0.5], 10, 1, 0, true, true);
        let json = Json::parse(&warm).unwrap();
        assert_eq!(json.get("measured").unwrap().as_bool(), Some(true));
        // `measured` is meaningless without `auto`: the wire bytes of a
        // fixed-configuration reply never change.
        let fixed =
            format_response(7, 3, SchemeId::Dither, 4, &[0.1, 0.9], 250, 4, 2, false, true);
        assert_eq!(fixed, line, "non-auto replies must stay bit-identical");
        // Zoo schemes ride the same response shape.
        let zoo = format_response(9, 2, SchemeId::SrVb, 3, &[0.5], 10, 1, 0, false, false);
        let json = Json::parse(&zoo).unwrap();
        assert_eq!(json.get("scheme").unwrap().as_str(), Some("srvb"));
    }

    #[test]
    fn error_replies_carry_the_unified_shape() {
        for (retryable, msg) in [(false, "unknown rounding scheme `fuzzy`"), (true, "timeout")] {
            let json = Json::parse(&format_error(7, msg, retryable)).unwrap();
            assert_eq!(json.get("id").unwrap().as_f64(), Some(7.0));
            assert_eq!(json.get("error").unwrap().as_str(), Some(msg));
            assert_eq!(json.get("retryable").unwrap().as_bool(), Some(retryable));
        }
    }

    #[test]
    fn auto_requests_parse_and_validate() {
        let pixels: Vec<f64> = (0..784).map(|i| i as f64 / 784.0).collect();
        let line = format_request_auto(13, "fashion_mlp", 0.25, &pixels);
        match parse_message(&line).unwrap() {
            Message::Infer(r) => {
                assert!(r.auto);
                assert_eq!(r.max_mse, Some(0.25));
                assert_eq!(r.id, 13);
                assert_eq!(r.model, "fashion_mlp");
            }
            other => panic!("wrong message {other:?}"),
        }
        // "k": 0 with a concrete scheme is the other auto spelling.
        let k0 = sample_request(0).replace("\"k\": 0,", "\"k\": 0, \"max_mse\": 1.5,");
        match parse_message(&k0).unwrap() {
            Message::Infer(r) => {
                assert!(r.auto);
                assert_eq!(r.max_mse, Some(1.5));
            }
            other => panic!("wrong message {other:?}"),
        }
        // A fixed request is not auto.
        match parse_message(&sample_request(4)).unwrap() {
            Message::Infer(r) => {
                assert!(!r.auto);
                assert_eq!(r.max_mse, None);
            }
            other => panic!("wrong message {other:?}"),
        }
        // Auto without any budget, or with a junk budget, is rejected.
        let no_budget = line.replace(",\"max_mse\":0.25", "");
        assert!(parse_message(&no_budget).is_err());
        for bad in ["-1", "0", "1e999"] {
            let junk = line.replace("\"max_mse\":0.25", &format!("\"max_mse\":{bad}"));
            assert!(parse_message(&junk).is_err(), "max_mse={bad} must be rejected");
        }
    }

    #[test]
    fn auto_latency_budgets_parse_and_validate() {
        let pixels: Vec<f64> = (0..784).map(|i| i as f64 / 784.0).collect();
        // Latency-only: legal since the SLO protocol revision.
        let lat_only = format_request_auto_slo(21, "digits_linear", None, Some(2500), &pixels);
        match parse_message(&lat_only).unwrap() {
            Message::Infer(r) => {
                assert!(r.auto);
                assert_eq!(r.max_mse, None);
                assert_eq!(r.max_latency_us, Some(2500));
            }
            other => panic!("wrong message {other:?}"),
        }
        // Both budgets together.
        let both =
            format_request_auto_slo(22, "digits_linear", Some(0.25), Some(900), &pixels);
        match parse_message(&both).unwrap() {
            Message::Infer(r) => {
                assert_eq!(r.max_mse, Some(0.25));
                assert_eq!(r.max_latency_us, Some(900));
            }
            other => panic!("wrong message {other:?}"),
        }
        // The mse-only builder is the slo builder with one axis absent.
        assert_eq!(
            format_request_auto(13, "fashion_mlp", 0.25, &pixels),
            format_request_auto_slo(13, "fashion_mlp", Some(0.25), None, &pixels)
        );
        // Budget-less autos and junk latency budgets are rejected; a junk
        // latency budget is rejected even when a valid max_mse rides along.
        let neither = format_request_auto_slo(23, "digits_linear", None, None, &pixels);
        assert!(parse_message(&neither).is_err());
        for bad in ["-5", "0", "0.2", "1e999"] {
            let junk = both.replace("\"max_latency_us\":900", &format!("\"max_latency_us\":{bad}"));
            assert!(
                parse_message(&junk).is_err(),
                "max_latency_us={bad} must be rejected"
            );
        }
        // A fixed-configuration request ignores the SLO fields entirely.
        match parse_message(&sample_request(4)).unwrap() {
            Message::Infer(r) => assert_eq!(r.max_latency_us, None),
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn overload_reply_is_marked() {
        let line = format_overloaded(9);
        let json = Json::parse(&line).unwrap();
        assert_eq!(json.get("id").unwrap().as_f64(), Some(9.0));
        assert_eq!(json.get("overloaded").unwrap().as_bool(), Some(true));
        assert_eq!(json.get("error").unwrap().as_str(), Some("overloaded"));
        assert_eq!(json.get("retryable").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn hello_handshake_roundtrip() {
        assert!(matches!(
            parse_message("{\"cmd\":\"hello\"}"),
            Ok(Message::Hello)
        ));
        let zoo = crate::rounding::SchemeRegistry::global().wire_names();
        let line = format_hello(32, &zoo, "wide");
        let json = Json::parse(&line).unwrap();
        assert_eq!(json.get("hello").unwrap().as_bool(), Some(true));
        assert_eq!(json.get("proto").unwrap().as_f64(), Some(4.0));
        assert_eq!(json.get("max_inflight").unwrap().as_f64(), Some(32.0));
        assert_eq!(json.get("kernel").unwrap().as_str(), Some("wide"));
        let features = json.get("features").unwrap().as_arr().unwrap();
        assert!(features
            .iter()
            .any(|f| f.as_str() == Some("pipelined")));
        assert!(
            features.iter().any(|f| f.as_str() == Some("events")),
            "v4 advertises the watch verbs: {line}"
        );
        let info = parse_hello(&line).unwrap();
        assert_eq!(info.proto, 4);
        assert_eq!(info.max_inflight, 32);
        assert_eq!(info.schemes, zoo, "hello advertises the full registry");
        assert_eq!(info.kernel.as_deref(), Some("wide"));
        // A v1 hello (no proto / schemes / kernel) defaults to the paper's
        // trio and an unknown kernel.
        let legacy = parse_hello("{\"hello\":true,\"max_inflight\":8}").unwrap();
        assert_eq!(legacy.proto, 1);
        assert_eq!(legacy.schemes, vec!["deterministic", "dither", "stochastic"]);
        assert_eq!(legacy.kernel, None);
        assert!(parse_hello("{\"pong\":true}").is_err());
    }

    #[test]
    fn watch_and_unwatch_roundtrip_through_the_wire() {
        // Bare watch: no filters.
        match parse_message("{\"cmd\":\"watch\"}").unwrap() {
            Message::Watch(q) => assert_eq!(q, WatchQuery::default()),
            other => panic!("wrong message {other:?}"),
        }
        let q = WatchQuery {
            severity: Some(Severity::Warn),
            kinds: vec![EventKind::BackendDown, EventKind::AlertFired],
        };
        match parse_message(&format_watch(&q)).unwrap() {
            Message::Watch(parsed) => assert_eq!(parsed, q),
            other => panic!("wrong message {other:?}"),
        }
        // Unknown filter values are rejected, not silently widened.
        assert!(parse_message("{\"cmd\":\"watch\",\"severity\":\"loud\"}").is_err());
        assert!(parse_message("{\"cmd\":\"watch\",\"kinds\":[\"nope\"]}").is_err());
        assert!(parse_message("{\"cmd\":\"watch\",\"kinds\":[7]}").is_err());
        // Unwatch needs its id.
        match parse_message(&format_unwatch(9)).unwrap() {
            Message::Unwatch(id) => assert_eq!(id, 9),
            other => panic!("wrong message {other:?}"),
        }
        assert!(parse_message("{\"cmd\":\"unwatch\"}").is_err());
        // Acks round-trip.
        assert_eq!(parse_watch_ack(&format_watch_ack(3)).unwrap(), 3);
        assert!(parse_watch_ack("{\"pong\":true}").is_err());
        let ack = Json::parse(&format_unwatch_ack(3, true)).unwrap();
        assert_eq!(ack.get("unwatched").unwrap().as_f64(), Some(3.0));
        assert_eq!(ack.get("removed").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn line_id_recovers_ids_from_malformed_requests() {
        // Valid JSON with an id but an invalid body: the error reply can
        // still be attributed.
        assert_eq!(line_id("{\"id\":41,\"k\":99}"), 41);
        // No id, or not JSON at all: falls back to 0.
        assert_eq!(line_id("{\"k\":4}"), 0);
        assert_eq!(line_id("not json"), 0);
    }

    #[test]
    fn reassembler_matches_by_id_and_rejects_duplicates() {
        let mut r = Reassembler::new();
        let a = format_response(3, 1, SchemeId::Dither, 4, &[0.5], 10, 1, 0, false, false);
        let b = format_overloaded(9);
        assert!(r.is_empty());
        assert_eq!(r.insert(&b).unwrap(), 9);
        assert_eq!(r.insert(&a).unwrap(), 3);
        assert_eq!(r.len(), 2);
        // One reply per id: a second answer for id 3 is a protocol error,
        // and the originally filed reply survives the rejected imposter.
        assert!(r.insert(&a).is_err());
        assert!(r.insert(&format_error(3, "imposter", false)).is_err());
        assert!(r.take(3).unwrap().contains("\"pred\""));
        assert!(r.take(9).unwrap().contains("overloaded"));
        assert!(r.take(3).is_none());
        assert!(r.is_empty());
        // A line without an id cannot be filed.
        assert!(r.insert("{\"pong\":true}").is_err());
        assert_eq!(response_id(&format_error(7, "bad", false)).unwrap(), 7);
    }

    #[test]
    fn parse_stats_recovers_counters_and_mergeable_fidelity() {
        // Shape emitted by Metrics::snapshot_json; extra fields ignored,
        // absent counters default to zero.
        let line = "{\"requests\":100,\"errors\":2,\"rejected\":3,\"batches\":25,\
                    \"mean_batch\":4,\"mean_us\":50,\"p50_us\":40,\"p95_us\":90,\
                    \"p99_us\":99,\"uptime_s\":12.5,\"shards\":2,\
                    \"per_shard_requests\":[60,40],\"timeouts\":1,\
                    \"deprecated_fields\":4,\
                    \"fidelity\":[{\"model\":\"digits_linear\",\"scheme\":\"dither\",\
                    \"k\":4,\"samples\":10,\"bias\":0.5,\"mse\":0.5,\"variance\":0.25}]}";
        let s = parse_stats(line).unwrap();
        assert_eq!(s.requests, 100);
        assert_eq!(s.errors, 2);
        assert_eq!(s.rejected, 3);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.deprecated_fields, 4);
        assert_eq!(s.batches, 25);
        assert_eq!(s.batched_requests, 100, "mean_batch * batches");
        assert_eq!(s.latency_sum_us, 5000.0, "mean_us * requests");
        assert_eq!(s.shards, 2);
        assert_eq!(s.per_shard_requests, vec![60.0, 40.0]);
        assert_eq!(s.writer_flushes, 0, "absent counters parse as zero");
        assert_eq!(s.kernel, None, "older servers report no kernel");
        assert!(s.latency_buckets.is_empty(), "no buckets on the wire");
        assert!(s.recent.is_empty());
        let cell = &s.fidelity[0];
        assert_eq!(cell.model, "digits_linear");
        assert_eq!(cell.scheme, SchemeId::Dither);
        assert_eq!(cell.k, 4);
        assert_eq!(cell.estimate.samples, 10);
        // m2 reconstructed so merge() reproduces the server-side math.
        assert!((cell.estimate.m2 - 2.5).abs() < 1e-12);
        assert!((cell.estimate.variance() - 0.25).abs() < 1e-12);
        assert!((cell.estimate.mse() - 0.5).abs() < 1e-12);
        // Two equal halves merge to the same bias with doubled samples.
        let mut merged = cell.estimate.clone();
        merged.merge(&cell.estimate);
        assert_eq!(merged.samples, 20);
        assert!((merged.bias - 0.5).abs() < 1e-12);
        assert!(parse_stats("not json").is_err());
        assert!(
            parse_stats("{\"fidelity\":[{\"scheme\":\"dither\",\"k\":4}]}").is_err(),
            "fidelity cell without a model is rejected"
        );
    }

    #[test]
    fn parse_stats_recovers_kernel_and_histograms() {
        let line = "{\"requests\":7,\"kernel\":\"wide\",\
                    \"latency_buckets\":[0,3,4,0],\
                    \"recent\":{\"dither\":{\"requests\":5,\"p50_us\":3,\
                    \"p99_us\":7,\"buckets\":[0,2,3]},\
                    \"stochastic\":{\"requests\":0,\"buckets\":[0,0,0]}}}";
        let s = parse_stats(line).unwrap();
        assert_eq!(s.kernel.as_deref(), Some("wide"));
        assert_eq!(s.latency_buckets, vec![0, 3, 4, 0]);
        assert_eq!(s.recent.len(), 2);
        let dither = s.recent.iter().find(|c| c.scheme == "dither").unwrap();
        assert_eq!(dither.requests, 5);
        assert_eq!(dither.buckets, vec![0, 2, 3]);
        // The wire buckets reproduce percentiles on the consumer side.
        let p99 = crate::coordinator::metrics::percentile_from_buckets(&s.latency_buckets, 0.99);
        assert_eq!(p99, crate::coordinator::metrics::bucket_upper(2) as f64);
    }

    #[test]
    fn mode_codes_match_kernel_encoding() {
        assert_eq!(mode_code(SchemeId::Deterministic), Some(0));
        assert_eq!(mode_code(SchemeId::Stochastic), Some(1));
        assert_eq!(mode_code(SchemeId::Dither), Some(2));
        // The zoo has no kernel encoding yet.
        for scheme in [SchemeId::Sr2, SchemeId::SrVb, SchemeId::Tpdf, SchemeId::Gauss] {
            assert_eq!(mode_code(scheme), None, "{scheme}");
        }
    }
}
