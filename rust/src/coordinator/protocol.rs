//! Wire protocol for the inference server: newline-delimited JSON.
//!
//! Request:
//! ```json
//! {"id": 1, "model": "digits_linear", "k": 4, "mode": "dither",
//!  "pixels": [784 floats in 0..1]}
//! ```
//! Response:
//! ```json
//! {"id": 1, "pred": 7, "logits": [...], "latency_us": 412, "batch": 8}
//! ```
//! Control: `{"cmd": "ping"}`, `{"cmd": "stats"}`, `{"cmd": "shutdown"}`.

use crate::rounding::RoundingMode;
use crate::util::json::Json;

/// A parsed inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    /// Client-chosen id echoed back in the response.
    pub id: u64,
    /// Model family: `digits_linear` or `fashion_mlp`.
    pub model: String,
    /// Quantizer bit width.
    pub k: u32,
    /// Rounding scheme.
    pub mode: RoundingMode,
    /// Flattened image pixels.
    pub pixels: Vec<f64>,
}

/// A parsed incoming message.
#[derive(Clone, Debug)]
pub enum Message {
    /// Run inference.
    Infer(InferenceRequest),
    /// Liveness check.
    Ping,
    /// Metrics snapshot request.
    Stats,
    /// Graceful shutdown.
    Shutdown,
}

/// Parse one request line.
pub fn parse_message(line: &str) -> Result<Message, String> {
    let json = Json::parse(line).map_err(|e| e.to_string())?;
    if let Some(cmd) = json.get("cmd").and_then(Json::as_str) {
        return match cmd {
            "ping" => Ok(Message::Ping),
            "stats" => Ok(Message::Stats),
            "shutdown" => Ok(Message::Shutdown),
            other => Err(format!("unknown cmd {other:?}")),
        };
    }
    let id = json
        .get("id")
        .and_then(Json::as_f64)
        .map(|v| v as u64)
        .unwrap_or(0);
    let model = json
        .get("model")
        .and_then(Json::as_str)
        .unwrap_or("digits_linear")
        .to_string();
    let k = json
        .get("k")
        .and_then(Json::as_usize)
        .ok_or("missing 'k'")? as u32;
    if !(1..=16).contains(&k) {
        return Err(format!("k={k} out of range 1..=16"));
    }
    let mode = json
        .get("mode")
        .and_then(Json::as_str)
        .and_then(RoundingMode::from_str)
        .ok_or("missing or invalid 'mode'")?;
    let pixels = json
        .get("pixels")
        .and_then(Json::as_f64_vec)
        .ok_or("missing 'pixels'")?;
    if pixels.len() != 784 {
        return Err(format!("expected 784 pixels, got {}", pixels.len()));
    }
    Ok(Message::Infer(InferenceRequest {
        id,
        model,
        k,
        mode,
        pixels,
    }))
}

/// Successful inference response line.
pub fn format_response(id: u64, pred: u8, logits: &[f64], latency_us: u64, batch: usize) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("pred", Json::Num(pred as f64)),
        ("logits", Json::nums(logits)),
        ("latency_us", Json::Num(latency_us as f64)),
        ("batch", Json::Num(batch as f64)),
    ])
    .to_string()
}

/// Error response line.
pub fn format_error(id: u64, error: &str) -> String {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str(error.to_string())),
    ])
    .to_string()
}

/// The rounding-mode wire encoding shared with the Pallas kernel
/// (0 = deterministic, 1 = stochastic, 2 = dither).
pub fn mode_code(mode: RoundingMode) -> i32 {
    match mode {
        RoundingMode::Deterministic => 0,
        RoundingMode::Stochastic => 1,
        RoundingMode::Dither => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_request(k: u32) -> String {
        let pixels: Vec<String> = (0..784).map(|i| format!("{}", i as f64 / 784.0)).collect();
        format!(
            "{{\"id\": 42, \"model\": \"digits_linear\", \"k\": {k}, \"mode\": \"dither\", \"pixels\": [{}]}}",
            pixels.join(",")
        )
    }

    #[test]
    fn parse_inference_request() {
        let msg = parse_message(&sample_request(4)).unwrap();
        match msg {
            Message::Infer(r) => {
                assert_eq!(r.id, 42);
                assert_eq!(r.k, 4);
                assert_eq!(r.mode, RoundingMode::Dither);
                assert_eq!(r.pixels.len(), 784);
            }
            other => panic!("wrong message {other:?}"),
        }
    }

    #[test]
    fn parse_control_messages() {
        assert!(matches!(parse_message("{\"cmd\":\"ping\"}"), Ok(Message::Ping)));
        assert!(matches!(
            parse_message("{\"cmd\":\"stats\"}"),
            Ok(Message::Stats)
        ));
        assert!(matches!(
            parse_message("{\"cmd\":\"shutdown\"}"),
            Ok(Message::Shutdown)
        ));
        assert!(parse_message("{\"cmd\":\"nope\"}").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_message("not json").is_err());
        assert!(parse_message("{\"k\": 4}").is_err()); // no pixels
        assert!(parse_message(&sample_request(0)).is_err()); // k out of range
        assert!(parse_message(&sample_request(17)).is_err());
        // wrong pixel count
        assert!(parse_message(
            "{\"id\":1,\"k\":4,\"mode\":\"dither\",\"pixels\":[1,2,3]}"
        )
        .is_err());
    }

    #[test]
    fn response_roundtrip() {
        let line = format_response(7, 3, &[0.1, 0.9], 250, 4);
        let json = Json::parse(&line).unwrap();
        assert_eq!(json.get("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(json.get("pred").unwrap().as_f64(), Some(3.0));
        assert_eq!(json.get("batch").unwrap().as_f64(), Some(4.0));
        let err = format_error(7, "bad");
        assert!(Json::parse(&err).unwrap().get("error").is_some());
    }

    #[test]
    fn mode_codes_match_kernel_encoding() {
        assert_eq!(mode_code(RoundingMode::Deterministic), 0);
        assert_eq!(mode_code(RoundingMode::Stochastic), 1);
        assert_eq!(mode_code(RoundingMode::Dither), 2);
    }
}
