//! Serving metrics: counters and latency percentiles for the coordinator.

use crate::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

/// Thread-safe metrics registry.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
    started: Instant,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    errors: u64,
    batches: u64,
    batched_requests: u64,
    latencies_us: Vec<u64>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Fresh registry.
    pub fn new() -> Metrics {
        Metrics {
            inner: Mutex::new(Inner::default()),
            started: Instant::now(),
        }
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record_request(&self, latency_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.requests += 1;
        // Reservoir-less cap: keep the most recent 100k latencies.
        if g.latencies_us.len() >= 100_000 {
            g.latencies_us.clear();
        }
        g.latencies_us.push(latency_us);
    }

    /// Record a protocol or execution error.
    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// Record one executed batch of the given size.
    pub fn record_batch(&self, size: usize) {
        let mut g = self.inner.lock().unwrap();
        g.batches += 1;
        g.batched_requests += size as u64;
    }

    /// Snapshot as a JSON line (the `stats` command response).
    pub fn snapshot_json(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut lat = g.latencies_us.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> f64 {
            if lat.is_empty() {
                return 0.0;
            }
            let idx = ((lat.len() - 1) as f64 * p).round() as usize;
            lat[idx] as f64
        };
        let mean_batch = if g.batches == 0 {
            0.0
        } else {
            g.batched_requests as f64 / g.batches as f64
        };
        let uptime = self.started.elapsed().as_secs_f64();
        let throughput = if uptime > 0.0 {
            g.requests as f64 / uptime
        } else {
            0.0
        };
        Json::obj(vec![
            ("requests", Json::Num(g.requests as f64)),
            ("errors", Json::Num(g.errors as f64)),
            ("batches", Json::Num(g.batches as f64)),
            ("mean_batch", Json::Num(mean_batch)),
            ("p50_us", Json::Num(pct(0.50))),
            ("p95_us", Json::Num(pct(0.95))),
            ("p99_us", Json::Num(pct(0.99))),
            ("uptime_s", Json::Num(uptime)),
            ("throughput_rps", Json::Num(throughput)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        for i in 0..100 {
            m.record_request(i * 10);
        }
        m.record_batch(8);
        m.record_batch(4);
        m.record_error();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(100.0));
        assert_eq!(json.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.get("mean_batch").unwrap().as_f64(), Some(6.0));
        let p50 = json.get("p50_us").unwrap().as_f64().unwrap();
        assert!((400.0..=600.0).contains(&p50), "p50={p50}");
    }

    #[test]
    fn empty_snapshot_is_valid() {
        let m = Metrics::new();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("p95_us").unwrap().as_f64(), Some(0.0));
    }
}
