//! Serving metrics: per-shard lock-free counters, merged on scrape.
//!
//! The hot path (batch workers, connection threads) only touches its own
//! shard's [`ShardMetrics`] — plain relaxed atomics, no shared lock — so
//! counting never serializes shards against each other. The `stats`
//! command walks every shard and merges counters plus the log₂ latency
//! histograms into one JSON snapshot.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Number of log₂ latency buckets: bucket `i` holds latencies in
/// `[2^(i-1), 2^i)` µs (bucket 0 is exactly 0 µs). 2^38 µs ≈ 3 days, far
/// beyond any request timeout.
const BUCKETS: usize = 40;

/// One shard's counters. All operations are relaxed atomics.
#[derive(Debug)]
pub struct ShardMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    latency_sum_us: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
}

impl Default for ShardMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardMetrics {
    /// Fresh zeroed counters.
    pub fn new() -> ShardMetrics {
        ShardMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            latency_sum_us: AtomicU64::new(0),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one completed request with its end-to-end latency.
    pub fn record_request(&self, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_buckets[bucket_index(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a protocol or execution error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an overload rejection (bounded queue full).
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one executed batch of the given size.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Requests completed so far.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    fn fold_into(&self, acc: &mut Merged) {
        acc.requests += self.requests.load(Ordering::Relaxed);
        acc.errors += self.errors.load(Ordering::Relaxed);
        acc.rejected += self.rejected.load(Ordering::Relaxed);
        acc.batches += self.batches.load(Ordering::Relaxed);
        acc.batched_requests += self.batched_requests.load(Ordering::Relaxed);
        acc.latency_sum_us += self.latency_sum_us.load(Ordering::Relaxed);
        for (slot, bucket) in acc.buckets.iter_mut().zip(&self.latency_buckets) {
            *slot += bucket.load(Ordering::Relaxed);
        }
    }
}

/// Map a latency to its log₂ bucket.
fn bucket_index(latency_us: u64) -> usize {
    ((u64::BITS - latency_us.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Upper edge (µs) of a bucket, used as the percentile estimate.
fn bucket_upper(index: usize) -> u64 {
    if index == 0 {
        0
    } else {
        (1u64 << index) - 1
    }
}

#[derive(Default)]
struct Merged {
    requests: u64,
    errors: u64,
    rejected: u64,
    batches: u64,
    batched_requests: u64,
    latency_sum_us: u64,
    buckets: [u64; BUCKETS],
}

impl Merged {
    /// Percentile estimate from the merged histogram (upper bucket edge).
    fn percentile_us(&self, p: f64) -> f64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((total as f64) * p).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &count) in self.buckets.iter().enumerate() {
            seen += count;
            if seen >= rank {
                return bucket_upper(i) as f64;
            }
        }
        bucket_upper(BUCKETS - 1) as f64
    }
}

/// The registry: one [`ShardMetrics`] slot per serving shard.
/// Connection-level events (parse errors, overload rejections) are
/// recorded into the slot of the shard the connection is routed to.
#[derive(Debug)]
pub struct Metrics {
    shards: Vec<Arc<ShardMetrics>>,
    started: Instant,
}

impl Metrics {
    /// Registry with `num_shards` shard slots (at least one).
    pub fn new(num_shards: usize) -> Metrics {
        Metrics {
            shards: (0..num_shards.max(1)).map(|_| Arc::new(ShardMetrics::new())).collect(),
            started: Instant::now(),
        }
    }

    /// Shard `i`'s counters (shared handle).
    pub fn shard(&self, i: usize) -> Arc<ShardMetrics> {
        self.shards[i % self.shards.len()].clone()
    }

    /// Number of shard slots.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Total requests completed across all shards.
    pub fn total_requests(&self) -> u64 {
        self.shards.iter().map(|s| s.requests()).sum()
    }

    /// Snapshot as a JSON line (the `stats` command response), merging all
    /// shards.
    pub fn snapshot_json(&self) -> String {
        let mut m = Merged::default();
        for shard in &self.shards {
            shard.fold_into(&mut m);
        }
        let mean_batch = if m.batches == 0 {
            0.0
        } else {
            m.batched_requests as f64 / m.batches as f64
        };
        let mean_us = if m.requests == 0 {
            0.0
        } else {
            m.latency_sum_us as f64 / m.requests as f64
        };
        let uptime = self.started.elapsed().as_secs_f64();
        let throughput = if uptime > 0.0 {
            m.requests as f64 / uptime
        } else {
            0.0
        };
        let per_shard: Vec<f64> = self.shards.iter().map(|s| s.requests() as f64).collect();
        Json::obj(vec![
            ("requests", Json::Num(m.requests as f64)),
            ("errors", Json::Num(m.errors as f64)),
            ("rejected", Json::Num(m.rejected as f64)),
            ("batches", Json::Num(m.batches as f64)),
            ("mean_batch", Json::Num(mean_batch)),
            ("mean_us", Json::Num(mean_us)),
            ("p50_us", Json::Num(m.percentile_us(0.50))),
            ("p95_us", Json::Num(m.percentile_us(0.95))),
            ("p99_us", Json::Num(m.percentile_us(0.99))),
            ("uptime_s", Json::Num(uptime)),
            ("throughput_rps", Json::Num(throughput)),
            ("shards", Json::Num(self.shards.len() as f64)),
            ("per_shard_requests", Json::nums(&per_shard)),
        ])
        .to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(495), 9);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(9), 511);
    }

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new(2);
        for i in 0..100u64 {
            m.shard((i % 2) as usize).record_request(i * 10);
        }
        m.shard(0).record_batch(8);
        m.shard(1).record_batch(4);
        m.shard(0).record_error();
        m.shard(1).record_rejected();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(100.0));
        assert_eq!(json.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.get("rejected").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.get("mean_batch").unwrap().as_f64(), Some(6.0));
        assert_eq!(json.get("shards").unwrap().as_f64(), Some(2.0));
        // Latencies 0,10,..,990: p50 lands in the [256, 512) µs bucket.
        let p50 = json.get("p50_us").unwrap().as_f64().unwrap();
        assert!((400.0..=600.0).contains(&p50), "p50={p50}");
        let p99 = json.get("p99_us").unwrap().as_f64().unwrap();
        assert!(p99 >= p50, "p99={p99} < p50={p50}");
        let per_shard = json.get("per_shard_requests").unwrap().as_f64_vec().unwrap();
        assert_eq!(per_shard, vec![50.0, 50.0]);
    }

    #[test]
    fn empty_snapshot_is_valid() {
        let m = Metrics::new(4);
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("p95_us").unwrap().as_f64(), Some(0.0));
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(json.get("shards").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn shard_indexing_wraps() {
        let m = Metrics::new(3);
        m.shard(5).record_request(1); // 5 % 3 == 2
        assert_eq!(m.shard(2).requests(), 1);
        assert_eq!(m.total_requests(), 1);
    }
}
