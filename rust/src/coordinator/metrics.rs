//! Serving metrics: per-shard lock-free counters, merged on scrape.
//!
//! The hot path (batch workers, connection threads) only touches its own
//! shard's [`ShardMetrics`] — plain relaxed atomics, no shared lock — so
//! counting never serializes shards against each other. The `stats`
//! command walks every shard and merges counters plus the log₂ latency
//! histograms into one JSON snapshot.
//!
//! Two latency views coexist:
//!
//! * **lifetime** — cumulative log₂ buckets since startup (capacity
//!   planning, long-run drift);
//! * **recent** — rotating wall-clock windows ([`WINDOW_SLOTS`] slots of
//!   [`WINDOW_SECS`] each, ~one minute total), kept *per rounding scheme*
//!   over every registered scheme **and per `(model, k)` cell** over the
//!   bounded fidelity label space ([`MODEL_SLOTS`] × [`MAX_K`]), so
//!   `stats` reports what p50/p99 look like right now for each scheme's
//!   and each configuration's traffic rather than a lifetime aggregate
//!   that stale load shapes dominate.
//!
//! Everything `stats` knows is also rendered as Prometheus text
//! exposition by [`Metrics::prometheus`] (the `{"cmd":"metrics"}` verb),
//! including the request tracer's per-stage span-duration histograms.

//! The registry also owns each shard's fidelity estimators
//! ([`FidelityShard`]): the engine's shadow path writes into them on the
//! shard worker thread, and `stats` merges every shard's
//! `(model, scheme, k)` Welford cells into the `fidelity` block.

use crate::fidelity::{
    AutoSnapshot, EstimateTable, FidelityEstimate, FidelityShard, LatencyView, MAX_K, MODEL_SLOTS,
};
use crate::rounding::SchemeId;
use crate::trace::{PromText, Tracer};
use crate::train::ModelSpec;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Number of log₂ latency buckets: bucket `i` holds latencies in
/// `[2^(i-1), 2^i)` µs (bucket 0 is exactly 0 µs). 2^38 µs ≈ 3 days, far
/// beyond any request timeout. Public because the raw bucket counts go over
/// the wire in `stats` replies, where the cluster proxy re-merges them.
pub const BUCKETS: usize = 40;

/// Width of one rotating latency window.
const WINDOW_SECS: u64 = 10;

/// Number of rotating windows kept live (total span ≈ one minute).
const WINDOW_SLOTS: usize = 6;

/// One rotating slot: a histogram stamped with the epoch it belongs to.
/// Writers of a new epoch zero the slot *before* publishing the epoch
/// stamp with `Release` (readers `Acquire` it), so a concurrent scrape
/// sees either the (excluded) stale epoch or an already-reset histogram —
/// aged-out data can never be read back as current. Writers racing the
/// reset can lose a handful of counts at a window boundary, which is
/// acceptable for approximate recent-latency metrics (no lock on the hot
/// path).
struct WindowSlot {
    /// Epoch stamp (0 = never written; live epochs start at 1).
    epoch: AtomicU64,
    count: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl WindowSlot {
    fn new() -> WindowSlot {
        WindowSlot {
            epoch: AtomicU64::new(0),
            count: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Rotating wall-clock latency windows for one rounding scheme.
struct SchemeWindows {
    slots: [WindowSlot; WINDOW_SLOTS],
}

impl SchemeWindows {
    fn new() -> SchemeWindows {
        SchemeWindows {
            slots: std::array::from_fn(|_| WindowSlot::new()),
        }
    }

    /// Record one latency into the window for `epoch`.
    fn record(&self, epoch: u64, latency_us: u64) {
        let slot = &self.slots[(epoch % WINDOW_SLOTS as u64) as usize];
        if slot.epoch.load(Ordering::Relaxed) != epoch {
            // Zero first, then publish the new epoch (`Release`, paired
            // with the `Acquire` load in `fold_recent`): until the store
            // the slot still carries its stale (excluded) stamp, and a
            // scrape that observes the new stamp is guaranteed to see the
            // zeroed histogram — aged-out buckets never fold as current.
            for b in &slot.buckets {
                b.store(0, Ordering::Relaxed);
            }
            slot.count.store(0, Ordering::Relaxed);
            slot.epoch.store(epoch, Ordering::Release);
        }
        slot.count.fetch_add(1, Ordering::Relaxed);
        slot.buckets[bucket_index(latency_us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Merge every slot still inside the window (relative to `now_epoch`)
    /// into `count` + `buckets`.
    fn fold_recent(&self, now_epoch: u64, count: &mut u64, buckets: &mut [u64; BUCKETS]) {
        for slot in &self.slots {
            let e = slot.epoch.load(Ordering::Acquire);
            if e != 0 && now_epoch.saturating_sub(e) < WINDOW_SLOTS as u64 {
                *count += slot.count.load(Ordering::Relaxed);
                for (acc, b) in buckets.iter_mut().zip(&slot.buckets) {
                    *acc += b.load(Ordering::Relaxed);
                }
            }
        }
    }
}

/// Scheme order used for the `recent` and `fidelity` stats sections:
/// every registered scheme, in registry slot order ([`SchemeId::slot`]
/// doubles as the index into the per-scheme window arrays).
const SCHEME_ORDER: [SchemeId; SchemeId::COUNT] = SchemeId::ALL;

/// One shard's counters. All operations are relaxed atomics.
#[derive(Debug)]
pub struct ShardMetrics {
    requests: AtomicU64,
    errors: AtomicU64,
    rejected: AtomicU64,
    timeouts: AtomicU64,
    deprecated_fields: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    writer_flushes: AtomicU64,
    writer_flushed_lines: AtomicU64,
    /// Requests whose `(model, k)` label fell outside the bounded recent
    /// window space (model slot ≥ [`MODEL_SLOTS`] or `k` out of range) —
    /// counted instead of silently dropped, because every dropped sample
    /// starves measured-cost auto resolution of signal.
    recent_dropped: AtomicU64,
    /// Auto requests that carried a latency budget (`max_latency_us`).
    auto_slo_requests: AtomicU64,
    /// Auto requests resolved from live measurements (a warm MSE cell or
    /// a warm latency window) rather than priors and static order alone.
    auto_measured: AtomicU64,
    /// Auto batches whose declared budgets no candidate could satisfy —
    /// the controller served the least-bad fallback. The SLO evaluator
    /// turns movement here into `auto_infeasible` journal events.
    auto_infeasible: AtomicU64,
    latency_sum_us: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
    started: Instant,
    windows: [SchemeWindows; SchemeId::COUNT],
    /// Rotating windows per `(model, k)` cell over the bounded fidelity
    /// label space, indexed `model_slot * MAX_K + (k - 1)`.
    model_k_windows: Vec<SchemeWindows>,
    /// Shadow-sampling error estimators, written by this shard's engine.
    fidelity: Arc<FidelityShard>,
}

impl std::fmt::Debug for SchemeWindows {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("SchemeWindows")
    }
}

impl Default for ShardMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardMetrics {
    /// Fresh zeroed counters.
    pub fn new() -> ShardMetrics {
        ShardMetrics {
            requests: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            deprecated_fields: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            writer_flushes: AtomicU64::new(0),
            writer_flushed_lines: AtomicU64::new(0),
            recent_dropped: AtomicU64::new(0),
            auto_slo_requests: AtomicU64::new(0),
            auto_measured: AtomicU64::new(0),
            auto_infeasible: AtomicU64::new(0),
            latency_sum_us: AtomicU64::new(0),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            started: Instant::now(),
            windows: std::array::from_fn(|_| SchemeWindows::new()),
            model_k_windows: (0..MODEL_SLOTS * MAX_K as usize)
                .map(|_| SchemeWindows::new())
                .collect(),
            fidelity: Arc::new(FidelityShard::new()),
        }
    }

    /// This shard's fidelity estimators. The shard pool hands the same
    /// handle to the shard's engine (the writer); `stats` scrapes and the
    /// auto-precision controller read it.
    pub fn fidelity(&self) -> &Arc<FidelityShard> {
        &self.fidelity
    }

    /// The current rotating-window epoch (1-based; 0 marks unused slots).
    fn current_epoch(&self) -> u64 {
        self.started.elapsed().as_secs() / WINDOW_SECS + 1
    }

    /// Record one completed request — its scheme, the `(model, k)`
    /// configuration that served it, and its end-to-end latency.
    /// `model_slot` is [`ModelSpec::index`]; an out-of-range slot or `k`
    /// still counts toward the totals and the scheme window, it just
    /// skips the per-configuration cell — and bumps `recent_dropped`, so
    /// a zoo larger than [`MODEL_SLOTS`] starving measured-cost auto
    /// resolution is visible instead of silent.
    ///
    /// The wall-clock epoch also drives the fidelity estimator's
    /// freshness rotation: the same cadence that ages latency windows out
    /// ages shadow-error cells out.
    pub fn record_request(&self, mode: SchemeId, model_slot: usize, k: u32, latency_us: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        self.latency_buckets[bucket_index(latency_us)].fetch_add(1, Ordering::Relaxed);
        let epoch = self.current_epoch();
        self.fidelity.advance_epoch(epoch);
        self.windows[mode.slot()].record(epoch, latency_us);
        if model_slot < MODEL_SLOTS && (1..=MAX_K).contains(&k) {
            self.model_k_windows[model_slot * MAX_K as usize + (k as usize - 1)]
                .record(epoch, latency_us);
        } else {
            self.recent_dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one resolved auto batch: `slo_members` of its members
    /// carried a latency budget, and `measured_members` counts the
    /// members whose choice was backed by live measurements
    /// ([`crate::fidelity::AutoChoice::any_measured`]).
    pub fn record_auto_resolution(&self, slo_members: u64, measured_members: u64) {
        self.auto_slo_requests.fetch_add(slo_members, Ordering::Relaxed);
        self.auto_measured.fetch_add(measured_members, Ordering::Relaxed);
    }

    /// Record one auto batch resolved against budgets no candidate could
    /// satisfy ([`crate::fidelity::AutoChoice::feasible`] was false).
    pub fn record_auto_infeasible(&self) {
        self.auto_infeasible.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a protocol or execution error.
    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an overload rejection (bounded queue full).
    pub fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a watchdog-answered reply (an accepted request whose engine
    /// call outlived the reply deadline).
    pub fn record_timeout(&self) {
        self.timeouts.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request that used a deprecated wire field (currently only
    /// the `"mode"` alias for `"scheme"`), so operators can find clients
    /// to migrate before the alias is removed.
    pub fn record_deprecated_field(&self) {
        self.deprecated_fields.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one writer-side coalesced flush that delivered `lines`
    /// reply lines in a single syscall.
    pub fn record_flush(&self, lines: usize) {
        self.writer_flushes.fetch_add(1, Ordering::Relaxed);
        self.writer_flushed_lines.fetch_add(lines as u64, Ordering::Relaxed);
    }

    /// Record one executed batch of the given size.
    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests.fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Requests completed so far.
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    fn fold_into(&self, acc: &mut Merged) {
        acc.requests += self.requests.load(Ordering::Relaxed);
        acc.errors += self.errors.load(Ordering::Relaxed);
        acc.rejected += self.rejected.load(Ordering::Relaxed);
        acc.timeouts += self.timeouts.load(Ordering::Relaxed);
        acc.deprecated_fields += self.deprecated_fields.load(Ordering::Relaxed);
        acc.batches += self.batches.load(Ordering::Relaxed);
        acc.batched_requests += self.batched_requests.load(Ordering::Relaxed);
        acc.writer_flushes += self.writer_flushes.load(Ordering::Relaxed);
        acc.writer_flushed_lines += self.writer_flushed_lines.load(Ordering::Relaxed);
        acc.recent_dropped += self.recent_dropped.load(Ordering::Relaxed);
        acc.auto_slo_requests += self.auto_slo_requests.load(Ordering::Relaxed);
        acc.auto_measured += self.auto_measured.load(Ordering::Relaxed);
        acc.auto_infeasible += self.auto_infeasible.load(Ordering::Relaxed);
        acc.latency_sum_us += self.latency_sum_us.load(Ordering::Relaxed);
        for (slot, bucket) in acc.buckets.iter_mut().zip(&self.latency_buckets) {
            *slot += bucket.load(Ordering::Relaxed);
        }
        let epoch = self.current_epoch();
        for (mode, (count, buckets)) in SCHEME_ORDER.iter().zip(acc.recent.iter_mut()) {
            self.windows[mode.slot()].fold_recent(epoch, count, buckets);
        }
        for (w, (count, buckets)) in self.model_k_windows.iter().zip(acc.recent_model_k.iter_mut())
        {
            w.fold_recent(epoch, count, buckets);
        }
    }
}

/// Map a latency to its log₂ bucket. Public because the request tracer's
/// per-stage duration histograms share this bucketing, so one exposition
/// surface serves both.
pub fn bucket_index(latency_us: u64) -> usize {
    ((u64::BITS - latency_us.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Upper edge (µs) of a bucket, used as the percentile estimate.
pub fn bucket_upper(index: usize) -> u64 {
    if index == 0 {
        0
    } else {
        (1u64 << index.min(BUCKETS - 1)) - 1
    }
}

/// Percentile estimate from a log₂ histogram (upper bucket edge). Takes any
/// bucket slice so wire-parsed histograms (whose length is whatever the
/// backend sent) merge without fixed-size conversion.
///
/// Degenerate inputs answer 0 rather than garbage: an empty slice or a
/// zero total has no percentile, and a junk `p` (NaN / out of `0..=1`)
/// is clamped before ranking, so the answer always names a bucket that
/// actually holds mass.
pub fn percentile_from_buckets(buckets: &[u64], p: f64) -> f64 {
    let total: u64 = buckets.iter().sum();
    if buckets.is_empty() || total == 0 {
        return 0.0;
    }
    let p = if p.is_finite() { p.clamp(0.0, 1.0) } else { 0.0 };
    let rank = ((total as f64) * p).ceil().max(1.0) as u64;
    let mut seen = 0u64;
    for (i, &count) in buckets.iter().enumerate() {
        seen += count;
        if seen >= rank {
            return bucket_upper(i) as f64;
        }
    }
    // Unreachable once p is clamped (rank <= total), but cap at the
    // slice's own last bucket rather than BUCKETS-1 so a short wire
    // histogram can never answer beyond its own range.
    bucket_upper(buckets.len() - 1) as f64
}

/// Bucket counts as a JSON array of numbers.
fn buckets_json(buckets: &[u64]) -> Json {
    Json::Arr(buckets.iter().map(|&b| Json::Num(b as f64)).collect())
}

/// One `stats.recent` cell: count, window percentiles, raw buckets.
fn recent_cell_json(count: u64, buckets: &[u64]) -> Json {
    Json::obj(vec![
        ("requests", Json::Num(count as f64)),
        ("p50_us", Json::Num(percentile_from_buckets(buckets, 0.50))),
        ("p99_us", Json::Num(percentile_from_buckets(buckets, 0.99))),
        // Raw window buckets: the cluster proxy sums these across
        // backends for true cluster percentiles.
        ("buckets", buckets_json(buckets)),
    ])
}

/// Total duration implied by a log₂ histogram, using upper bucket edges
/// (a deliberate overestimate; windows keep no exact sum). Exposition
/// `_sum` samples for window histograms use this — the cluster proxy's
/// merged exposition included.
pub(crate) fn approx_sum_us(buckets: &[u64]) -> f64 {
    buckets
        .iter()
        .enumerate()
        .map(|(i, &c)| c as f64 * bucket_upper(i) as f64)
        .sum()
}

struct Merged {
    requests: u64,
    errors: u64,
    rejected: u64,
    timeouts: u64,
    deprecated_fields: u64,
    batches: u64,
    batched_requests: u64,
    writer_flushes: u64,
    writer_flushed_lines: u64,
    recent_dropped: u64,
    auto_slo_requests: u64,
    auto_measured: u64,
    auto_infeasible: u64,
    latency_sum_us: u64,
    buckets: [u64; BUCKETS],
    /// Recent-window (count, buckets) per scheme, in [`SCHEME_ORDER`].
    recent: [(u64, [u64; BUCKETS]); SchemeId::COUNT],
    /// Recent-window (count, buckets) per `(model, k)` cell, indexed
    /// `model_slot * MAX_K + (k - 1)`.
    recent_model_k: Vec<(u64, [u64; BUCKETS])>,
}

// Manual impl: `Default` is not derivable for arrays longer than 32.
impl Default for Merged {
    fn default() -> Merged {
        Merged {
            requests: 0,
            errors: 0,
            rejected: 0,
            timeouts: 0,
            deprecated_fields: 0,
            batches: 0,
            batched_requests: 0,
            writer_flushes: 0,
            writer_flushed_lines: 0,
            recent_dropped: 0,
            auto_slo_requests: 0,
            auto_measured: 0,
            auto_infeasible: 0,
            latency_sum_us: 0,
            buckets: [0; BUCKETS],
            recent: [(0, [0; BUCKETS]); SchemeId::COUNT],
            recent_model_k: vec![(0, [0; BUCKETS]); MODEL_SLOTS * MAX_K as usize],
        }
    }
}

impl Merged {
    /// Percentile estimate from the merged lifetime histogram.
    fn percentile_us(&self, p: f64) -> f64 {
        percentile_from_buckets(&self.buckets, p)
    }
}

/// The registry: one [`ShardMetrics`] slot per serving shard.
/// Connection-level events (parse errors, overload rejections) are
/// recorded into the slot of the shard the connection is routed to.
#[derive(Debug)]
pub struct Metrics {
    shards: Vec<Arc<ShardMetrics>>,
    started: Instant,
    /// Wall-clock start (unix seconds), echoed in `stats` so operators
    /// and the cluster proxy can tell restarts from counter resets.
    start_unix: u64,
}

impl Metrics {
    /// Registry with `num_shards` shard slots (at least one).
    pub fn new(num_shards: usize) -> Metrics {
        Metrics {
            shards: (0..num_shards.max(1)).map(|_| Arc::new(ShardMetrics::new())).collect(),
            started: Instant::now(),
            start_unix: std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
        }
    }

    /// Wall-clock process start (unix seconds).
    pub fn start_unix(&self) -> u64 {
        self.start_unix
    }

    /// Shard `i`'s counters (shared handle).
    pub fn shard(&self, i: usize) -> Arc<ShardMetrics> {
        self.shards[i % self.shards.len()].clone()
    }

    /// Number of shard slots.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// A cheap clone-able handle onto the shard slots, for readers that
    /// outlive the borrow — the shard pool's auto-snapshot refresher.
    pub fn handle(&self) -> MetricsHandle {
        MetricsHandle {
            shards: self.shards.clone(),
        }
    }

    /// Total requests completed across all shards.
    pub fn total_requests(&self) -> u64 {
        self.shards.iter().map(|s| s.requests()).sum()
    }

    /// Merge every shard's counters and windows.
    fn merged(&self) -> Merged {
        let mut m = Merged::default();
        for shard in &self.shards {
            shard.fold_into(&mut m);
        }
        m
    }

    /// Merge every shard's fidelity estimators; only observed
    /// `(model, scheme, k)` cells are returned (the label space is
    /// bounded, but an empty cell says nothing an operator needs).
    fn fidelity_cells(&self) -> Vec<(ModelSpec, SchemeId, u32, FidelityEstimate)> {
        let mut cells = Vec::new();
        for spec in ModelSpec::ALL {
            for k in 1..=MAX_K {
                for mode in SCHEME_ORDER {
                    let mut est = FidelityEstimate::default();
                    for shard in &self.shards {
                        est.merge(&shard.fidelity().estimate(spec.index(), mode, k));
                    }
                    if est.samples > 0 {
                        cells.push((spec, mode, k, est));
                    }
                }
            }
        }
        cells
    }

    /// The merged recent-window cells keyed as the `stats.recent` object:
    /// one `"<scheme>"` entry per registered scheme, plus one
    /// `"<model>/k=<K>"` entry per `(model, k)` cell that saw traffic.
    fn recent_cells(m: &Merged) -> BTreeMap<String, (u64, [u64; BUCKETS])> {
        let mut cells = BTreeMap::new();
        for (mode, (count, buckets)) in SCHEME_ORDER.iter().zip(&m.recent) {
            cells.insert(mode.wire_name().to_string(), (*count, *buckets));
        }
        for (slot, spec) in ModelSpec::ALL.into_iter().enumerate() {
            for k in 1..=MAX_K {
                let (count, buckets) = m.recent_model_k[slot * MAX_K as usize + (k as usize - 1)];
                if count > 0 {
                    cells.insert(format!("{}/k={k}", spec.name()), (count, buckets));
                }
            }
        }
        cells
    }

    /// Snapshot as a JSON line (the `stats` command response), merging all
    /// shards. Includes the recent per-scheme and per-`(model, k)`
    /// rotating-window percentiles alongside the lifetime histogram.
    pub fn snapshot_json(&self) -> String {
        let m = self.merged();
        let mean_batch = if m.batches == 0 {
            0.0
        } else {
            m.batched_requests as f64 / m.batches as f64
        };
        let mean_us = if m.requests == 0 {
            0.0
        } else {
            m.latency_sum_us as f64 / m.requests as f64
        };
        let uptime = self.started.elapsed().as_secs_f64();
        let throughput = if uptime > 0.0 {
            m.requests as f64 / uptime
        } else {
            0.0
        };
        let per_shard: Vec<f64> = self.shards.iter().map(|s| s.requests() as f64).collect();
        let fidelity: Vec<Json> = self
            .fidelity_cells()
            .into_iter()
            .map(|(spec, mode, k, est)| {
                Json::obj(vec![
                    ("model", Json::Str(spec.name().to_string())),
                    ("scheme", Json::Str(mode.to_string())),
                    ("k", Json::Num(f64::from(k))),
                    ("samples", Json::Num(est.samples as f64)),
                    ("bias", Json::Num(est.bias)),
                    ("mse", Json::Num(est.mse())),
                    ("variance", Json::Num(est.variance())),
                ])
            })
            .collect();
        let recent: BTreeMap<String, Json> = Self::recent_cells(&m)
            .into_iter()
            .map(|(key, (count, buckets))| (key, recent_cell_json(count, &buckets)))
            .collect();
        Json::obj(vec![
            ("kernel", Json::Str(crate::kernels::active_id().name().to_string())),
            ("requests", Json::Num(m.requests as f64)),
            ("errors", Json::Num(m.errors as f64)),
            ("rejected", Json::Num(m.rejected as f64)),
            ("timeouts", Json::Num(m.timeouts as f64)),
            ("deprecated_fields", Json::Num(m.deprecated_fields as f64)),
            ("batches", Json::Num(m.batches as f64)),
            ("writer_flushes", Json::Num(m.writer_flushes as f64)),
            ("writer_flushed_lines", Json::Num(m.writer_flushed_lines as f64)),
            ("recent_dropped", Json::Num(m.recent_dropped as f64)),
            ("auto_slo_requests", Json::Num(m.auto_slo_requests as f64)),
            ("auto_measured", Json::Num(m.auto_measured as f64)),
            ("auto_infeasible", Json::Num(m.auto_infeasible as f64)),
            ("start_time", Json::Num(self.start_unix as f64)),
            ("mean_batch", Json::Num(mean_batch)),
            ("mean_us", Json::Num(mean_us)),
            ("p50_us", Json::Num(m.percentile_us(0.50))),
            ("p95_us", Json::Num(m.percentile_us(0.95))),
            ("p99_us", Json::Num(m.percentile_us(0.99))),
            // Raw lifetime log₂ buckets (bucket i = [2^(i-1), 2^i) µs).
            ("latency_buckets", buckets_json(&m.buckets)),
            ("recent_window_s", Json::Num((WINDOW_SECS * WINDOW_SLOTS as u64) as f64)),
            ("recent", Json::Obj(recent)),
            ("fidelity", Json::Arr(fidelity)),
            ("uptime_s", Json::Num(uptime)),
            ("throughput_rps", Json::Num(throughput)),
            ("shards", Json::Num(self.shards.len() as f64)),
            ("per_shard_requests", Json::nums(&per_shard)),
        ])
        .to_string()
    }

    /// Prometheus text exposition (the `{"cmd":"metrics"}` verb): every
    /// counter `stats` reports, the lifetime and recent-window latency
    /// histograms, fidelity gauges per observed `(model, scheme, k)`,
    /// the tracer's own counters, and the per-stage span-duration
    /// histograms.
    pub fn prometheus(&self, tracer: &Tracer) -> String {
        let m = self.merged();
        let mut p = PromText::new();
        p.scalar(
            "dither_requests_total",
            "counter",
            "Completed requests",
            m.requests as f64,
        );
        p.scalar(
            "dither_errors_total",
            "counter",
            "Protocol and execution errors",
            m.errors as f64,
        );
        p.scalar(
            "dither_rejected_total",
            "counter",
            "Overload rejections",
            m.rejected as f64,
        );
        p.scalar(
            "dither_timeouts_total",
            "counter",
            "Watchdog-answered requests",
            m.timeouts as f64,
        );
        p.scalar(
            "dither_deprecated_fields_total",
            "counter",
            "Requests using deprecated wire fields",
            m.deprecated_fields as f64,
        );
        p.scalar(
            "dither_batches_total",
            "counter",
            "Executed batches",
            m.batches as f64,
        );
        p.scalar(
            "dither_batched_requests_total",
            "counter",
            "Requests served inside batches",
            m.batched_requests as f64,
        );
        p.scalar(
            "dither_writer_flushes_total",
            "counter",
            "Writer-side coalesced flushes",
            m.writer_flushes as f64,
        );
        p.scalar(
            "dither_writer_flushed_lines_total",
            "counter",
            "Reply lines delivered across coalesced flushes",
            m.writer_flushed_lines as f64,
        );
        p.scalar(
            "dither_recent_dropped_total",
            "counter",
            "Latency samples outside the bounded (model, k) window space",
            m.recent_dropped as f64,
        );
        p.scalar(
            "dither_auto_slo_requests_total",
            "counter",
            "Auto requests carrying a max_latency_us budget",
            m.auto_slo_requests as f64,
        );
        p.scalar(
            "dither_auto_measured_total",
            "counter",
            "Auto requests resolved from live measurements",
            m.auto_measured as f64,
        );
        p.scalar(
            "dither_auto_infeasible_total",
            "counter",
            "Auto batches resolved against infeasible budgets",
            m.auto_infeasible as f64,
        );
        p.scalar(
            "dither_uptime_seconds",
            "gauge",
            "Process uptime",
            self.started.elapsed().as_secs_f64(),
        );
        p.scalar(
            "dither_shards",
            "gauge",
            "Serving shards in the process",
            self.shards.len() as f64,
        );
        p.family(
            "dither_kernel_info",
            "gauge",
            "Active compute kernel (value is always 1)",
        );
        p.sample(
            "dither_kernel_info",
            &[("kernel", crate::kernels::active_id().name())],
            1.0,
        );
        p.family(
            "dither_shard_requests_total",
            "counter",
            "Completed requests per shard",
        );
        for (i, shard) in self.shards.iter().enumerate() {
            let label = i.to_string();
            p.sample(
                "dither_shard_requests_total",
                &[("shard", &label)],
                shard.requests() as f64,
            );
        }
        p.family(
            "dither_latency_us",
            "histogram",
            "Lifetime end-to-end request latency",
        );
        p.histogram_series(
            "dither_latency_us",
            &[],
            &m.buckets,
            m.latency_sum_us as f64,
            bucket_upper,
        );
        // One labeled series per recent-window cell that saw traffic —
        // scheme cells as {scheme="..."}, (model, k) cells split back
        // into {model="...",k="..."}.
        let recent = Self::recent_cells(&m);
        if recent.values().any(|(count, _)| *count > 0) {
            p.family(
                "dither_recent_latency_us",
                "histogram",
                "Rotating-window request latency per scheme and per (model, k)",
            );
            for (key, (count, buckets)) in &recent {
                if *count == 0 {
                    continue;
                }
                match key.split_once("/k=") {
                    Some((model, k)) => p.histogram_series(
                        "dither_recent_latency_us",
                        &[("model", model), ("k", k)],
                        buckets,
                        approx_sum_us(buckets),
                        bucket_upper,
                    ),
                    None => p.histogram_series(
                        "dither_recent_latency_us",
                        &[("scheme", key)],
                        buckets,
                        approx_sum_us(buckets),
                        bucket_upper,
                    ),
                }
            }
        }
        let fidelity = self.fidelity_cells();
        if !fidelity.is_empty() {
            let families: [(&str, &str, fn(&FidelityEstimate) -> f64); 3] = [
                (
                    "dither_fidelity_samples",
                    "Shadow samples per (model, scheme, k)",
                    |est| est.samples as f64,
                ),
                (
                    "dither_fidelity_bias",
                    "Mean signed logit error per (model, scheme, k)",
                    |est| est.bias,
                ),
                (
                    "dither_fidelity_mse",
                    "Mean squared logit error per (model, scheme, k)",
                    FidelityEstimate::mse,
                ),
            ];
            for (name, help, value) in families {
                p.family(name, "gauge", help);
                for (spec, mode, k, est) in &fidelity {
                    let k_label = k.to_string();
                    p.sample(
                        name,
                        &[
                            ("model", spec.name()),
                            ("scheme", mode.wire_name()),
                            ("k", &k_label),
                        ],
                        value(est),
                    );
                }
            }
        }
        p.scalar(
            "dither_traces_begun_total",
            "counter",
            "Trace contexts handed out (sampled + speculative)",
            tracer.begun() as f64,
        );
        p.scalar(
            "dither_traces_committed_total",
            "counter",
            "Traces committed to the ring buffer",
            tracer.committed() as f64,
        );
        p.scalar(
            "dither_traces_slow_total",
            "counter",
            "Traces promoted by the slow threshold",
            tracer.slow_promoted() as f64,
        );
        p.scalar(
            "dither_traces_evicted_total",
            "counter",
            "Traces evicted from the full ring buffer",
            tracer.evicted() as f64,
        );
        p.scalar(
            "dither_traces_resident",
            "gauge",
            "Completed traces resident in the ring buffer",
            tracer.resident() as f64,
        );
        p.stage_histograms(&tracer.stage_snapshots());
        p.finish()
    }
}

/// The `MetricsHandle → LatencyView` seam the SLO controller reads
/// through: a clone-able handle over every shard's counters that can fold
/// the live fidelity estimators and recent latency windows into one
/// merged, immutable [`AutoSnapshot`]. The shard pool refreshes one
/// snapshot per process on a short cadence and publishes it via
/// [`crate::fidelity::AutoView`], so all shards resolve auto requests
/// against the same replayable view.
#[derive(Clone, Debug)]
pub struct MetricsHandle {
    shards: Vec<Arc<ShardMetrics>>,
}

impl MetricsHandle {
    /// Fold every shard's state into one [`AutoSnapshot`]: merged
    /// `(model, scheme, k)` Welford cells, plus a `(samples, p50)`
    /// recent-latency surface per `(model, k)` window and per scheme
    /// window (each shard folded at its own current epoch, so aged-out
    /// slots are excluded exactly as in `stats`).
    pub fn auto_snapshot(&self) -> AutoSnapshot {
        let mut estimates = EstimateTable::empty();
        for shard in &self.shards {
            estimates.merge_shard(shard.fidelity());
        }
        let mut latency = LatencyView::empty();
        for model in 0..MODEL_SLOTS {
            for k in 1..=MAX_K {
                let i = model * MAX_K as usize + (k as usize - 1);
                let mut count = 0u64;
                let mut buckets = [0u64; BUCKETS];
                for shard in &self.shards {
                    shard.model_k_windows[i].fold_recent(
                        shard.current_epoch(),
                        &mut count,
                        &mut buckets,
                    );
                }
                if count > 0 {
                    latency.set_model_k(
                        model,
                        k,
                        count,
                        percentile_from_buckets(&buckets, 0.50) as u64,
                    );
                }
            }
        }
        for mode in SCHEME_ORDER {
            let mut count = 0u64;
            let mut buckets = [0u64; BUCKETS];
            for shard in &self.shards {
                shard.windows[mode.slot()].fold_recent(
                    shard.current_epoch(),
                    &mut count,
                    &mut buckets,
                );
            }
            if count > 0 {
                latency.set_scheme(mode, count, percentile_from_buckets(&buckets, 0.50) as u64);
            }
        }
        AutoSnapshot { estimates, latency }
    }

    /// Fold the lifetime counters the SLO evaluator differences tick to
    /// tick. Tracer and plan-cache counters live elsewhere; the caller
    /// (the shard pool's evaluator thread) fills `slow_promoted` and
    /// `plan_evictions` before handing the sample over.
    pub fn slo_sample(&self) -> crate::obs::SloSample {
        let mut s = crate::obs::SloSample {
            latency_buckets: vec![0u64; BUCKETS],
            ..crate::obs::SloSample::default()
        };
        for shard in &self.shards {
            s.requests += shard.requests.load(Ordering::Relaxed);
            s.errors += shard.errors.load(Ordering::Relaxed);
            s.rejected += shard.rejected.load(Ordering::Relaxed);
            s.timeouts += shard.timeouts.load(Ordering::Relaxed);
            s.auto_infeasible += shard.auto_infeasible.load(Ordering::Relaxed);
            for (acc, b) in s.latency_buckets.iter_mut().zip(&shard.latency_buckets) {
                *acc += b.load(Ordering::Relaxed);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(495), 9);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(9), 511);
    }

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new(2);
        for i in 0..100u64 {
            m.shard((i % 2) as usize).record_request(SchemeId::Dither, 0, 4, i * 10);
        }
        m.shard(0).record_batch(8);
        m.shard(1).record_batch(4);
        m.shard(0).record_error();
        m.shard(1).record_rejected();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(100.0));
        assert_eq!(json.get("errors").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.get("rejected").unwrap().as_f64(), Some(1.0));
        assert_eq!(json.get("mean_batch").unwrap().as_f64(), Some(6.0));
        assert_eq!(json.get("shards").unwrap().as_f64(), Some(2.0));
        // Latencies 0,10,..,990: p50 lands in the [256, 512) µs bucket.
        let p50 = json.get("p50_us").unwrap().as_f64().unwrap();
        assert!((400.0..=600.0).contains(&p50), "p50={p50}");
        let p99 = json.get("p99_us").unwrap().as_f64().unwrap();
        assert!(p99 >= p50, "p99={p99} < p50={p50}");
        let per_shard = json.get("per_shard_requests").unwrap().as_f64_vec().unwrap();
        assert_eq!(per_shard, vec![50.0, 50.0]);
    }

    #[test]
    fn recent_section_is_per_scheme() {
        let m = Metrics::new(2);
        for _ in 0..40 {
            m.shard(0).record_request(SchemeId::Dither, 0, 4, 100);
        }
        m.shard(1).record_request(SchemeId::Deterministic, 1, 8, 1_000_000);
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("recent_window_s").unwrap().as_f64(), Some(60.0));
        let recent = json.get("recent").expect("recent section");
        let dither = recent.get("dither").expect("dither entry");
        assert_eq!(dither.get("requests").unwrap().as_f64(), Some(40.0));
        let dit_p99 = dither.get("p99_us").unwrap().as_f64().unwrap();
        assert!(dit_p99 < 1000.0, "dither p99={dit_p99}");
        let det = recent.get("deterministic").expect("deterministic entry");
        assert_eq!(det.get("requests").unwrap().as_f64(), Some(1.0));
        let det_p99 = det.get("p99_us").unwrap().as_f64().unwrap();
        assert!(det_p99 >= 1_000_000.0 / 2.0, "det p99={det_p99}");
        // A scheme with no recent traffic reports empty percentiles.
        let sto = recent.get("stochastic").expect("stochastic entry");
        assert_eq!(sto.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(sto.get("p99_us").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn snapshot_carries_kernel_and_raw_buckets() {
        let m = Metrics::new(2);
        for i in 0..30u64 {
            m.shard((i % 2) as usize).record_request(SchemeId::Dither, 0, 4, i * 50);
        }
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        let kernel = json.get("kernel").unwrap().as_str().unwrap();
        assert_eq!(kernel, crate::kernels::active_id().name());
        let buckets = json.get("latency_buckets").unwrap().as_f64_vec().unwrap();
        assert_eq!(buckets.len(), BUCKETS);
        assert_eq!(buckets.iter().sum::<f64>(), 30.0, "bucket mass == requests");
        // Recomputing the percentile from the wire buckets reproduces the
        // reported one — the proxy-side merge depends on this round trip.
        let wire: Vec<u64> = buckets.iter().map(|&b| b as u64).collect();
        assert_eq!(
            json.get("p99_us").unwrap().as_f64().unwrap(),
            percentile_from_buckets(&wire, 0.99)
        );
        let dither = json.get("recent").unwrap().get("dither").expect("dither entry");
        let recent_buckets = dither.get("buckets").unwrap().as_f64_vec().unwrap();
        assert_eq!(recent_buckets.len(), BUCKETS);
        assert_eq!(recent_buckets.iter().sum::<f64>(), 30.0);
    }

    #[test]
    fn windows_rotate_out_old_epochs() {
        let w = SchemeWindows::new();
        w.record(1, 100);
        w.record(1, 200);
        let mut count = 0u64;
        let mut buckets = [0u64; BUCKETS];
        w.fold_recent(1, &mut count, &mut buckets);
        assert_eq!(count, 2);
        // Still visible near the end of the window span...
        count = 0;
        buckets = [0; BUCKETS];
        w.fold_recent(WINDOW_SLOTS as u64, &mut count, &mut buckets);
        assert_eq!(count, 2);
        // ...aged out once the window has fully rotated past it.
        count = 0;
        buckets = [0; BUCKETS];
        w.fold_recent(1 + WINDOW_SLOTS as u64, &mut count, &mut buckets);
        assert_eq!(count, 0);
        // Reusing the slot for a new epoch resets the stale histogram.
        w.record(1 + WINDOW_SLOTS as u64, 50);
        count = 0;
        buckets = [0; BUCKETS];
        w.fold_recent(1 + WINDOW_SLOTS as u64, &mut count, &mut buckets);
        assert_eq!(count, 1);
        assert_eq!(buckets[bucket_index(50)], 1);
        assert_eq!(buckets[bucket_index(100)], 0, "old epoch data must be gone");
    }

    #[test]
    fn windows_fold_monotonically_within_one_epoch() {
        // Record-vs-fold determinism inside a single epoch: every record
        // raises the folded count by exactly one, folds with no writes in
        // between are identical, and a pseudo-random record sequence over
        // several epochs never makes a fold go backwards while the epoch
        // stands still.
        let w = SchemeWindows::new();
        let mut state = 0x2545_F491_4F6C_DD1Du64;
        let mut rng = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for epoch in 1..=3 * WINDOW_SLOTS as u64 {
            let mut prev = 0u64;
            for _ in 0..(rng() % 32) {
                w.record(epoch, rng() % 100_000);
                let mut count = 0u64;
                let mut buckets = [0u64; BUCKETS];
                w.fold_recent(epoch, &mut count, &mut buckets);
                assert!(count > prev, "fold went backwards within epoch {epoch}");
                assert_eq!(buckets.iter().sum::<u64>(), count, "bucket mass == count");
                let mut again = 0u64;
                let mut b2 = [0u64; BUCKETS];
                w.fold_recent(epoch, &mut again, &mut b2);
                assert_eq!((again, b2), (count, buckets), "idle folds must agree");
                prev = count;
            }
        }
    }

    #[test]
    fn concurrent_epoch_rotation_never_folds_aged_buckets() {
        // The zero-then-publish discipline under a live writer: a scrape
        // folding at epoch E must never see a bucket that only an aged-out
        // epoch (≤ E − WINDOW_SLOTS) could have written. Each epoch
        // records a latency that lands in a bucket unique within a cycle
        // longer than the whole window span, so any cross-epoch
        // contamination names a forbidden bucket.
        use std::sync::atomic::AtomicBool;
        const EPOCH_CYCLE: u64 = 36; // 6 × WINDOW_SLOTS: no aliasing in range
        fn epoch_latency(e: u64) -> u64 {
            1u64 << (e % EPOCH_CYCLE) // bucket (e % EPOCH_CYCLE) + 1 < BUCKETS
        }
        let w = Arc::new(SchemeWindows::new());
        let published = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let (w, published, stop) =
                (Arc::clone(&w), Arc::clone(&published), Arc::clone(&stop));
            std::thread::spawn(move || {
                let mut epoch = 1u64;
                while !stop.load(Ordering::Relaxed) {
                    // Publish before recording: at any instant every
                    // recorded epoch is ≤ the published one.
                    published.store(epoch, Ordering::Release);
                    for _ in 0..64 {
                        w.record(epoch, epoch_latency(epoch));
                    }
                    epoch += 1;
                }
            })
        };
        let mut checked = 0u32;
        let mut spins = 0u64;
        while checked < 1_000 && spins < 50_000_000 {
            spins += 1;
            let before = published.load(Ordering::Acquire);
            if before == 0 {
                continue;
            }
            let mut count = 0u64;
            let mut buckets = [0u64; BUCKETS];
            w.fold_recent(before, &mut count, &mut buckets);
            let after = published.load(Ordering::Acquire);
            // Epochs legally foldable here span (before − WINDOW_SLOTS,
            // after]; when that range fits inside one encoding cycle, any
            // other bucket holding mass is aged-out data read as current.
            let oldest = (before + 1).saturating_sub(WINDOW_SLOTS as u64).max(1);
            if after - oldest >= EPOCH_CYCLE {
                continue; // writer lapped the cycle mid-fold; skip
            }
            let allowed: std::collections::BTreeSet<usize> =
                (oldest..=after).map(|e| bucket_index(epoch_latency(e))).collect();
            for (i, &mass) in buckets.iter().enumerate() {
                assert!(
                    mass == 0 || allowed.contains(&i),
                    "bucket {i} holds {mass} aged-out samples (fold at epoch \
                     {before}, writer at {after})"
                );
            }
            checked += 1;
        }
        stop.store(true, Ordering::Relaxed);
        writer.join().unwrap();
        assert!(checked > 0, "the fold race was never exercised");
    }

    #[test]
    fn fidelity_block_merges_shards() {
        let m = Metrics::new(2);
        for _ in 0..10 {
            m.shard(0).fidelity().record(0, SchemeId::Dither, 4, 0.5);
            m.shard(1).fidelity().record(0, SchemeId::Dither, 4, -0.5);
        }
        m.shard(0).fidelity().record(1, SchemeId::Stochastic, 2, 2.0);
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        let fid = json.get("fidelity").unwrap().as_arr().unwrap();
        assert_eq!(fid.len(), 2, "only observed (model, scheme, k) cells are emitted");
        let dither = fid
            .iter()
            .find(|e| e.get("scheme").and_then(Json::as_str) == Some("dither"))
            .expect("dither entry");
        assert_eq!(dither.get("model").unwrap().as_str(), Some("digits_linear"));
        assert_eq!(dither.get("k").unwrap().as_f64(), Some(4.0));
        assert_eq!(dither.get("samples").unwrap().as_f64(), Some(20.0));
        // +0.5 on one shard, -0.5 on the other: unbiased, MSE 0.25.
        assert!(dither.get("bias").unwrap().as_f64().unwrap().abs() < 1e-12);
        assert!((dither.get("mse").unwrap().as_f64().unwrap() - 0.25).abs() < 1e-12);
        let sto = fid
            .iter()
            .find(|e| e.get("scheme").and_then(Json::as_str) == Some("stochastic"))
            .expect("stochastic entry");
        assert_eq!(sto.get("model").unwrap().as_str(), Some("fashion_mlp"));
        assert_eq!(sto.get("samples").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn empty_snapshot_is_valid() {
        let m = Metrics::new(4);
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("p95_us").unwrap().as_f64(), Some(0.0));
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(0.0));
        assert_eq!(json.get("shards").unwrap().as_f64(), Some(4.0));
        assert_eq!(
            json.get("fidelity").unwrap().as_arr().map(<[Json]>::len),
            Some(0),
            "no shadow samples -> empty fidelity block"
        );
        let recent = json.get("recent").expect("recent section");
        for scheme in ["deterministic", "stochastic", "dither"] {
            assert_eq!(
                recent.get(scheme).unwrap().get("requests").unwrap().as_f64(),
                Some(0.0)
            );
        }
    }

    #[test]
    fn timeout_and_flush_counters_merge_on_scrape() {
        let m = Metrics::new(2);
        m.shard(0).record_timeout();
        m.shard(1).record_timeout();
        m.shard(0).record_flush(4); // one syscall delivered 4 replies
        m.shard(0).record_flush(1);
        m.shard(1).record_flush(3);
        m.shard(0).record_deprecated_field();
        m.shard(1).record_deprecated_field();
        m.shard(1).record_deprecated_field();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("timeouts").unwrap().as_f64(), Some(2.0));
        assert_eq!(json.get("deprecated_fields").unwrap().as_f64(), Some(3.0));
        assert_eq!(json.get("writer_flushes").unwrap().as_f64(), Some(3.0));
        assert_eq!(json.get("writer_flushed_lines").unwrap().as_f64(), Some(8.0));
        // Timeouts are their own counter, not errors.
        assert_eq!(json.get("errors").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn recent_includes_model_k_cells() {
        let m = Metrics::new(2);
        for _ in 0..40 {
            m.shard(0).record_request(SchemeId::Dither, 0, 4, 100);
        }
        m.shard(1).record_request(SchemeId::Dither, 1, 8, 1_000_000);
        // Out-of-range labels count toward totals but skip the cell.
        m.shard(0).record_request(SchemeId::Dither, 99, 4, 5);
        m.shard(0).record_request(SchemeId::Dither, 0, 99, 5);
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        let recent = json.get("recent").expect("recent section");
        let digits = recent.get("digits_linear/k=4").expect("digits k=4 cell");
        assert_eq!(digits.get("requests").unwrap().as_f64(), Some(40.0));
        assert!(digits.get("p99_us").unwrap().as_f64().unwrap() < 1000.0);
        let fashion = recent.get("fashion_mlp/k=8").expect("fashion k=8 cell");
        assert_eq!(fashion.get("requests").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            fashion.get("buckets").unwrap().as_f64_vec().unwrap().len(),
            BUCKETS
        );
        // Cells with no traffic are not emitted at all.
        assert!(recent.get("digits_linear/k=2").is_none());
        assert_eq!(json.get("requests").unwrap().as_f64(), Some(43.0));
        // The two out-of-space labels are counted, not silently dropped.
        assert_eq!(json.get("recent_dropped").unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn auto_counters_merge_on_scrape() {
        let m = Metrics::new(2);
        m.shard(0).record_auto_resolution(3, 4);
        m.shard(1).record_auto_resolution(2, 0);
        m.shard(0).record_auto_infeasible();
        m.shard(1).record_auto_infeasible();
        let json = crate::util::json::Json::parse(&m.snapshot_json()).unwrap();
        assert_eq!(json.get("auto_slo_requests").unwrap().as_f64(), Some(5.0));
        assert_eq!(json.get("auto_measured").unwrap().as_f64(), Some(4.0));
        assert_eq!(json.get("auto_infeasible").unwrap().as_f64(), Some(2.0));
        // Wall-clock start is echoed (and sane: after 2020, i.e. not 0).
        assert!(json.get("start_time").unwrap().as_f64().unwrap() > 1.5e9);
    }

    #[test]
    fn slo_sample_folds_lifetime_counters() {
        let m = Metrics::new(2);
        for i in 0..10u64 {
            m.shard((i % 2) as usize).record_request(SchemeId::Dither, 0, 4, 100);
        }
        m.shard(0).record_error();
        m.shard(1).record_rejected();
        m.shard(0).record_timeout();
        m.shard(1).record_auto_infeasible();
        let s = m.handle().slo_sample();
        assert_eq!(s.requests, 10);
        assert_eq!(s.errors, 1);
        assert_eq!(s.rejected, 1);
        assert_eq!(s.timeouts, 1);
        assert_eq!(s.auto_infeasible, 1);
        assert_eq!(s.latency_buckets.len(), BUCKETS);
        assert_eq!(s.latency_buckets.iter().sum::<u64>(), 10);
        // Tracer- and engine-owned counters stay for the caller to fill.
        assert_eq!((s.slow_promoted, s.plan_evictions), (0, 0));
    }

    #[test]
    fn auto_snapshot_folds_estimators_and_latency_windows() {
        use crate::fidelity::{LATENCY_MIN_SAMPLES, MIN_SAMPLES};
        let m = Metrics::new(2);
        // Warm the (model 0, k=2) window and the dither scheme window
        // across both shards; leave deterministic one sample short.
        for i in 0..LATENCY_MIN_SAMPLES {
            m.shard((i % 2) as usize).record_request(SchemeId::Dither, 0, 2, 100);
        }
        for _ in 0..LATENCY_MIN_SAMPLES - 1 {
            m.shard(0).record_request(SchemeId::Deterministic, 1, 1, 50_000);
        }
        // Warm one MSE cell split across shards.
        for i in 0..MIN_SAMPLES {
            let e = if i % 2 == 0 { 0.5 } else { -0.5 };
            m.shard((i % 2) as usize).fidelity().record(0, SchemeId::Dither, 2, e);
        }
        let snap = m.handle().auto_snapshot();
        let est = snap.estimates.get(0, SchemeId::Dither, 2);
        assert_eq!(est.samples, MIN_SAMPLES);
        assert!((est.mse() - 0.25).abs() < 1e-12, "mse={}", est.mse());
        let mk = snap.latency.model_k_latency(0, 2).expect("warm (model, k) window");
        assert!(mk >= 100 && mk < 1000, "p50={mk}");
        assert!(snap.latency.scheme_latency(SchemeId::Dither).is_some());
        assert_eq!(
            snap.latency.scheme_latency(SchemeId::Deterministic),
            None,
            "one sample short of LATENCY_MIN_SAMPLES stays cold"
        );
        // The snapshot is plain data: folding again reproduces it.
        assert_eq!(snap, m.handle().auto_snapshot());
    }

    #[test]
    fn percentile_answers_sanely_at_the_edges() {
        // Empty slice and zero mass: no percentile, answer 0.
        assert_eq!(percentile_from_buckets(&[], 0.99), 0.0);
        assert_eq!(percentile_from_buckets(&[0, 0, 0], 0.5), 0.0);
        // A single bucket holding all mass answers that bucket's upper
        // edge for every p.
        let mut one = [0u64; 8];
        one[3] = 1000;
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_from_buckets(&one, p), bucket_upper(3) as f64);
        }
        // Junk p is clamped, never a fall-off-the-end garbage answer.
        assert_eq!(percentile_from_buckets(&one, f64::NAN), bucket_upper(3) as f64);
        assert_eq!(percentile_from_buckets(&one, -2.0), bucket_upper(3) as f64);
        assert_eq!(percentile_from_buckets(&one, 42.0), bucket_upper(3) as f64);
        // A short wire slice can never answer beyond its own last bucket.
        assert!(percentile_from_buckets(&[5, 5], 1.0) <= bucket_upper(1) as f64);
    }

    #[test]
    fn prometheus_exposition_is_well_formed_and_complete() {
        use crate::trace::{check_exposition, Stage, TraceConfig, Tracer};
        let m = Metrics::new(2);
        for i in 0..20u64 {
            m.shard((i % 2) as usize).record_request(SchemeId::Dither, 0, 4, i * 100);
        }
        m.shard(0).record_error();
        m.shard(0).record_request(SchemeId::Dither, 99, 4, 5); // out-of-space drop
        m.shard(0).record_auto_resolution(2, 3);
        m.shard(0).fidelity().record(0, SchemeId::Dither, 4, 0.5);
        let tracer = Tracer::new(TraceConfig {
            rate: 1.0,
            slow_us: 0,
            buffer: 4,
        });
        let mut b = tracer.begin(1).unwrap();
        let now = std::time::Instant::now();
        b.span(Stage::Kernel, now, now);
        tracer.finish(b);
        let text = m.prometheus(&tracer);
        check_exposition(&text).expect("well-formed exposition");
        assert!(text.contains("dither_requests_total 21"), "{text}");
        assert!(text.contains("dither_errors_total 1"), "{text}");
        assert!(text.contains("dither_recent_dropped_total 1"), "{text}");
        assert!(text.contains("dither_auto_slo_requests_total 2"), "{text}");
        assert!(text.contains("dither_auto_measured_total 3"), "{text}");
        assert!(text.contains("# TYPE dither_latency_us histogram"), "{text}");
        assert!(text.contains("dither_latency_us_bucket{le=\"+Inf\"} 21"), "{text}");
        assert!(
            text.contains("dither_recent_latency_us_bucket{scheme=\"dither\",le=\"+Inf\"} 21"),
            "{text}"
        );
        assert!(
            text.contains("dither_recent_latency_us_bucket{model=\"digits_linear\",k=\"4\""),
            "{text}"
        );
        assert!(
            text.contains(
                "dither_fidelity_mse{model=\"digits_linear\",scheme=\"dither\",k=\"4\"}"
            ),
            "{text}"
        );
        assert!(text.contains("dither_shard_requests_total{shard=\"0\"} 11"), "{text}");
        assert!(
            text.contains("dither_stage_duration_us_bucket{stage=\"kernel\""),
            "span histograms must reach the exposition: {text}"
        );
        assert!(text.contains("dither_traces_committed_total 1"), "{text}");
        // An idle process still exposes a valid (family-bearing) surface.
        let idle = Metrics::new(1);
        let idle_tracer = Tracer::new(TraceConfig::default());
        check_exposition(&idle.prometheus(&idle_tracer)).expect("idle exposition");
    }

    #[test]
    fn shard_indexing_wraps() {
        let m = Metrics::new(3);
        m.shard(5).record_request(SchemeId::Stochastic, 0, 4, 1); // 5 % 3 == 2
        assert_eq!(m.shard(2).requests(), 1);
        assert_eq!(m.total_requests(), 1);
    }
}
