//! Per-shard dynamic batcher: a bounded queue that groups
//! same-configuration requests into batches.
//!
//! Requests arriving within `max_wait` that share `(model, k, scheme)` are
//! coalesced up to `max_batch` and executed in one engine call — the
//! classic dynamic-batching policy. Each request carries a [`ReplyTo`] —
//! the per-request reply channel back to its connection's writer, tagged
//! with the request id so pipelined completions can return out of order.
//! The queue is bounded (`capacity`):
//! [`Batcher::submit`] rejects instead of growing without limit, which is
//! the server's backpressure signal ([`SubmitError::Overloaded`]).
//!
//! **Plan-aware draining**: when a residency oracle is installed
//! ([`Batcher::set_residency`] — the shard pool points it at the owning
//! engine's plan cache), the batcher prefers to drain keys whose prepared
//! plans are cache-resident, so a cold configuration's replanning cost is
//! not paid in front of hot traffic. Starvation is bounded: once the
//! oldest queued request has waited [`STARVATION_MULT`]× the linger time,
//! its key is drained next regardless of residency.
//!
//! Shutdown has two flavours: [`Batcher::close`] stops intake and lets the
//! worker drain what is queued (graceful), [`Batcher::stop`] aborts after
//! the in-flight batch.
//!
//! **Reply watchdog**: the worker registers every dispatched batch with
//! the pool's [`ReplyWatchdog`] before the engine call; a sweeper thread
//! answers `timeout` (with the request id) for any reply still
//! outstanding past the deadline and releases its window slot, bounding
//! the damage of a wedged, non-panicking engine call.
//!
//! **Auto batches**: `"scheme":"auto"` requests queue under their `k = 0`
//! placeholder key and resolve to a concrete `(scheme, k)` once per
//! drained batch ([`BatchKey::is_auto`]), so adjacent auto requests under
//! a pipelined flood coalesce onto one engine call. Resolution prices
//! candidates against the process's merged [`AutoView`] snapshot (the
//! strictest member budget on each axis), echoes `"measured": true` when
//! the choice came from live measurements, and answers a batch carrying
//! no budget at all with a non-retryable error.
//!
//! **Tracing**: a traced request carries its [`TraceBuilder`] inside
//! [`Pending`] (one `Option<Box<_>>`, so untraced queues pay a pointer).
//! The worker stamps queue-wait, batch-assembly and auto-resolution
//! spans, fans the engine's batch-level plan/kernel/shadow intervals out
//! to every traced member (the kernel span is noted
//! `"<kernel>/<scheme>"`), then times serialization and the writer
//! handoff before handing the finished builder to the shard pool's
//! [`Tracer`]. All clock reads are gated on the batch actually containing
//! a traced request, so `--trace-rate 0` adds no timing work.

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::protocol::{format_error, format_response, InferenceRequest};
use crate::fidelity::{choose_slo, AutoChoice, AutoSnapshot, AutoView, SloBudget};
use crate::obs::{EventKind, Journal, Severity};
use crate::rounding::SchemeId;
use crate::trace::{BatchStageTimes, Stage, TraceBuilder, Tracer};
use crate::train::ModelSpec;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// How many linger periods the oldest queued request may wait before its
/// key is drained ahead of resident-plan keys (the anti-starvation bound
/// of plan-aware batching).
pub const STARVATION_MULT: u32 = 8;

/// The shared completion state behind a [`ReplyTo`] and its watchdog
/// handles: whichever completion path runs first — the worker's reply, a
/// cancellation on drop, or a watchdog timeout — takes the channel sender
/// and delivers its line; every later path is a no-op. Taking the sender
/// also *drops* it, so a wedged engine call still holding the `ReplyTo`
/// cannot keep the connection's writer channel open at shutdown.
struct ReplyState {
    id: u64,
    tx: Mutex<Option<SyncSender<String>>>,
    window: Option<Arc<AtomicUsize>>,
    /// Records abnormal completions (cancellation, timeout) in the owning
    /// shard's metrics.
    metrics: Option<Arc<ShardMetrics>>,
}

impl ReplyState {
    /// Deliver `line` if no completion path won yet; true when this call
    /// was the winner (it then also released the window slot). The
    /// receiving writer may already be gone on connection teardown; that
    /// send failure is ignored.
    fn complete(&self, line: String) -> bool {
        let Some(tx) = self.tx.lock().unwrap().take() else {
            return false;
        };
        let _ = tx.send(line);
        if let Some(window) = &self.window {
            window.fetch_sub(1, Ordering::AcqRel);
        }
        true
    }

    /// True once some completion path has delivered (or abandoned) the
    /// reply.
    fn is_done(&self) -> bool {
        self.tx.lock().unwrap().is_none()
    }
}

/// Where one request's response line goes: the submitting connection's
/// writer channel, tagged with the request id so the reply can be matched
/// out of order (pipelined connections funnel every reply through one
/// channel). Dropping a `ReplyTo` without replying — hard shutdown clears
/// shard queues by dropping `Pending`s — sends a `cancelled` error
/// instead, so a pipelined client is never left waiting on an accepted
/// id. When a per-connection in-flight window is attached, delivering (or
/// cancelling, or timing out) the reply releases its window slot exactly
/// once. [`ReplyTo::watch`] hands the watchdog a deadline-tagged handle to
/// the same completion state.
pub struct ReplyTo {
    state: Arc<ReplyState>,
}

impl ReplyTo {
    /// Reply channel for request `id`. The channel is the connection
    /// writer's bounded funnel; capacity is sized so in-window replies
    /// never block (see `server::writer channel`).
    pub fn new(id: u64, tx: SyncSender<String>) -> ReplyTo {
        ReplyTo {
            state: Arc::new(ReplyState {
                id,
                tx: Mutex::new(Some(tx)),
                window: None,
                metrics: None,
            }),
        }
    }

    /// Attach (and occupy) one slot of a connection's in-flight window;
    /// the slot is released when the reply is sent, cancelled, or timed
    /// out. Builder-only: must run before any watchdog handle is taken.
    pub fn with_window(mut self, window: Arc<AtomicUsize>) -> ReplyTo {
        window.fetch_add(1, Ordering::AcqRel);
        let state = Arc::get_mut(&mut self.state).expect("with_window before sharing");
        state.window = Some(window);
        self
    }

    /// Record abnormal completions — a cancellation as an error, a
    /// watchdog timeout as a timeout — in `metrics`, so hard-stopped and
    /// wedged requests stay visible in `stats`. Builder-only, like
    /// [`ReplyTo::with_window`].
    pub fn with_cancel_metrics(mut self, metrics: Arc<ShardMetrics>) -> ReplyTo {
        let state = Arc::get_mut(&mut self.state).expect("with_cancel_metrics before sharing");
        state.metrics = Some(metrics);
        self
    }

    /// The request id this reply channel serves.
    pub fn id(&self) -> u64 {
        self.state.id
    }

    /// Deliver the response line (no-op if a watchdog timeout beat it).
    pub fn send(self, line: String) {
        self.state.complete(line);
        // Drop then finds the sender gone and does nothing further.
    }

    /// A watchdog handle to this reply with the given deadline (see
    /// [`ReplyWatchdog`]).
    pub fn watch(&self, deadline: Instant) -> ReplyDeadline {
        ReplyDeadline {
            state: self.state.clone(),
            deadline,
        }
    }
}

impl Drop for ReplyTo {
    fn drop(&mut self) {
        if self
            .state
            .complete(format_error(self.state.id, "cancelled", true))
        {
            if let Some(metrics) = &self.state.metrics {
                metrics.record_error();
            }
        }
    }
}

/// A deadline-tagged handle to an in-flight reply, held by the
/// [`ReplyWatchdog`]. Expiring it answers `timeout` — with the request's
/// id — and releases the window slot, unless the real reply (or a
/// cancellation) won first.
#[derive(Clone)]
pub struct ReplyDeadline {
    state: Arc<ReplyState>,
    deadline: Instant,
}

impl ReplyDeadline {
    /// When this reply is considered wedged.
    pub fn deadline(&self) -> Instant {
        self.deadline
    }

    /// True once the reply was delivered, cancelled, or timed out.
    pub fn is_done(&self) -> bool {
        self.state.is_done()
    }

    /// Answer `timeout` if nothing else completed the reply first; true
    /// when this call won (it then also recorded the timeout in the
    /// shard's metrics).
    pub fn expire(&self) -> bool {
        let won = self.state.complete(format_error(self.state.id, "timeout", true));
        if won {
            if let Some(metrics) = &self.state.metrics {
                metrics.record_timeout();
            }
        }
        won
    }
}

/// Deadline sweep over outstanding replies: restores the per-request time
/// bound the lockstep loop used to have. Workers register each batch's
/// replies just before the engine call; a sweeper thread (one per shard
/// pool) periodically expires entries whose deadline passed — a wedged,
/// non-panicking engine call then answers `timeout` with its id and
/// releases its window slot instead of holding the reply channel (and the
/// connection's writer at shutdown) forever. Completed entries are pruned
/// on every sweep and opportunistically on registration, so the table
/// tracks only genuinely outstanding replies.
pub struct ReplyWatchdog {
    timeout: Duration,
    entries: Mutex<Vec<ReplyDeadline>>,
    stopped: AtomicBool,
}

impl ReplyWatchdog {
    /// Watchdog answering `timeout` for replies outstanding longer than
    /// `timeout` past their dispatch (clamped to ≥ 1 ms).
    pub fn new(timeout: Duration) -> ReplyWatchdog {
        ReplyWatchdog {
            timeout: timeout.max(Duration::from_millis(1)),
            entries: Mutex::new(Vec::new()),
            stopped: AtomicBool::new(false),
        }
    }

    /// The configured per-dispatch deadline.
    pub fn timeout(&self) -> Duration {
        self.timeout
    }

    /// Register a batch that is about to enter an engine call.
    pub fn register(&self, batch: &[Pending]) {
        let deadline = Instant::now() + self.timeout;
        let mut entries = self.entries.lock().unwrap();
        entries.retain(|e| !e.is_done());
        entries.extend(batch.iter().map(|p| p.respond_to.watch(deadline)));
    }

    /// One sweep at `now`: expire overdue replies, prune completed ones;
    /// returns how many replies this sweep answered with `timeout`.
    /// Expiry runs *outside* the entry lock — a `timeout` send can block
    /// on a full writer channel, and that must never stall the workers
    /// registering fresh batches.
    pub fn sweep(&self, now: Instant) -> usize {
        let mut due: Vec<ReplyDeadline> = Vec::new();
        self.entries.lock().unwrap().retain(|e| {
            if e.is_done() {
                return false;
            }
            if now >= e.deadline() {
                due.push(e.clone());
                return false;
            }
            true
        });
        due.into_iter().filter(|e| e.expire()).count()
    }

    /// Replies currently tracked (outstanding at the last prune).
    pub fn outstanding(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// Stop the sweeper loop ([`ReplyWatchdog::run`]).
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::Release);
    }

    /// Sweep periodically until [`ReplyWatchdog::stop`]. The shard pool
    /// runs this on a dedicated thread; the tick is a fraction of the
    /// deadline so expiry lands within ~12% of the configured bound.
    pub fn run(&self) {
        let tick = (self.timeout / 8).clamp(Duration::from_millis(5), Duration::from_millis(250));
        while !self.stopped.load(Ordering::Acquire) {
            std::thread::sleep(tick);
            self.sweep(Instant::now());
        }
    }
}

/// A queued request with its response channel.
pub struct Pending {
    /// The request.
    pub req: InferenceRequest,
    /// Where the response line is sent.
    pub respond_to: ReplyTo,
    /// Enqueue time (for latency accounting).
    pub enqueued: Instant,
    /// In-flight trace context (`None` for the untraced common case).
    /// Moves with the request — span recording needs no lock.
    pub trace: Option<Box<TraceBuilder>>,
}

/// Batch key: requests with equal keys can share one executable call.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BatchKey {
    /// Model family.
    pub model: String,
    /// Bit width.
    pub k: u32,
    /// Rounding scheme.
    pub scheme: SchemeId,
}

impl BatchKey {
    fn of(req: &InferenceRequest) -> BatchKey {
        BatchKey {
            model: req.model.clone(),
            k: req.k,
            scheme: req.scheme,
        }
    }

    fn matches(&self, req: &InferenceRequest) -> bool {
        req.model == self.model && req.k == self.k && req.scheme == self.scheme
    }

    /// True for the auto-precision pseudo-key: auto requests enter the
    /// queue under their parse-time placeholder (`k = 0`, which no
    /// concrete request can carry), so a model's adjacent auto requests
    /// share one key and the worker resolves the concrete `(scheme, k)`
    /// once per drained batch.
    pub fn is_auto(&self) -> bool {
        self.k == 0
    }
}

/// Why a [`Batcher::submit`] was refused. The rejected request is handed
/// back so the caller can reply to its client.
pub enum SubmitError {
    /// The bounded queue is full — backpressure; client should retry.
    Overloaded(Pending),
    /// The batcher is closed or stopped (server shutting down).
    Closed(Pending),
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded(p) => write!(f, "Overloaded(id={})", p.req.id),
            SubmitError::Closed(p) => write!(f, "Closed(id={})", p.req.id),
        }
    }
}

/// Shared state between submitters and one shard's batching worker.
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    closed: AtomicBool,
    stopped: AtomicBool,
    /// Plan-residency oracle (set once at shard start): true when a key's
    /// prepared plans are cache-resident in the owning shard's engine.
    residency: OnceLock<Box<dyn Fn(&BatchKey) -> bool + Send + Sync>>,
    /// Maximum batch size per engine call.
    pub max_batch: usize,
    /// How long to linger for more same-key requests.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure threshold).
    pub capacity: usize,
}

impl Batcher {
    /// New batcher with the given policy. `capacity` bounds the queue;
    /// submissions beyond it are rejected with
    /// [`SubmitError::Overloaded`].
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            closed: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            residency: OnceLock::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Install the plan-residency oracle (first call wins; the shard pool
    /// sets it once before traffic). With no oracle the batcher drains in
    /// pure arrival order, exactly as before.
    pub fn set_residency(&self, f: impl Fn(&BatchKey) -> bool + Send + Sync + 'static) {
        let _ = self.residency.set(Box::new(f));
    }

    /// Age past which the oldest queued request's key preempts
    /// resident-plan preference.
    fn starvation_bound(&self) -> Duration {
        self.max_wait
            .saturating_mul(STARVATION_MULT)
            .max(Duration::from_millis(2))
    }

    /// Choose the key the next batch drains: the oldest request's key once
    /// it is over the starvation bound, else the first queued key whose
    /// plans are resident, else the oldest request's key.
    ///
    /// Runs under the queue lock, so the oracle (which takes the engine's
    /// plan-cache lock) is probed once per *distinct* key — the queue
    /// typically holds 1–3 — not once per queued request.
    fn pick_key(&self, q: &VecDeque<Pending>) -> BatchKey {
        let front = q.front().expect("pick_key on a non-empty queue");
        if front.enqueued.elapsed() >= self.starvation_bound() {
            return BatchKey::of(&front.req);
        }
        if let Some(resident) = self.residency.get() {
            let mut probed: Vec<BatchKey> = Vec::new();
            for p in q {
                if probed.iter().any(|k| k.matches(&p.req)) {
                    continue; // this key already probed non-resident
                }
                let key = BatchKey::of(&p.req);
                if resident(&key) {
                    return key;
                }
                probed.push(key);
            }
        }
        BatchKey::of(&front.req)
    }

    /// Enqueue a request; rejects when the queue is full or the batcher is
    /// shutting down.
    pub fn submit(&self, p: Pending) -> Result<(), SubmitError> {
        let mut q = self.queue.lock().unwrap();
        // Flag check under the queue lock: close()/stop() set their flag
        // before taking this lock, so a submitter that sees the flags
        // clear here is guaranteed to enqueue before the worker observes
        // shutdown — the request is drained (close) or cleared (stop),
        // never stranded in a dead queue.
        if self.closed.load(Ordering::SeqCst) || self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed(p));
        }
        if q.len() >= self.capacity {
            return Err(SubmitError::Overloaded(p));
        }
        q.push_back(p);
        drop(q);
        self.notify.notify_one();
        Ok(())
    }

    /// Graceful shutdown: refuse new submissions, let the worker drain the
    /// queue and then exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        // Take the queue lock before notifying: a worker that checked the
        // flag but has not yet parked in `wait` still holds the lock, so
        // this blocks until it parks and the wakeup cannot be lost.
        let _guard = self.queue.lock().unwrap();
        self.notify.notify_all();
    }

    /// Hard shutdown: the worker exits after its in-flight batch; queued
    /// requests are dropped here so their channels close and waiting
    /// clients error out immediately. The drop (which sends `cancelled`
    /// lines into bounded writer channels) happens outside the queue
    /// lock so a slow client cannot stall submitters.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.closed.store(true, Ordering::SeqCst);
        let drained: Vec<Pending> = {
            let mut q = self.queue.lock().unwrap();
            let drained = q.drain(..).collect();
            self.notify.notify_all();
            drained
        };
        drop(drained); // Pendings -> ReplyTo cancellations -> clients unblock
    }

    /// True once `close` or `stop` has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// True once `stop` has been called.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::SeqCst)
    }

    /// Current queue depth (approximate under concurrency).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Pull the next batch: blocks until at least one request is queued,
    /// lingers up to `max_wait` for same-key company, then drains up to
    /// `max_batch` matching requests (preserving arrival order of the
    /// rest). Returns `None` on stop, or on close once the queue is empty.
    pub fn next_batch(&self) -> Option<(BatchKey, Vec<Pending>)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            loop {
                if self.is_stopped() {
                    return None;
                }
                if !q.is_empty() {
                    break;
                }
                if self.closed.load(Ordering::SeqCst) {
                    return None; // graceful drain complete
                }
                q = self.notify.wait(q).unwrap();
            }
            let key = self.pick_key(&q);
            // Linger for stragglers while the batch is not full (skipped
            // when shutting down — drain as fast as possible).
            let deadline = Instant::now() + self.max_wait;
            loop {
                let matching = q.iter().filter(|p| key.matches(&p.req)).count();
                if matching >= self.max_batch
                    || Instant::now() >= deadline
                    || self.is_shutting_down()
                {
                    break;
                }
                let (guard, _timeout) = self
                    .notify
                    .wait_timeout(q, deadline.saturating_duration_since(Instant::now()))
                    .unwrap();
                q = guard;
            }
            // Drain matching requests.
            let mut batch = Vec::new();
            let mut rest = VecDeque::with_capacity(q.len());
            while let Some(p) = q.pop_front() {
                if key.matches(&p.req) && batch.len() < self.max_batch {
                    batch.push(p);
                } else {
                    rest.push_back(p);
                }
            }
            *q = rest;
            if !batch.is_empty() {
                return Some((key, batch));
            }
            // stop() cleared the queue while we lingered without the lock;
            // loop back (the stopped check above returns None).
        }
    }
}

/// Resolve an auto-precision batch once, against the process's merged
/// [`AutoSnapshot`]: the strictest member budget on each axis (minimum
/// `max_mse`, minimum `max_latency_us`) picks the cheapest `(scheme, k)`
/// the measurements (or the paper-shape prior and static cost order) can
/// justify, so every request in the drained batch shares one engine call.
/// Batch granularity is the point — under a pipelined flood, adjacent
/// auto requests no longer read estimator state mid-drain and split onto
/// different keys.
///
/// A batch in which no member carries a budget on either axis is a
/// resolution error, not an unbounded walk: folding `max_mse` over zero
/// members used to yield `INFINITY` and silently serve the cheapest
/// candidate. The protocol layer rejects budget-less autos, so reaching
/// that state here means a hand-built [`Pending`]; it is answered with a
/// non-retryable error. An absent axis is only treated as unbounded when
/// the other axis is present.
fn resolve_auto(
    model: &str,
    batch: &[Pending],
    snapshot: &AutoSnapshot,
) -> Result<AutoChoice, String> {
    let spec = ModelSpec::from_name(model)
        .ok_or_else(|| format!("unknown model family {model:?}"))?;
    let max_mse = batch
        .iter()
        .filter_map(|p| p.req.max_mse)
        .fold(None, |acc: Option<f64>, b| Some(acc.map_or(b, |a| a.min(b))));
    let max_latency_us = batch.iter().filter_map(|p| p.req.max_latency_us).min();
    if max_mse.is_none() && max_latency_us.is_none() {
        return Err(
            "auto batch carries no 'max_mse' or 'max_latency_us' budget on any member"
                .to_string(),
        );
    }
    Ok(choose_slo(
        &snapshot.estimates,
        &snapshot.latency,
        spec.index(),
        SloBudget { max_mse, max_latency_us },
    ))
}

/// One shard's batching worker loop: pull → resolve (auto batches) →
/// execute → respond. Returns on shutdown (after draining, for a graceful
/// close). `shard` tags response lines so clients can observe the
/// routing; when a `watchdog` is installed, every batch's replies are
/// registered just before the engine call so a wedged call answers
/// `timeout` instead of holding its window slots forever. Traced requests
/// (see [`Pending::trace`]) accumulate their queue/assemble/engine-stage
/// spans here and finish into `tracer`. Auto batches resolve against the
/// latest [`AutoView`] snapshot (merged across shards by the pool's
/// refresher), so every worker of one process converges on the same view
/// of measured latency and fidelity. When a `journal` is installed, auto
/// resolutions that move a model to a new `(scheme, k)` operating point
/// publish a [`EventKind::SchemeSwitch`] event, and budget-infeasible
/// resolutions bump the shard's `auto_infeasible` counter (the SLO
/// evaluator turns movement there into events off the hot path).
#[allow(clippy::too_many_arguments)]
pub fn worker_loop(
    batcher: &Batcher,
    engine: &Engine,
    metrics: &ShardMetrics,
    tracer: &Tracer,
    auto_view: &AutoView,
    shard: usize,
    watchdog: Option<&ReplyWatchdog>,
    journal: Option<&Journal>,
) {
    // Per-worker memory of the last resolved operating point per model:
    // scheme switches are detected here (no shared state, so two shards
    // may each announce the same fleet-wide move — acceptable for an
    // ops signal, free for the hot path).
    let mut last_choice: std::collections::HashMap<String, (SchemeId, u32)> =
        std::collections::HashMap::new();
    while let Some((key, mut batch)) = batcher.next_batch() {
        metrics.record_batch(batch.len());
        let size = batch.len();
        // Every clock read below is gated on this: an untraced batch
        // (the whole workload at --trace-rate 0) takes no timestamps.
        let traced = batch.iter().any(|p| p.trace.is_some());
        let drained = traced.then(Instant::now);
        let (scheme, k, measured) = if key.is_auto() {
            let snapshot = auto_view.load();
            match resolve_auto(&key.model, &batch, &snapshot) {
                Ok(choice) => {
                    let slo_members =
                        batch.iter().filter(|p| p.req.max_latency_us.is_some()).count() as u64;
                    let measured = choice.any_measured();
                    metrics.record_auto_resolution(
                        slo_members,
                        if measured { batch.len() as u64 } else { 0 },
                    );
                    if !choice.feasible {
                        metrics.record_auto_infeasible();
                    }
                    if let Some(journal) = journal {
                        let prev = last_choice
                            .insert(key.model.clone(), (choice.scheme, choice.k));
                        if let Some((from_scheme, from_k)) = prev {
                            if (from_scheme, from_k) != (choice.scheme, choice.k) {
                                journal.publish(
                                    Severity::Info,
                                    EventKind::SchemeSwitch,
                                    &[
                                        ("model", &key.model),
                                        ("from_scheme", from_scheme.wire_name()),
                                        ("from_k", &from_k.to_string()),
                                        ("to_scheme", choice.scheme.wire_name()),
                                        ("to_k", &choice.k.to_string()),
                                    ],
                                );
                            }
                        }
                    }
                    (choice.scheme, choice.k, measured)
                }
                Err(e) => {
                    for mut p in batch {
                        metrics.record_error();
                        let id = p.req.id;
                        let trace = p.trace.take();
                        // An unknown model family (or a budget-less
                        // batch) never resolves, no matter how often the
                        // client retries.
                        p.respond_to.send(format_error(id, &e, false));
                        if let Some(mut b) = trace {
                            b.set_shard(shard);
                            tracer.finish(b);
                        }
                    }
                    continue;
                }
            }
        } else {
            (key.scheme, key.k, false)
        };
        let resolved = traced.then(Instant::now);
        if let Some(watchdog) = watchdog {
            watchdog.register(&batch);
        }
        if let (Some(drained), Some(resolved)) = (drained, resolved) {
            let sealed = Instant::now();
            for p in batch.iter_mut() {
                if let Some(b) = p.trace.as_deref_mut() {
                    b.span(Stage::Queue, p.enqueued, drained);
                    if key.is_auto() {
                        b.span(Stage::AutoResolve, drained, resolved);
                    }
                    b.span(Stage::Assemble, drained, sealed);
                    b.annotate(&key.model, scheme.wire_name(), k);
                    b.set_shard(shard);
                }
            }
        }
        let model_slot = ModelSpec::from_name(&key.model).map_or(usize::MAX, |s| s.index());
        let mut stage_times = BatchStageTimes::default();
        let result = {
            let pixel_refs: Vec<&[f64]> = batch.iter().map(|p| p.req.pixels.as_slice()).collect();
            engine.infer_batch_timed(
                &key.model,
                k,
                scheme,
                &pixel_refs,
                traced.then_some(&mut stage_times),
            )
        };
        match result {
            Ok(outputs) => {
                let kernel_note = traced.then(|| {
                    format!(
                        "{}/{}",
                        crate::kernels::active_id().name(),
                        scheme.wire_name()
                    )
                });
                for (mut p, out) in batch.into_iter().zip(outputs) {
                    let latency_us = p.enqueued.elapsed().as_micros() as u64;
                    metrics.record_request(scheme, model_slot, k, latency_us);
                    let mut trace = p.trace.take();
                    if let Some(b) = trace.as_deref_mut() {
                        // Batch-level engine stages: shared work, so every
                        // member's timeline shows the same intervals.
                        if let Some((s, e)) = stage_times.plan {
                            b.span(Stage::Plan, s, e);
                        }
                        if let Some((s, e)) = stage_times.kernel {
                            b.span_noted(Stage::Kernel, s, e, kernel_note.clone());
                        }
                        if let Some((s, e)) = stage_times.shadow {
                            b.span(Stage::Shadow, s, e);
                        }
                    }
                    let serialize_at = trace.as_ref().map(|_| Instant::now());
                    let line = format_response(
                        p.req.id,
                        out.pred,
                        scheme,
                        k,
                        &out.logits,
                        latency_us,
                        size,
                        shard,
                        p.req.auto,
                        measured,
                    );
                    if let (Some(b), Some(at)) = (trace.as_deref_mut(), serialize_at) {
                        b.span_since(Stage::Serialize, at);
                    }
                    let flush_at = trace.as_ref().map(|_| Instant::now());
                    p.respond_to.send(line);
                    if let (Some(mut b), Some(at)) = (trace, flush_at) {
                        b.span_since(Stage::Flush, at);
                        tracer.finish(b);
                    }
                }
            }
            Err(e) => {
                for mut p in batch {
                    metrics.record_error();
                    let id = p.req.id;
                    let trace = p.trace.take();
                    // Engine rejections (bad model/width) are permanent.
                    p.respond_to.send(format_error(id, &e.to_string(), false));
                    if let Some(b) = trace {
                        tracer.finish(b);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;

    fn req(model: &str, k: u32, scheme: SchemeId, id: u64) -> InferenceRequest {
        InferenceRequest {
            id,
            model: model.to_string(),
            k,
            scheme,
            auto: false,
            deprecated_mode: false,
            max_mse: None,
            max_latency_us: None,
            trace: None,
            pixels: vec![0.0; 784],
        }
    }

    fn pending(
        model: &str,
        k: u32,
        mode: SchemeId,
        id: u64,
    ) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = sync_channel(64);
        (
            Pending {
                req: req(model, k, mode, id),
                respond_to: ReplyTo::new(id, tx),
                enqueued: Instant::now(),
                trace: None,
            },
            rx,
        )
    }

    #[test]
    fn groups_same_key_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, i);
            b.submit(p).unwrap();
        }
        let (p, _rx) = pending("digits_linear", 2, SchemeId::Dither, 99);
        b.submit(p).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
        assert_eq!(batch.len(), 3);
        // The k=2 request stays queued.
        let (key2, batch2) = b.next_batch().unwrap();
        assert_eq!(key2.k, 2);
        assert_eq!(batch2.len(), 1);
        assert_eq!(batch2[0].req.id, 99);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(2, Duration::from_millis(1), 64);
        for i in 0..5 {
            let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_key() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..4 {
            let (p, _rx) = pending("digits_linear", 4, SchemeId::Stochastic, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bounded_queue_rejects_overload() {
        let b = Batcher::new(8, Duration::from_millis(1), 2);
        for i in 0..2 {
            let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, i);
            b.submit(p).unwrap();
        }
        assert_eq!(b.depth(), 2);
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 9);
        match b.submit(p) {
            Err(SubmitError::Overloaded(back)) => assert_eq!(back.req.id, 9),
            other => panic!("expected overload, got {other:?}"),
        }
        assert_eq!(b.depth(), 2, "rejected request must not occupy the queue");
        // Draining frees capacity again.
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.depth(), 0);
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 10);
        assert!(b.submit(p).is_ok());
    }

    #[test]
    fn closed_batcher_rejects_submissions() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        b.close();
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 1);
        match b.submit(p) {
            Err(SubmitError::Closed(back)) => assert_eq!(back.req.id, 1),
            other => panic!("expected closed, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_queue_then_ends() {
        let b = Batcher::new(2, Duration::from_millis(1), 8);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, i);
            b.submit(p).unwrap();
        }
        b.close();
        // Queued work is still handed out...
        assert_eq!(b.next_batch().unwrap().1.len(), 2);
        assert_eq!(b.next_batch().unwrap().1.len(), 1);
        // ...then the worker is released.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stop_unblocks_worker() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(1), 8));
        let b2 = b.clone();
        let handle = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(20));
        b.stop();
        assert!(handle.join().unwrap());
    }

    #[test]
    fn stop_discards_queued_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 1);
        b.submit(p).unwrap();
        b.stop();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn resident_keys_drain_first_under_mixed_load() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        // Cold key arrives first, resident keys behind it.
        let (p, _rx0) = pending("digits_linear", 2, SchemeId::Dither, 0);
        b.submit(p).unwrap();
        for id in 1..4u64 {
            let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, id);
            b.submit(p).unwrap();
            std::mem::forget(rx);
        }
        // The resident k=4 batch jumps the cold k=2 front request...
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4, "resident-plan key must drain first");
        assert_eq!(batch.len(), 3);
        // ...and the cold key is served right after (no residents left).
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2);
        assert_eq!(batch[0].req.id, 0);
    }

    #[test]
    fn cold_key_is_not_starved_by_resident_traffic() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        let (cold, _rx) = pending("digits_linear", 2, SchemeId::Dither, 0);
        b.submit(cold).unwrap();
        // Let the cold request age past the starvation bound (8× the 1 ms
        // linger), then pile resident traffic behind it.
        std::thread::sleep(b.starvation_bound() + Duration::from_millis(5));
        let (hot, _rx2) = pending("digits_linear", 4, SchemeId::Dither, 1);
        b.submit(hot).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "over-age cold key must preempt resident keys");
        assert_eq!(batch[0].req.id, 0);
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
    }

    #[test]
    fn no_oracle_means_pure_arrival_order() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        let (p, _rx) = pending("digits_linear", 2, SchemeId::Dither, 0);
        b.submit(p).unwrap();
        let (p, _rx2) = pending("digits_linear", 4, SchemeId::Dither, 1);
        b.submit(p).unwrap();
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "without residency the front key drains first");
    }

    #[test]
    fn lingers_to_fill_batch() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(200), 64));
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 0);
        b.submit(p).unwrap();
        let b2 = b.clone();
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            for i in 1..4 {
                let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, i);
                b2.submit(p).unwrap();
                std::mem::forget(rx);
            }
        });
        let (_, batch) = b.next_batch().unwrap();
        submitter.join().unwrap();
        assert_eq!(batch.len(), 4, "linger should capture the stragglers");
    }

    #[test]
    fn reply_to_cancels_on_drop_and_releases_window_slot() {
        use std::sync::atomic::AtomicUsize;
        let window = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = sync_channel(8);
        // A delivered reply: slot taken while in flight, freed after.
        let reply = ReplyTo::new(5, tx.clone()).with_window(window.clone());
        assert_eq!(reply.id(), 5);
        assert_eq!(window.load(Ordering::SeqCst), 1);
        reply.send("{\"id\":5,\"pred\":1}".to_string());
        assert_eq!(window.load(Ordering::SeqCst), 0);
        assert!(rx.recv().unwrap().contains("\"pred\""));
        // A dropped reply (hard shutdown clears the queue): the client
        // gets a cancelled error and the slot is still released.
        let reply = ReplyTo::new(6, tx).with_window(window.clone());
        assert_eq!(window.load(Ordering::SeqCst), 1);
        drop(reply);
        assert_eq!(window.load(Ordering::SeqCst), 0);
        let line = rx.recv().unwrap();
        assert!(line.contains("cancelled") && line.contains("\"id\":6"), "{line}");
        // With metrics attached, a cancellation counts as an error — a
        // delivered reply does not.
        let all = crate::coordinator::metrics::Metrics::new(1);
        let (tx2, _rx2) = sync_channel(8);
        let delivered = ReplyTo::new(7, tx2.clone()).with_cancel_metrics(all.shard(0));
        delivered.send("{\"id\":7}".to_string());
        assert!(all.snapshot_json().contains("\"errors\":0"));
        let cancelled = ReplyTo::new(8, tx2).with_cancel_metrics(all.shard(0));
        drop(cancelled);
        assert!(all.snapshot_json().contains("\"errors\":1"));
    }

    #[test]
    fn watchdog_times_out_wedged_replies_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        let all = crate::coordinator::metrics::Metrics::new(1);
        let window = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = sync_channel(8);
        let reply = ReplyTo::new(31, tx)
            .with_window(window.clone())
            .with_cancel_metrics(all.shard(0));
        assert_eq!(window.load(Ordering::SeqCst), 1);
        let dog = ReplyWatchdog::new(Duration::from_millis(20));
        let p = Pending {
            req: req("digits_linear", 4, SchemeId::Dither, 31),
            respond_to: reply,
            enqueued: Instant::now(),
            trace: None,
        };
        dog.register(std::slice::from_ref(&p));
        assert_eq!(dog.outstanding(), 1);
        // Before the deadline nothing expires.
        assert_eq!(dog.sweep(Instant::now()), 0);
        assert_eq!(dog.outstanding(), 1);
        // Past the deadline the reply is answered `timeout` with its id,
        // the window slot is released, and the timeout is counted.
        assert_eq!(dog.sweep(Instant::now() + Duration::from_millis(25)), 1);
        assert_eq!(dog.outstanding(), 0);
        let line = rx.recv().unwrap();
        assert!(line.contains("timeout") && line.contains("\"id\":31"), "{line}");
        assert_eq!(window.load(Ordering::SeqCst), 0, "timeout releases the slot");
        assert!(all.snapshot_json().contains("\"timeouts\":1"));
        // The wedged worker's late reply is a no-op: no second line, no
        // double slot release, and the drop is not a cancellation.
        p.respond_to.send("{\"id\":31,\"pred\":1}".to_string());
        assert!(rx.try_recv().is_err(), "timed-out reply must answer once");
        assert_eq!(window.load(Ordering::SeqCst), 0);
        assert!(all.snapshot_json().contains("\"errors\":0"));
    }

    #[test]
    fn watchdog_ignores_replies_that_answered_in_time() {
        let all = crate::coordinator::metrics::Metrics::new(1);
        let dog = ReplyWatchdog::new(Duration::from_millis(10));
        let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, 5);
        dog.register(std::slice::from_ref(&p));
        p.respond_to.send("{\"id\":5,\"pred\":2}".to_string());
        // Even an overdue sweep finds the entry completed.
        assert_eq!(dog.sweep(Instant::now() + Duration::from_secs(1)), 0);
        assert_eq!(dog.outstanding(), 0);
        assert!(rx.recv().unwrap().contains("\"pred\""));
        assert!(rx.try_recv().is_err());
        assert!(all.snapshot_json().contains("\"timeouts\":0"));
        // A cancellation (drop) also wins over a later sweep.
        let (p2, rx2) = pending("digits_linear", 4, SchemeId::Dither, 6);
        dog.register(std::slice::from_ref(&p2));
        drop(p2);
        assert_eq!(dog.sweep(Instant::now() + Duration::from_secs(1)), 0);
        assert!(rx2.recv().unwrap().contains("cancelled"));
    }

    #[test]
    fn watchdog_run_loop_sweeps_until_stopped() {
        let dog = Arc::new(ReplyWatchdog::new(Duration::from_millis(20)));
        let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, 9);
        dog.register(std::slice::from_ref(&p));
        let d2 = dog.clone();
        let sweeper = std::thread::spawn(move || d2.run());
        // The sweeper answers the wedged reply within a few ticks.
        let line = rx.recv_timeout(Duration::from_secs(2)).expect("timeout reply");
        assert!(line.contains("timeout"), "{line}");
        dog.stop();
        sweeper.join().unwrap();
        drop(p); // late drop after timeout: no further reply possible
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn auto_requests_share_one_batch_key_and_resolve_per_batch() {
        // Auto requests carry the parse-time placeholder (k=0, Dither):
        // they must coalesce into one batch regardless of budget, and
        // never mix with concrete-key traffic.
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        let mut receivers = Vec::new();
        for (id, budget) in [(1u64, 0.5f64), (2, 2.0), (3, 1.0)] {
            let (tx, rx) = sync_channel(8);
            let mut r = req("digits_linear", 0, SchemeId::Dither, id);
            r.auto = true;
            r.max_mse = Some(budget);
            b.submit(Pending {
                req: r,
                respond_to: ReplyTo::new(id, tx),
                enqueued: Instant::now(),
                trace: None,
            })
            .unwrap();
            receivers.push(rx);
        }
        let (p, _rx) = pending("digits_linear", 4, SchemeId::Dither, 9);
        b.submit(p).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert!(key.is_auto());
        assert_eq!(batch.len(), 3, "adjacent auto requests form one batch");
        // Per-batch resolution: strictest member budget, cold snapshot
        // → the paper-shape prior picks the cheapest feasible k, and the
        // whole batch lands on that single (scheme, k).
        let metrics = crate::coordinator::metrics::Metrics::new(1);
        let snapshot = metrics.handle().auto_snapshot();
        let choice = resolve_auto("digits_linear", &batch, &snapshot).unwrap();
        let strictest = crate::fidelity::choose(
            metrics.shard(0).fidelity(),
            crate::train::ModelSpec::DigitsLinear.index(),
            0.5,
        );
        assert_eq!((choice.scheme, choice.k), (strictest.scheme, strictest.k));
        assert!(choice.k >= 1, "resolution must produce a servable bit width");
        assert!(!choice.any_measured(), "cold snapshot cannot claim a measured choice");
        // The concrete k=4 request stayed behind under its own key.
        let (key2, batch2) = b.next_batch().unwrap();
        assert!(!key2.is_auto());
        assert_eq!(batch2[0].req.id, 9);
        // Unknown models fail resolution with a per-batch error.
        assert!(resolve_auto("nope", &batch, &snapshot).is_err());
    }

    #[test]
    fn budget_less_auto_batches_error_and_latency_only_batches_resolve() {
        // A batch where no member carries a budget on either axis is
        // unreachable through the protocol (parse rejects it), so a
        // hand-built one must surface as an explicit resolution error —
        // not fold to an INFINITY mse budget and silently serve the
        // cheapest candidate.
        let snapshot = AutoSnapshot::default();
        let make = |id: u64, max_latency_us: Option<u64>| {
            let (tx, rx) = sync_channel(8);
            let mut r = req("digits_linear", 0, SchemeId::Dither, id);
            r.auto = true;
            r.max_latency_us = max_latency_us;
            (
                Pending {
                    req: r,
                    respond_to: ReplyTo::new(id, tx),
                    enqueued: Instant::now(),
                    trace: None,
                },
                rx,
            )
        };
        let (p, _rx) = make(1, None);
        let err = resolve_auto("digits_linear", std::slice::from_ref(&p), &snapshot).unwrap_err();
        assert!(err.contains("budget"), "error must name the missing budget: {err}");
        // A latency-only member makes the batch resolvable: the mse axis
        // is then legitimately unbounded, and a cold view reduces to the
        // static cost walk's cheapest candidate.
        let (p, _rx) = make(2, Some(5_000));
        let choice = resolve_auto("digits_linear", std::slice::from_ref(&p), &snapshot).unwrap();
        assert_eq!((choice.scheme, choice.k), (SchemeId::Deterministic, 1));
        assert!(!choice.any_measured());
    }

    #[test]
    fn stop_sends_cancellations_for_queued_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, 11);
        b.submit(p).unwrap();
        b.stop(); // clears the queue, dropping the Pending
        let line = rx.recv().unwrap();
        assert!(line.contains("cancelled") && line.contains("\"id\":11"), "{line}");
    }

    #[test]
    fn pipelined_flood_of_resident_key_does_not_starve_cold_key() {
        // A pipelined connection floods the hot plan-resident key (k=4)
        // faster than the worker drains it, so the queue always holds hot
        // traffic; the lone cold key (k=2) must still be served within the
        // 8×max_wait starvation bound.
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5), 4096));
        b.set_residency(|key: &BatchKey| key.k == 4);
        let bound = b.starvation_bound();

        // Queue the cold request plus an initial hot burst before the
        // worker starts, so the first pick already sees both keys.
        let t0 = Instant::now();
        let (cold, _cold_rx) = pending("digits_linear", 2, SchemeId::Dither, 0);
        b.submit(cold).unwrap();
        let mut receivers = Vec::new();
        let mut id = 1u64;
        for _ in 0..8 {
            let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, id);
            b.submit(p).unwrap();
            receivers.push(rx);
            id += 1;
        }

        // Worker: ~1 ms simulated service per batch, reporting when the
        // cold key is drained and how much hot work preceded it.
        let (served_tx, served_rx) = std::sync::mpsc::channel();
        let wb = b.clone();
        let worker = std::thread::spawn(move || {
            let mut hot_batches = 0usize;
            while let Some((key, _batch)) = wb.next_batch() {
                if key.k == 2 {
                    let _ = served_tx.send((t0.elapsed(), hot_batches));
                } else {
                    hot_batches += 1;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        });

        // Flood: hot submissions outpace the 1 ms/batch service rate for
        // several starvation bounds.
        while t0.elapsed() < bound * 3 {
            let (p, rx) = pending("digits_linear", 4, SchemeId::Dither, id);
            if b.submit(p).is_ok() {
                receivers.push(rx);
            }
            id += 1;
            std::thread::sleep(Duration::from_micros(500));
        }
        b.stop();
        worker.join().unwrap();

        let (waited, hot_before) = served_rx
            .try_recv()
            .expect("cold key must be served during the flood");
        assert!(
            hot_before > 0,
            "resident-key traffic should drain ahead of the cold key first"
        );
        assert!(
            waited <= bound.saturating_mul(3),
            "cold key waited {waited:?}, starvation bound is {bound:?}"
        );
        assert!(
            served_rx.try_recv().is_err(),
            "the cold key must be served exactly once"
        );
    }
}
