//! Per-shard dynamic batcher: a bounded queue that groups
//! same-configuration requests into batches.
//!
//! Requests arriving within `max_wait` that share `(model, k, mode)` are
//! coalesced up to `max_batch` and executed in one engine call — the
//! classic dynamic-batching policy. Each request carries a [`ReplyTo`] —
//! the per-request reply channel back to its connection's writer, tagged
//! with the request id so pipelined completions can return out of order.
//! The queue is bounded (`capacity`):
//! [`Batcher::submit`] rejects instead of growing without limit, which is
//! the server's backpressure signal ([`SubmitError::Overloaded`]).
//!
//! **Plan-aware draining**: when a residency oracle is installed
//! ([`Batcher::set_residency`] — the shard pool points it at the owning
//! engine's plan cache), the batcher prefers to drain keys whose prepared
//! plans are cache-resident, so a cold configuration's replanning cost is
//! not paid in front of hot traffic. Starvation is bounded: once the
//! oldest queued request has waited [`STARVATION_MULT`]× the linger time,
//! its key is drained next regardless of residency.
//!
//! Shutdown has two flavours: [`Batcher::close`] stops intake and lets the
//! worker drain what is queued (graceful), [`Batcher::stop`] aborts after
//! the in-flight batch.

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::protocol::{format_error, format_response, InferenceRequest};
use crate::rounding::RoundingMode;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// How many linger periods the oldest queued request may wait before its
/// key is drained ahead of resident-plan keys (the anti-starvation bound
/// of plan-aware batching).
pub const STARVATION_MULT: u32 = 8;

/// Where one request's response line goes: the submitting connection's
/// writer channel, tagged with the request id so the reply can be matched
/// out of order (pipelined connections funnel every reply through one
/// channel). Dropping a `ReplyTo` without replying — hard shutdown clears
/// shard queues by dropping `Pending`s — sends a `cancelled` error
/// instead, so a pipelined client is never left waiting on an accepted
/// id. When a per-connection in-flight window is attached, delivering (or
/// cancelling) the reply releases its window slot.
pub struct ReplyTo {
    id: u64,
    tx: Sender<String>,
    window: Option<Arc<AtomicUsize>>,
    /// Counts a cancellation as an error in the owning shard's metrics
    /// (the lockstep loop used to record one when a reply channel died).
    cancel_metrics: Option<Arc<ShardMetrics>>,
    replied: bool,
}

impl ReplyTo {
    /// Reply channel for request `id`.
    pub fn new(id: u64, tx: Sender<String>) -> ReplyTo {
        ReplyTo {
            id,
            tx,
            window: None,
            cancel_metrics: None,
            replied: false,
        }
    }

    /// Attach (and occupy) one slot of a connection's in-flight window;
    /// the slot is released when the reply is sent or cancelled.
    pub fn with_window(mut self, window: Arc<AtomicUsize>) -> ReplyTo {
        window.fetch_add(1, Ordering::AcqRel);
        self.window = Some(window);
        self
    }

    /// Record a cancellation (reply dropped unanswered) as an error in
    /// `metrics`, so hard-stopped requests stay visible in `stats`.
    pub fn with_cancel_metrics(mut self, metrics: Arc<ShardMetrics>) -> ReplyTo {
        self.cancel_metrics = Some(metrics);
        self
    }

    /// The request id this reply channel serves.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Deliver the response line. The receiving writer may already be
    /// gone on connection teardown; that send failure is ignored.
    pub fn send(mut self, line: String) {
        self.replied = true;
        let _ = self.tx.send(line);
        // Drop releases the window slot.
    }
}

impl Drop for ReplyTo {
    fn drop(&mut self) {
        if !self.replied {
            let _ = self.tx.send(format_error(self.id, "cancelled"));
            if let Some(metrics) = &self.cancel_metrics {
                metrics.record_error();
            }
        }
        if let Some(window) = &self.window {
            window.fetch_sub(1, Ordering::AcqRel);
        }
    }
}

/// A queued request with its response channel.
pub struct Pending {
    /// The request.
    pub req: InferenceRequest,
    /// Where the response line is sent.
    pub respond_to: ReplyTo,
    /// Enqueue time (for latency accounting).
    pub enqueued: Instant,
}

/// Batch key: requests with equal keys can share one executable call.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BatchKey {
    /// Model family.
    pub model: String,
    /// Bit width.
    pub k: u32,
    /// Rounding scheme.
    pub mode: RoundingMode,
}

impl BatchKey {
    fn of(req: &InferenceRequest) -> BatchKey {
        BatchKey {
            model: req.model.clone(),
            k: req.k,
            mode: req.mode,
        }
    }

    fn matches(&self, req: &InferenceRequest) -> bool {
        req.model == self.model && req.k == self.k && req.mode == self.mode
    }
}

/// Why a [`Batcher::submit`] was refused. The rejected request is handed
/// back so the caller can reply to its client.
pub enum SubmitError {
    /// The bounded queue is full — backpressure; client should retry.
    Overloaded(Pending),
    /// The batcher is closed or stopped (server shutting down).
    Closed(Pending),
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded(p) => write!(f, "Overloaded(id={})", p.req.id),
            SubmitError::Closed(p) => write!(f, "Closed(id={})", p.req.id),
        }
    }
}

/// Shared state between submitters and one shard's batching worker.
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    closed: AtomicBool,
    stopped: AtomicBool,
    /// Plan-residency oracle (set once at shard start): true when a key's
    /// prepared plans are cache-resident in the owning shard's engine.
    residency: OnceLock<Box<dyn Fn(&BatchKey) -> bool + Send + Sync>>,
    /// Maximum batch size per engine call.
    pub max_batch: usize,
    /// How long to linger for more same-key requests.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure threshold).
    pub capacity: usize,
}

impl Batcher {
    /// New batcher with the given policy. `capacity` bounds the queue;
    /// submissions beyond it are rejected with
    /// [`SubmitError::Overloaded`].
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            closed: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            residency: OnceLock::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Install the plan-residency oracle (first call wins; the shard pool
    /// sets it once before traffic). With no oracle the batcher drains in
    /// pure arrival order, exactly as before.
    pub fn set_residency(&self, f: impl Fn(&BatchKey) -> bool + Send + Sync + 'static) {
        let _ = self.residency.set(Box::new(f));
    }

    /// Age past which the oldest queued request's key preempts
    /// resident-plan preference.
    fn starvation_bound(&self) -> Duration {
        self.max_wait
            .saturating_mul(STARVATION_MULT)
            .max(Duration::from_millis(2))
    }

    /// Choose the key the next batch drains: the oldest request's key once
    /// it is over the starvation bound, else the first queued key whose
    /// plans are resident, else the oldest request's key.
    ///
    /// Runs under the queue lock, so the oracle (which takes the engine's
    /// plan-cache lock) is probed once per *distinct* key — the queue
    /// typically holds 1–3 — not once per queued request.
    fn pick_key(&self, q: &VecDeque<Pending>) -> BatchKey {
        let front = q.front().expect("pick_key on a non-empty queue");
        if front.enqueued.elapsed() >= self.starvation_bound() {
            return BatchKey::of(&front.req);
        }
        if let Some(resident) = self.residency.get() {
            let mut probed: Vec<BatchKey> = Vec::new();
            for p in q {
                if probed.iter().any(|k| k.matches(&p.req)) {
                    continue; // this key already probed non-resident
                }
                let key = BatchKey::of(&p.req);
                if resident(&key) {
                    return key;
                }
                probed.push(key);
            }
        }
        BatchKey::of(&front.req)
    }

    /// Enqueue a request; rejects when the queue is full or the batcher is
    /// shutting down.
    pub fn submit(&self, p: Pending) -> Result<(), SubmitError> {
        let mut q = self.queue.lock().unwrap();
        // Flag check under the queue lock: close()/stop() set their flag
        // before taking this lock, so a submitter that sees the flags
        // clear here is guaranteed to enqueue before the worker observes
        // shutdown — the request is drained (close) or cleared (stop),
        // never stranded in a dead queue.
        if self.closed.load(Ordering::SeqCst) || self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed(p));
        }
        if q.len() >= self.capacity {
            return Err(SubmitError::Overloaded(p));
        }
        q.push_back(p);
        drop(q);
        self.notify.notify_one();
        Ok(())
    }

    /// Graceful shutdown: refuse new submissions, let the worker drain the
    /// queue and then exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        // Take the queue lock before notifying: a worker that checked the
        // flag but has not yet parked in `wait` still holds the lock, so
        // this blocks until it parks and the wakeup cannot be lost.
        let _guard = self.queue.lock().unwrap();
        self.notify.notify_all();
    }

    /// Hard shutdown: the worker exits after its in-flight batch; queued
    /// requests are dropped here so their channels close and waiting
    /// clients error out immediately.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.closed.store(true, Ordering::SeqCst);
        let mut q = self.queue.lock().unwrap();
        q.clear(); // drop Pendings -> their Senders -> receivers unblock
        self.notify.notify_all();
    }

    /// True once `close` or `stop` has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// True once `stop` has been called.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::SeqCst)
    }

    /// Current queue depth (approximate under concurrency).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Pull the next batch: blocks until at least one request is queued,
    /// lingers up to `max_wait` for same-key company, then drains up to
    /// `max_batch` matching requests (preserving arrival order of the
    /// rest). Returns `None` on stop, or on close once the queue is empty.
    pub fn next_batch(&self) -> Option<(BatchKey, Vec<Pending>)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            loop {
                if self.is_stopped() {
                    return None;
                }
                if !q.is_empty() {
                    break;
                }
                if self.closed.load(Ordering::SeqCst) {
                    return None; // graceful drain complete
                }
                q = self.notify.wait(q).unwrap();
            }
            let key = self.pick_key(&q);
            // Linger for stragglers while the batch is not full (skipped
            // when shutting down — drain as fast as possible).
            let deadline = Instant::now() + self.max_wait;
            loop {
                let matching = q.iter().filter(|p| key.matches(&p.req)).count();
                if matching >= self.max_batch
                    || Instant::now() >= deadline
                    || self.is_shutting_down()
                {
                    break;
                }
                let (guard, _timeout) = self
                    .notify
                    .wait_timeout(q, deadline.saturating_duration_since(Instant::now()))
                    .unwrap();
                q = guard;
            }
            // Drain matching requests.
            let mut batch = Vec::new();
            let mut rest = VecDeque::with_capacity(q.len());
            while let Some(p) = q.pop_front() {
                if key.matches(&p.req) && batch.len() < self.max_batch {
                    batch.push(p);
                } else {
                    rest.push_back(p);
                }
            }
            *q = rest;
            if !batch.is_empty() {
                return Some((key, batch));
            }
            // stop() cleared the queue while we lingered without the lock;
            // loop back (the stopped check above returns None).
        }
    }
}

/// One shard's batching worker loop: pull → execute → respond. Returns on
/// shutdown (after draining, for a graceful close). `shard` tags response
/// lines so clients can observe the routing.
pub fn worker_loop(batcher: &Batcher, engine: &Engine, metrics: &ShardMetrics, shard: usize) {
    while let Some((key, batch)) = batcher.next_batch() {
        metrics.record_batch(batch.len());
        let size = batch.len();
        let result = {
            let pixel_refs: Vec<&[f64]> = batch.iter().map(|p| p.req.pixels.as_slice()).collect();
            engine.infer_batch(&key.model, key.k, key.mode, &pixel_refs)
        };
        match result {
            Ok(outputs) => {
                for (p, out) in batch.into_iter().zip(outputs) {
                    let latency_us = p.enqueued.elapsed().as_micros() as u64;
                    metrics.record_request(key.mode, latency_us);
                    let line = format_response(
                        p.req.id,
                        out.pred,
                        key.mode,
                        key.k,
                        &out.logits,
                        latency_us,
                        size,
                        shard,
                        p.req.auto,
                    );
                    p.respond_to.send(line);
                }
            }
            Err(e) => {
                for p in batch {
                    metrics.record_error();
                    let id = p.req.id;
                    p.respond_to.send(format_error(id, &e.to_string()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(model: &str, k: u32, mode: RoundingMode, id: u64) -> InferenceRequest {
        InferenceRequest {
            id,
            model: model.to_string(),
            k,
            mode,
            auto: false,
            max_mse: None,
            pixels: vec![0.0; 784],
        }
    }

    fn pending(
        model: &str,
        k: u32,
        mode: RoundingMode,
        id: u64,
    ) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = channel();
        (
            Pending {
                req: req(model, k, mode, id),
                respond_to: ReplyTo::new(id, tx),
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn groups_same_key_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        let (p, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 99);
        b.submit(p).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
        assert_eq!(batch.len(), 3);
        // The k=2 request stays queued.
        let (key2, batch2) = b.next_batch().unwrap();
        assert_eq!(key2.k, 2);
        assert_eq!(batch2.len(), 1);
        assert_eq!(batch2[0].req.id, 99);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(2, Duration::from_millis(1), 64);
        for i in 0..5 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_key() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..4 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Stochastic, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bounded_queue_rejects_overload() {
        let b = Batcher::new(8, Duration::from_millis(1), 2);
        for i in 0..2 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        assert_eq!(b.depth(), 2);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 9);
        match b.submit(p) {
            Err(SubmitError::Overloaded(back)) => assert_eq!(back.req.id, 9),
            other => panic!("expected overload, got {other:?}"),
        }
        assert_eq!(b.depth(), 2, "rejected request must not occupy the queue");
        // Draining frees capacity again.
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.depth(), 0);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 10);
        assert!(b.submit(p).is_ok());
    }

    #[test]
    fn closed_batcher_rejects_submissions() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        b.close();
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        match b.submit(p) {
            Err(SubmitError::Closed(back)) => assert_eq!(back.req.id, 1),
            other => panic!("expected closed, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_queue_then_ends() {
        let b = Batcher::new(2, Duration::from_millis(1), 8);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        b.close();
        // Queued work is still handed out...
        assert_eq!(b.next_batch().unwrap().1.len(), 2);
        assert_eq!(b.next_batch().unwrap().1.len(), 1);
        // ...then the worker is released.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stop_unblocks_worker() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(1), 8));
        let b2 = b.clone();
        let handle = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(20));
        b.stop();
        assert!(handle.join().unwrap());
    }

    #[test]
    fn stop_discards_queued_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(p).unwrap();
        b.stop();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn resident_keys_drain_first_under_mixed_load() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        // Cold key arrives first, resident keys behind it.
        let (p, _rx0) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        for id in 1..4u64 {
            let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, id);
            b.submit(p).unwrap();
            std::mem::forget(rx);
        }
        // The resident k=4 batch jumps the cold k=2 front request...
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4, "resident-plan key must drain first");
        assert_eq!(batch.len(), 3);
        // ...and the cold key is served right after (no residents left).
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2);
        assert_eq!(batch[0].req.id, 0);
    }

    #[test]
    fn cold_key_is_not_starved_by_resident_traffic() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        let (cold, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(cold).unwrap();
        // Let the cold request age past the starvation bound (8× the 1 ms
        // linger), then pile resident traffic behind it.
        std::thread::sleep(b.starvation_bound() + Duration::from_millis(5));
        let (hot, _rx2) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(hot).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "over-age cold key must preempt resident keys");
        assert_eq!(batch[0].req.id, 0);
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
    }

    #[test]
    fn no_oracle_means_pure_arrival_order() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        let (p, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        let (p, _rx2) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(p).unwrap();
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "without residency the front key drains first");
    }

    #[test]
    fn lingers_to_fill_batch() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(200), 64));
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        let b2 = b.clone();
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            for i in 1..4 {
                let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
                b2.submit(p).unwrap();
                std::mem::forget(rx);
            }
        });
        let (_, batch) = b.next_batch().unwrap();
        submitter.join().unwrap();
        assert_eq!(batch.len(), 4, "linger should capture the stragglers");
    }

    #[test]
    fn reply_to_cancels_on_drop_and_releases_window_slot() {
        use std::sync::atomic::AtomicUsize;
        let window = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        // A delivered reply: slot taken while in flight, freed after.
        let reply = ReplyTo::new(5, tx.clone()).with_window(window.clone());
        assert_eq!(reply.id(), 5);
        assert_eq!(window.load(Ordering::SeqCst), 1);
        reply.send("{\"id\":5,\"pred\":1}".to_string());
        assert_eq!(window.load(Ordering::SeqCst), 0);
        assert!(rx.recv().unwrap().contains("\"pred\""));
        // A dropped reply (hard shutdown clears the queue): the client
        // gets a cancelled error and the slot is still released.
        let reply = ReplyTo::new(6, tx).with_window(window.clone());
        assert_eq!(window.load(Ordering::SeqCst), 1);
        drop(reply);
        assert_eq!(window.load(Ordering::SeqCst), 0);
        let line = rx.recv().unwrap();
        assert!(line.contains("cancelled") && line.contains("\"id\":6"), "{line}");
        // With metrics attached, a cancellation counts as an error — a
        // delivered reply does not.
        let all = crate::coordinator::metrics::Metrics::new(1);
        let (tx2, _rx2) = channel();
        let delivered = ReplyTo::new(7, tx2.clone()).with_cancel_metrics(all.shard(0));
        delivered.send("{\"id\":7}".to_string());
        assert!(all.snapshot_json().contains("\"errors\":0"));
        let cancelled = ReplyTo::new(8, tx2).with_cancel_metrics(all.shard(0));
        drop(cancelled);
        assert!(all.snapshot_json().contains("\"errors\":1"));
    }

    #[test]
    fn stop_sends_cancellations_for_queued_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, 11);
        b.submit(p).unwrap();
        b.stop(); // clears the queue, dropping the Pending
        let line = rx.recv().unwrap();
        assert!(line.contains("cancelled") && line.contains("\"id\":11"), "{line}");
    }

    #[test]
    fn pipelined_flood_of_resident_key_does_not_starve_cold_key() {
        // A pipelined connection floods the hot plan-resident key (k=4)
        // faster than the worker drains it, so the queue always holds hot
        // traffic; the lone cold key (k=2) must still be served within the
        // 8×max_wait starvation bound.
        let b = Arc::new(Batcher::new(4, Duration::from_millis(5), 4096));
        b.set_residency(|key: &BatchKey| key.k == 4);
        let bound = b.starvation_bound();

        // Queue the cold request plus an initial hot burst before the
        // worker starts, so the first pick already sees both keys.
        let t0 = Instant::now();
        let (cold, _cold_rx) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(cold).unwrap();
        let mut receivers = Vec::new();
        let mut id = 1u64;
        for _ in 0..8 {
            let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, id);
            b.submit(p).unwrap();
            receivers.push(rx);
            id += 1;
        }

        // Worker: ~1 ms simulated service per batch, reporting when the
        // cold key is drained and how much hot work preceded it.
        let (served_tx, served_rx) = channel();
        let wb = b.clone();
        let worker = std::thread::spawn(move || {
            let mut hot_batches = 0usize;
            while let Some((key, _batch)) = wb.next_batch() {
                if key.k == 2 {
                    let _ = served_tx.send((t0.elapsed(), hot_batches));
                } else {
                    hot_batches += 1;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
        });

        // Flood: hot submissions outpace the 1 ms/batch service rate for
        // several starvation bounds.
        while t0.elapsed() < bound * 3 {
            let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, id);
            if b.submit(p).is_ok() {
                receivers.push(rx);
            }
            id += 1;
            std::thread::sleep(Duration::from_micros(500));
        }
        b.stop();
        worker.join().unwrap();

        let (waited, hot_before) = served_rx
            .try_recv()
            .expect("cold key must be served during the flood");
        assert!(
            hot_before > 0,
            "resident-key traffic should drain ahead of the cold key first"
        );
        assert!(
            waited <= bound.saturating_mul(3),
            "cold key waited {waited:?}, starvation bound is {bound:?}"
        );
        assert!(
            served_rx.try_recv().is_err(),
            "the cold key must be served exactly once"
        );
    }
}
