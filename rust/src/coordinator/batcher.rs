//! Per-shard dynamic batcher: a bounded queue that groups
//! same-configuration requests into batches.
//!
//! Requests arriving within `max_wait` that share `(model, k, mode)` are
//! coalesced up to `max_batch` and executed in one engine call — the
//! classic dynamic-batching policy. Each request carries a oneshot-style
//! channel for its response line. The queue is bounded (`capacity`):
//! [`Batcher::submit`] rejects instead of growing without limit, which is
//! the server's backpressure signal ([`SubmitError::Overloaded`]).
//!
//! **Plan-aware draining**: when a residency oracle is installed
//! ([`Batcher::set_residency`] — the shard pool points it at the owning
//! engine's plan cache), the batcher prefers to drain keys whose prepared
//! plans are cache-resident, so a cold configuration's replanning cost is
//! not paid in front of hot traffic. Starvation is bounded: once the
//! oldest queued request has waited [`STARVATION_MULT`]× the linger time,
//! its key is drained next regardless of residency.
//!
//! Shutdown has two flavours: [`Batcher::close`] stops intake and lets the
//! worker drain what is queued (graceful), [`Batcher::stop`] aborts after
//! the in-flight batch.

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::ShardMetrics;
use crate::coordinator::protocol::{format_error, format_response, InferenceRequest};
use crate::rounding::RoundingMode;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// How many linger periods the oldest queued request may wait before its
/// key is drained ahead of resident-plan keys (the anti-starvation bound
/// of plan-aware batching).
pub const STARVATION_MULT: u32 = 8;

/// A queued request with its response channel.
pub struct Pending {
    /// The request.
    pub req: InferenceRequest,
    /// Where the response line is sent.
    pub respond_to: Sender<String>,
    /// Enqueue time (for latency accounting).
    pub enqueued: Instant,
}

/// Batch key: requests with equal keys can share one executable call.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BatchKey {
    /// Model family.
    pub model: String,
    /// Bit width.
    pub k: u32,
    /// Rounding scheme.
    pub mode: RoundingMode,
}

impl BatchKey {
    fn of(req: &InferenceRequest) -> BatchKey {
        BatchKey {
            model: req.model.clone(),
            k: req.k,
            mode: req.mode,
        }
    }

    fn matches(&self, req: &InferenceRequest) -> bool {
        req.model == self.model && req.k == self.k && req.mode == self.mode
    }
}

/// Why a [`Batcher::submit`] was refused. The rejected request is handed
/// back so the caller can reply to its client.
pub enum SubmitError {
    /// The bounded queue is full — backpressure; client should retry.
    Overloaded(Pending),
    /// The batcher is closed or stopped (server shutting down).
    Closed(Pending),
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Overloaded(p) => write!(f, "Overloaded(id={})", p.req.id),
            SubmitError::Closed(p) => write!(f, "Closed(id={})", p.req.id),
        }
    }
}

/// Shared state between submitters and one shard's batching worker.
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    closed: AtomicBool,
    stopped: AtomicBool,
    /// Plan-residency oracle (set once at shard start): true when a key's
    /// prepared plans are cache-resident in the owning shard's engine.
    residency: OnceLock<Box<dyn Fn(&BatchKey) -> bool + Send + Sync>>,
    /// Maximum batch size per engine call.
    pub max_batch: usize,
    /// How long to linger for more same-key requests.
    pub max_wait: Duration,
    /// Bounded queue capacity (backpressure threshold).
    pub capacity: usize,
}

impl Batcher {
    /// New batcher with the given policy. `capacity` bounds the queue;
    /// submissions beyond it are rejected with
    /// [`SubmitError::Overloaded`].
    pub fn new(max_batch: usize, max_wait: Duration, capacity: usize) -> Batcher {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            closed: AtomicBool::new(false),
            stopped: AtomicBool::new(false),
            residency: OnceLock::new(),
            max_batch: max_batch.max(1),
            max_wait,
            capacity: capacity.max(1),
        }
    }

    /// Install the plan-residency oracle (first call wins; the shard pool
    /// sets it once before traffic). With no oracle the batcher drains in
    /// pure arrival order, exactly as before.
    pub fn set_residency(&self, f: impl Fn(&BatchKey) -> bool + Send + Sync + 'static) {
        let _ = self.residency.set(Box::new(f));
    }

    /// Age past which the oldest queued request's key preempts
    /// resident-plan preference.
    fn starvation_bound(&self) -> Duration {
        self.max_wait
            .saturating_mul(STARVATION_MULT)
            .max(Duration::from_millis(2))
    }

    /// Choose the key the next batch drains: the oldest request's key once
    /// it is over the starvation bound, else the first queued key whose
    /// plans are resident, else the oldest request's key.
    ///
    /// Runs under the queue lock, so the oracle (which takes the engine's
    /// plan-cache lock) is probed once per *distinct* key — the queue
    /// typically holds 1–3 — not once per queued request.
    fn pick_key(&self, q: &VecDeque<Pending>) -> BatchKey {
        let front = q.front().expect("pick_key on a non-empty queue");
        if front.enqueued.elapsed() >= self.starvation_bound() {
            return BatchKey::of(&front.req);
        }
        if let Some(resident) = self.residency.get() {
            let mut probed: Vec<BatchKey> = Vec::new();
            for p in q {
                if probed.iter().any(|k| k.matches(&p.req)) {
                    continue; // this key already probed non-resident
                }
                let key = BatchKey::of(&p.req);
                if resident(&key) {
                    return key;
                }
                probed.push(key);
            }
        }
        BatchKey::of(&front.req)
    }

    /// Enqueue a request; rejects when the queue is full or the batcher is
    /// shutting down.
    pub fn submit(&self, p: Pending) -> Result<(), SubmitError> {
        let mut q = self.queue.lock().unwrap();
        // Flag check under the queue lock: close()/stop() set their flag
        // before taking this lock, so a submitter that sees the flags
        // clear here is guaranteed to enqueue before the worker observes
        // shutdown — the request is drained (close) or cleared (stop),
        // never stranded in a dead queue.
        if self.closed.load(Ordering::SeqCst) || self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Closed(p));
        }
        if q.len() >= self.capacity {
            return Err(SubmitError::Overloaded(p));
        }
        q.push_back(p);
        drop(q);
        self.notify.notify_one();
        Ok(())
    }

    /// Graceful shutdown: refuse new submissions, let the worker drain the
    /// queue and then exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        // Take the queue lock before notifying: a worker that checked the
        // flag but has not yet parked in `wait` still holds the lock, so
        // this blocks until it parks and the wakeup cannot be lost.
        let _guard = self.queue.lock().unwrap();
        self.notify.notify_all();
    }

    /// Hard shutdown: the worker exits after its in-flight batch; queued
    /// requests are dropped here so their channels close and waiting
    /// clients error out immediately.
    pub fn stop(&self) {
        self.stopped.store(true, Ordering::SeqCst);
        self.closed.store(true, Ordering::SeqCst);
        let mut q = self.queue.lock().unwrap();
        q.clear(); // drop Pendings -> their Senders -> receivers unblock
        self.notify.notify_all();
    }

    /// True once `close` or `stop` has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// True once `stop` has been called.
    pub fn is_stopped(&self) -> bool {
        self.stopped.load(Ordering::SeqCst)
    }

    /// Current queue depth (approximate under concurrency).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    /// Pull the next batch: blocks until at least one request is queued,
    /// lingers up to `max_wait` for same-key company, then drains up to
    /// `max_batch` matching requests (preserving arrival order of the
    /// rest). Returns `None` on stop, or on close once the queue is empty.
    pub fn next_batch(&self) -> Option<(BatchKey, Vec<Pending>)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            loop {
                if self.is_stopped() {
                    return None;
                }
                if !q.is_empty() {
                    break;
                }
                if self.closed.load(Ordering::SeqCst) {
                    return None; // graceful drain complete
                }
                q = self.notify.wait(q).unwrap();
            }
            let key = self.pick_key(&q);
            // Linger for stragglers while the batch is not full (skipped
            // when shutting down — drain as fast as possible).
            let deadline = Instant::now() + self.max_wait;
            loop {
                let matching = q.iter().filter(|p| key.matches(&p.req)).count();
                if matching >= self.max_batch
                    || Instant::now() >= deadline
                    || self.is_shutting_down()
                {
                    break;
                }
                let (guard, _timeout) = self
                    .notify
                    .wait_timeout(q, deadline.saturating_duration_since(Instant::now()))
                    .unwrap();
                q = guard;
            }
            // Drain matching requests.
            let mut batch = Vec::new();
            let mut rest = VecDeque::with_capacity(q.len());
            while let Some(p) = q.pop_front() {
                if key.matches(&p.req) && batch.len() < self.max_batch {
                    batch.push(p);
                } else {
                    rest.push_back(p);
                }
            }
            *q = rest;
            if !batch.is_empty() {
                return Some((key, batch));
            }
            // stop() cleared the queue while we lingered without the lock;
            // loop back (the stopped check above returns None).
        }
    }
}

/// One shard's batching worker loop: pull → execute → respond. Returns on
/// shutdown (after draining, for a graceful close). `shard` tags response
/// lines so clients can observe the routing.
pub fn worker_loop(batcher: &Batcher, engine: &Engine, metrics: &ShardMetrics, shard: usize) {
    while let Some((key, batch)) = batcher.next_batch() {
        let pixel_refs: Vec<&[f64]> = batch.iter().map(|p| p.req.pixels.as_slice()).collect();
        metrics.record_batch(batch.len());
        match engine.infer_batch(&key.model, key.k, key.mode, &pixel_refs) {
            Ok(outputs) => {
                for (p, out) in batch.iter().zip(outputs) {
                    let latency_us = p.enqueued.elapsed().as_micros() as u64;
                    metrics.record_request(key.mode, latency_us);
                    let line = format_response(
                        p.req.id,
                        out.pred,
                        key.mode,
                        key.k,
                        &out.logits,
                        latency_us,
                        batch.len(),
                        shard,
                        p.req.auto,
                    );
                    let _ = p.respond_to.send(line);
                }
            }
            Err(e) => {
                for p in &batch {
                    metrics.record_error();
                    let _ = p.respond_to.send(format_error(p.req.id, &e.to_string()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(model: &str, k: u32, mode: RoundingMode, id: u64) -> InferenceRequest {
        InferenceRequest {
            id,
            model: model.to_string(),
            k,
            mode,
            auto: false,
            max_mse: None,
            pixels: vec![0.0; 784],
        }
    }

    fn pending(
        model: &str,
        k: u32,
        mode: RoundingMode,
        id: u64,
    ) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = channel();
        (
            Pending {
                req: req(model, k, mode, id),
                respond_to: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn groups_same_key_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        let (p, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 99);
        b.submit(p).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
        assert_eq!(batch.len(), 3);
        // The k=2 request stays queued.
        let (key2, batch2) = b.next_batch().unwrap();
        assert_eq!(key2.k, 2);
        assert_eq!(batch2.len(), 1);
        assert_eq!(batch2[0].req.id, 99);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(2, Duration::from_millis(1), 64);
        for i in 0..5 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_key() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        for i in 0..4 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Stochastic, i);
            b.submit(p).unwrap();
        }
        let (_, batch) = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bounded_queue_rejects_overload() {
        let b = Batcher::new(8, Duration::from_millis(1), 2);
        for i in 0..2 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        assert_eq!(b.depth(), 2);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 9);
        match b.submit(p) {
            Err(SubmitError::Overloaded(back)) => assert_eq!(back.req.id, 9),
            other => panic!("expected overload, got {other:?}"),
        }
        assert_eq!(b.depth(), 2, "rejected request must not occupy the queue");
        // Draining frees capacity again.
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(b.depth(), 0);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 10);
        assert!(b.submit(p).is_ok());
    }

    #[test]
    fn closed_batcher_rejects_submissions() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        b.close();
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        match b.submit(p) {
            Err(SubmitError::Closed(back)) => assert_eq!(back.req.id, 1),
            other => panic!("expected closed, got {other:?}"),
        }
    }

    #[test]
    fn close_drains_queue_then_ends() {
        let b = Batcher::new(2, Duration::from_millis(1), 8);
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p).unwrap();
        }
        b.close();
        // Queued work is still handed out...
        assert_eq!(b.next_batch().unwrap().1.len(), 2);
        assert_eq!(b.next_batch().unwrap().1.len(), 1);
        // ...then the worker is released.
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn stop_unblocks_worker() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(1), 8));
        let b2 = b.clone();
        let handle = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(20));
        b.stop();
        assert!(handle.join().unwrap());
    }

    #[test]
    fn stop_discards_queued_requests() {
        let b = Batcher::new(8, Duration::from_millis(1), 8);
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(p).unwrap();
        b.stop();
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn resident_keys_drain_first_under_mixed_load() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        // Cold key arrives first, resident keys behind it.
        let (p, _rx0) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        for id in 1..4u64 {
            let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, id);
            b.submit(p).unwrap();
            std::mem::forget(rx);
        }
        // The resident k=4 batch jumps the cold k=2 front request...
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4, "resident-plan key must drain first");
        assert_eq!(batch.len(), 3);
        // ...and the cold key is served right after (no residents left).
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2);
        assert_eq!(batch[0].req.id, 0);
    }

    #[test]
    fn cold_key_is_not_starved_by_resident_traffic() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        b.set_residency(|key: &BatchKey| key.k == 4);
        let (cold, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(cold).unwrap();
        // Let the cold request age past the starvation bound (8× the 1 ms
        // linger), then pile resident traffic behind it.
        std::thread::sleep(b.starvation_bound() + Duration::from_millis(5));
        let (hot, _rx2) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(hot).unwrap();
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "over-age cold key must preempt resident keys");
        assert_eq!(batch[0].req.id, 0);
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
    }

    #[test]
    fn no_oracle_means_pure_arrival_order() {
        let b = Batcher::new(8, Duration::from_millis(1), 64);
        let (p, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        let (p, _rx2) = pending("digits_linear", 4, RoundingMode::Dither, 1);
        b.submit(p).unwrap();
        let (key, _) = b.next_batch().unwrap();
        assert_eq!(key.k, 2, "without residency the front key drains first");
    }

    #[test]
    fn lingers_to_fill_batch() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(200), 64));
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 0);
        b.submit(p).unwrap();
        let b2 = b.clone();
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            for i in 1..4 {
                let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
                b2.submit(p).unwrap();
                std::mem::forget(rx);
            }
        });
        let (_, batch) = b.next_batch().unwrap();
        submitter.join().unwrap();
        assert_eq!(batch.len(), 4, "linger should capture the stragglers");
    }
}
