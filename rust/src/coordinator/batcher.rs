//! Dynamic batcher: groups same-configuration requests into batches.
//!
//! Requests arriving within `max_wait` that share `(model, k, mode)` are
//! coalesced up to `max_batch` and executed in one artifact call — the
//! classic dynamic-batching policy. Each request carries a oneshot-style
//! channel for its response line.

use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{format_error, format_response, InferenceRequest};
use crate::rounding::RoundingMode;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued request with its response channel.
pub struct Pending {
    /// The request.
    pub req: InferenceRequest,
    /// Where the response line is sent.
    pub respond_to: Sender<String>,
    /// Enqueue time (for latency accounting).
    pub enqueued: Instant,
}

/// Batch key: requests with equal keys can share one executable call.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct BatchKey {
    /// Model family.
    pub model: String,
    /// Bit width.
    pub k: u32,
    /// Rounding scheme.
    pub mode: RoundingMode,
}

/// Shared state between submitters and the batching worker.
pub struct Batcher {
    queue: Mutex<VecDeque<Pending>>,
    notify: Condvar,
    shutdown: AtomicBool,
    /// Maximum batch size per executable call.
    pub max_batch: usize,
    /// How long to linger for more same-key requests.
    pub max_wait: Duration,
}

impl Batcher {
    /// New batcher with the given policy.
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher {
        Batcher {
            queue: Mutex::new(VecDeque::new()),
            notify: Condvar::new(),
            shutdown: AtomicBool::new(false),
            max_batch,
            max_wait,
        }
    }

    /// Enqueue a request.
    pub fn submit(&self, p: Pending) {
        self.queue.lock().unwrap().push_back(p);
        self.notify.notify_one();
    }

    /// Request worker shutdown (drains nothing; pending requests error out
    /// when their channels drop).
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.notify.notify_all();
    }

    /// True once `stop` has been called.
    pub fn is_stopped(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// Pull the next batch: blocks until at least one request is queued,
    /// lingers up to `max_wait` for same-key company, then drains up to
    /// `max_batch` matching requests (preserving arrival order of the
    /// rest). Returns `None` on shutdown.
    pub fn next_batch(&self) -> Option<(BatchKey, Vec<Pending>)> {
        let mut q = self.queue.lock().unwrap();
        loop {
            if self.is_stopped() {
                return None;
            }
            if !q.is_empty() {
                break;
            }
            q = self.notify.wait(q).unwrap();
        }
        let key = {
            let first = q.front().unwrap();
            BatchKey {
                model: first.req.model.clone(),
                k: first.req.k,
                mode: first.req.mode,
            }
        };
        // Linger for stragglers while the batch is not full.
        let deadline = Instant::now() + self.max_wait;
        loop {
            let matching = q
                .iter()
                .filter(|p| {
                    p.req.model == key.model && p.req.k == key.k && p.req.mode == key.mode
                })
                .count();
            if matching >= self.max_batch || Instant::now() >= deadline || self.is_stopped() {
                break;
            }
            let (guard, _timeout) = self
                .notify
                .wait_timeout(q, deadline.saturating_duration_since(Instant::now()))
                .unwrap();
            q = guard;
        }
        // Drain matching requests.
        let mut batch = Vec::new();
        let mut rest = VecDeque::with_capacity(q.len());
        while let Some(p) = q.pop_front() {
            let matches = p.req.model == key.model && p.req.k == key.k && p.req.mode == key.mode;
            if matches && batch.len() < self.max_batch {
                batch.push(p);
            } else {
                rest.push_back(p);
            }
        }
        *q = rest;
        Some((key, batch))
    }
}

/// The batching worker loop: pull → execute → respond. Returns on shutdown.
pub fn worker_loop(batcher: &Batcher, engine: &Engine, metrics: &Metrics) {
    while let Some((key, batch)) = batcher.next_batch() {
        let pixel_refs: Vec<&[f64]> = batch.iter().map(|p| p.req.pixels.as_slice()).collect();
        metrics.record_batch(batch.len());
        match engine.infer_batch(&key.model, key.k, key.mode, &pixel_refs) {
            Ok(outputs) => {
                for (p, out) in batch.iter().zip(outputs) {
                    let latency_us = p.enqueued.elapsed().as_micros() as u64;
                    metrics.record_request(latency_us);
                    let line = format_response(
                        p.req.id,
                        out.pred,
                        &out.logits,
                        latency_us,
                        batch.len(),
                    );
                    let _ = p.respond_to.send(line);
                }
            }
            Err(e) => {
                for p in &batch {
                    metrics.record_error();
                    let _ = p.respond_to.send(format_error(p.req.id, &e.to_string()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn req(model: &str, k: u32, mode: RoundingMode, id: u64) -> InferenceRequest {
        InferenceRequest {
            id,
            model: model.to_string(),
            k,
            mode,
            pixels: vec![0.0; 784],
        }
    }

    fn pending(model: &str, k: u32, mode: RoundingMode, id: u64) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = channel();
        (
            Pending {
                req: req(model, k, mode, id),
                respond_to: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn groups_same_key_requests() {
        let b = Batcher::new(8, Duration::from_millis(1));
        for i in 0..3 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p);
        }
        let (p, _rx) = pending("digits_linear", 2, RoundingMode::Dither, 99);
        b.submit(p);
        let (key, batch) = b.next_batch().unwrap();
        assert_eq!(key.k, 4);
        assert_eq!(batch.len(), 3);
        // The k=2 request stays queued.
        let (key2, batch2) = b.next_batch().unwrap();
        assert_eq!(key2.k, 2);
        assert_eq!(batch2.len(), 1);
        assert_eq!(batch2[0].req.id, 99);
    }

    #[test]
    fn respects_max_batch() {
        let b = Batcher::new(2, Duration::from_millis(1));
        for i in 0..5 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
            b.submit(p);
        }
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        let (_, batch) = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_key() {
        let b = Batcher::new(8, Duration::from_millis(1));
        for i in 0..4 {
            let (p, _rx) = pending("digits_linear", 4, RoundingMode::Stochastic, i);
            b.submit(p);
        }
        let (_, batch) = b.next_batch().unwrap();
        let ids: Vec<u64> = batch.iter().map(|p| p.req.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn stop_unblocks_worker() {
        let b = Arc::new(Batcher::new(8, Duration::from_millis(1)));
        let b2 = b.clone();
        let handle = std::thread::spawn(move || b2.next_batch().is_none());
        std::thread::sleep(Duration::from_millis(20));
        b.stop();
        assert!(handle.join().unwrap());
    }

    #[test]
    fn lingers_to_fill_batch() {
        let b = Arc::new(Batcher::new(4, Duration::from_millis(200)));
        let (p, _rx) = pending("digits_linear", 4, RoundingMode::Dither, 0);
        b.submit(p);
        let b2 = b.clone();
        let submitter = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            for i in 1..4 {
                let (p, rx) = pending("digits_linear", 4, RoundingMode::Dither, i);
                b2.submit(p);
                std::mem::forget(rx);
            }
        });
        let (_, batch) = b.next_batch().unwrap();
        submitter.join().unwrap();
        assert_eq!(batch.len(), 4, "linger should capture the stragglers");
    }
}
