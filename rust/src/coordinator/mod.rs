//! L3 coordinator: the sharded serving stack around the quantized engines.
//!
//! The paper's contribution is a numeric format, so the coordinator is a
//! focused (but real) inference server: newline-JSON TCP protocol
//! ([`protocol`]), K worker shards each owning an engine and a bounded
//! dynamic batcher ([`shard`], [`batcher`]), the model zoo + numeric glue
//! ([`engine`]), per-shard lock-free serving metrics ([`metrics`]), and
//! the threaded TCP front-end with hash-routed connections and graceful
//! shutdown ([`server`]). Observability rides alongside: per-request span
//! timelines through [`crate::trace`] (the `trace` wire verb) and a
//! Prometheus text exposition (the `metrics` verb / raw `GET /metrics`).
//!
//! Per-request rounding configuration is the point: a client can A/B
//! deterministic vs stochastic vs dither rounding at any bit width against
//! the same loaded models with one JSON field — the paper's three-way
//! comparison as a live serving scenario.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod server;
pub mod shard;

pub use batcher::{Batcher, Pending, ReplyDeadline, ReplyTo, ReplyWatchdog, SubmitError};
pub use engine::{Engine, InferenceOutput};
pub use metrics::{
    bucket_upper, percentile_from_buckets, Metrics, MetricsHandle, ShardMetrics, BUCKETS,
};
pub use protocol::{
    format_error, format_hello, format_metrics_reply, format_overloaded, format_request,
    format_request_auto, format_request_auto_slo, format_response, format_trace_query,
    format_traces, format_unwatch, format_unwatch_ack, format_watch, format_watch_ack, line_id,
    parse_message, parse_metrics_reply, parse_stats, parse_traces, parse_watch_ack, response_id,
    FidelityCell, InferenceRequest, Message, Reassembler, RecentCell, StatsSummary, TraceQuery,
    WatchQuery, PROTO_VERSION,
};
pub use server::{ping, serve, wait_ready, ServerConfig, WRITER_CONTROL_SLACK};
pub use shard::{ShardConfig, ShardPool};
