//! L3 coordinator: the serving stack around the compiled artifacts.
//!
//! The paper's contribution is a numeric format, so the coordinator is a
//! focused (but real) inference server: newline-JSON TCP protocol
//! ([`protocol`]), dynamic batching by `(model, k, rounding-mode)`
//! ([`batcher`]), model + runtime glue ([`engine`]), serving metrics
//! ([`metrics`]), and the threaded TCP front-end ([`server`]).
//!
//! Per-request rounding configuration is the point: a client can A/B
//! deterministic vs dither rounding at any bit width against the same
//! loaded model with one JSON field.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use batcher::{Batcher, Pending};
pub use engine::{Engine, InferenceOutput};
pub use metrics::Metrics;
pub use protocol::{parse_message, InferenceRequest, Message};
pub use server::{serve, ServerConfig};
