//! The TCP inference server: accept loop, per-connection readers, and the
//! batching workers. Plain threads — the request path is CPU-bound model
//! execution, so an async runtime would buy nothing here.

use crate::coordinator::batcher::{worker_loop, Batcher, Pending};
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::protocol::{format_error, parse_message, Message};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Maximum dynamic-batch size.
    pub max_batch: usize,
    /// Batch linger time in microseconds.
    pub max_wait_us: u64,
    /// Artifacts directory for the engine.
    pub artifacts_dir: String,
    /// Training-set size for the on-demand model zoo.
    pub train_n: usize,
    /// Engine seed.
    pub seed: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            max_batch: 32,
            max_wait_us: 2_000,
            artifacts_dir: "artifacts".to_string(),
            train_n: 2000,
            seed: 7,
        }
    }
}

/// Run the server until a `shutdown` command arrives. Blocks.
///
/// The PJRT handles in [`Engine`] are not `Send` (the `xla` crate wraps
/// them in `Rc`), so the engine is constructed and driven entirely on one
/// dedicated worker thread; connection threads talk to it only through the
/// [`Batcher`] queue. PJRT's CPU executor parallelizes inside a call, so a
/// single execution thread does not serialize the math.
pub fn serve(cfg: &ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true)?;
    let metrics = Arc::new(Metrics::new());
    let batcher = Arc::new(Batcher::new(
        cfg.max_batch,
        Duration::from_micros(cfg.max_wait_us),
    ));

    // Engine thread: builds the engine (training/loading models, compiling
    // artifacts) and then runs the batch loop until shutdown.
    let (ready_tx, ready_rx) = channel();
    let engine_thread = {
        let b = batcher.clone();
        let m = metrics.clone();
        let cfg = cfg.clone();
        std::thread::spawn(move || {
            let engine = match Engine::new(&cfg.artifacts_dir, cfg.train_n, cfg.seed) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(format!(
                        "platform={} digits_acc={:.3} fashion_acc={:.3}",
                        e.runtime().platform(),
                        e.float_accuracy("digits_linear").unwrap_or(0.0),
                        e.float_accuracy("fashion_mlp").unwrap_or(0.0),
                    )));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            worker_loop(&b, &engine, &m);
        })
    };
    match ready_rx.recv() {
        Ok(Ok(info)) => println!(
            "dither-serve listening on {} ({info}, max_batch={})",
            cfg.addr, cfg.max_batch
        ),
        Ok(Err(e)) => anyhow::bail!("engine init failed: {e}"),
        Err(_) => anyhow::bail!("engine thread died during init"),
    }

    let mut conn_handles = Vec::new();
    while !batcher.is_stopped() {
        match listener.accept() {
            Ok((stream, _addr)) => {
                let b = batcher.clone();
                let m = metrics.clone();
                conn_handles.push(std::thread::spawn(move || {
                    let _ = handle_connection(stream, &b, &m);
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    let _ = engine_thread.join();
    for h in conn_handles {
        let _ = h.join();
    }
    println!("dither-serve stopped");
    Ok(())
}

/// Read request lines, dispatch, write response lines. One thread per
/// connection; inference requests are answered in submission order.
fn handle_connection(stream: TcpStream, batcher: &Batcher, metrics: &Metrics) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_message(&line) {
            Ok(Message::Ping) => writeln!(writer, "{{\"pong\":true}}")?,
            Ok(Message::Stats) => writeln!(writer, "{}", metrics.snapshot_json())?,
            Ok(Message::Shutdown) => {
                writeln!(writer, "{{\"stopping\":true}}")?;
                batcher.stop();
                break;
            }
            Ok(Message::Infer(req)) => {
                let (tx, rx) = channel();
                batcher.submit(Pending {
                    req,
                    respond_to: tx,
                    enqueued: Instant::now(),
                });
                // Wait for this request's response before reading the next
                // line (pipelining happens across connections).
                match rx.recv_timeout(Duration::from_secs(120)) {
                    Ok(response) => writeln!(writer, "{response}")?,
                    Err(_) => {
                        metrics.record_error();
                        writeln!(writer, "{}", format_error(0, "timeout"))?;
                    }
                }
            }
            Err(e) => {
                metrics.record_error();
                writeln!(writer, "{}", format_error(0, &e))?;
            }
        }
        writer.flush()?;
    }
    Ok(())
}
