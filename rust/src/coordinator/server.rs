//! The TCP inference server: accept loop, per-connection reader/writer
//! pairs, and the sharded batching core. Plain threads — the request path
//! is CPU-bound model execution, so an async runtime would buy nothing
//! here.
//!
//! Scale shape: the accept loop hash-routes each connection onto one of K
//! serving shards ([`crate::coordinator::shard`]); connection threads only
//! touch their shard's bounded queue and metrics slot, so adding shards
//! adds throughput without adding contention.
//!
//! **Pipelined connections**: each connection is split into a reader that
//! keeps parsing request lines and submitting them to the shard's batcher
//! without waiting for replies, and a writer thread that drains completed
//! responses in completion order (out of order with respect to
//! submission; every line echoes its request id). One connection can
//! therefore keep its shard's batcher full — exactly what dynamic
//! batching needs when large `k` makes per-request latency highest. A
//! bounded per-connection in-flight window (`--max-inflight`)
//! backpressures clients that outrun the server: requests beyond the
//! window are answered `overloaded` immediately, carrying the offending
//! id.
//!
//! Shutdown is graceful: the `shutdown` command stops intake everywhere,
//! shards drain their queues, every accepted request's reply (each holds
//! a clone of its connection's writer channel) is delivered, and every
//! thread is joined before `serve` returns.
//!
//! Two resource bounds ride on the reply path: the reader→writer channel
//! is **bounded** (`max_inflight +` [`WRITER_CONTROL_SLACK`]), so a
//! connection's reply backlog cannot grow without limit, and the shard
//! pool's **reply watchdog** (`--reply-timeout-ms`) answers `timeout` for
//! any accepted request whose engine call wedges past the deadline,
//! releasing its window slot and its hold on the writer channel.

use crate::coordinator::batcher::{Pending, ReplyTo, SubmitError};
use crate::coordinator::metrics::{Metrics, ShardMetrics};
use crate::coordinator::protocol::{
    format_error, format_hello, format_metrics_reply, format_overloaded, format_traces,
    format_unwatch_ack, format_watch_ack, line_id, parse_message, InferenceRequest, Message,
    PROTO_VERSION,
};
use crate::coordinator::shard::{ShardConfig, ShardPool};
use crate::obs::{self, EventKind, Journal, Severity, SloPolicy, Subscription};
use crate::trace::{PromText, Stage, TraceConfig};
use crate::train::Zoo;
use crate::util::error::{Context, Result};
use crate::util::threadpool::WorkerPool;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SendError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Writer-channel headroom beyond the in-flight window: control replies
/// (`pong`, `hello`, `stats`, parse errors, the shutdown ack) share the
/// funnel with request completions, but the reader submits them one at a
/// time, so a small constant bounds them. The channel is sized
/// `max_inflight + WRITER_CONTROL_SLACK`.
///
/// Trade-off (the deliberate point of the bound): window slots release
/// when a reply is *queued*, not when the socket drains, so a client
/// that pipelines aggressively and stops reading can fill the channel —
/// a worker completing one of its requests then blocks in the send until
/// the writer's 30 s write timeout tears the connection down (after
/// which every send fails fast). That briefly convoys other connections
/// on the same shard; the previous unbounded channel never blocked, but
/// let one such client grow the reply backlog without limit. See the
/// ROADMAP follow-up on decoupling slot release from channel occupancy.
pub const WRITER_CONTROL_SLACK: usize = 8;

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Listen address, e.g. `127.0.0.1:7878`.
    pub addr: String,
    /// Number of serving shards (0 = one per core, capped at 16;
    /// explicit values are clamped to 1..=64).
    pub shards: usize,
    /// Maximum dynamic-batch size per shard.
    pub max_batch: usize,
    /// Batch linger time in microseconds.
    pub max_wait_us: u64,
    /// Bounded per-shard queue capacity (overload threshold).
    pub queue_cap: usize,
    /// Training-set size for the on-demand model zoo.
    pub train_n: usize,
    /// Base seed for the per-shard engine rounding streams.
    pub seed: u64,
    /// Bit widths prewarmed into every shard's plan cache at startup
    /// (the paper's trio of schemes, every model). Empty disables
    /// prewarming.
    pub prewarm_bits: Vec<u32>,
    /// Fraction of request rows shadow-checked against the exact f64
    /// forward pass (feeds `stats.fidelity` and the auto controller;
    /// 0 disables).
    pub shadow_rate: f64,
    /// Per-shard plan-cache byte budget in MiB (0 disables plan caching).
    pub plan_cache_mb: usize,
    /// Per-connection bound on requests in flight (accepted but not yet
    /// answered). Pipelined requests beyond the window get an immediate
    /// `overloaded` reply carrying their id. Clamped to ≥ 1.
    pub max_inflight: usize,
    /// Reply-watchdog deadline in milliseconds: an accepted request still
    /// unanswered this long after its batch dispatched is answered
    /// `timeout` (releasing its window slot). 0 disables the watchdog.
    pub reply_timeout_ms: u64,
    /// Fraction of admitted requests sampled for end-to-end tracing
    /// (`--trace-rate`; 0 disables sampling).
    pub trace_rate: f64,
    /// Slow-trace promotion threshold in µs (`--trace-slow-us`): any
    /// request at least this slow is traced regardless of sampling.
    /// 0 disables promotion.
    pub trace_slow_us: u64,
    /// Completed-trace ring-buffer capacity (`--trace-buffer`; 0 disables
    /// tracing entirely).
    pub trace_buffer: usize,
    /// SLO latency budget in µs for burn-rate alerting
    /// (`--slo-p99-us`; 0 disables the latency alert).
    pub slo_p99_us: u64,
    /// SLO error-rate threshold — errors + timeouts per request — for
    /// burn-rate alerting (`--slo-error-rate`; 0 disables).
    pub slo_error_rate: f64,
    /// Measured-MSE alert envelope as a multiple of the analytic prior
    /// per `(model, scheme, k)` (`--slo-mse-factor`; 0 disables).
    pub slo_mse_factor: f64,
    /// SLO evaluator tick in milliseconds (`--slo-eval-ms`; 0 disables
    /// the evaluator thread entirely).
    pub slo_eval_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            shards: 0,
            max_batch: 32,
            max_wait_us: 2_000,
            queue_cap: 256,
            train_n: 2000,
            seed: 7,
            prewarm_bits: vec![2, 4, 8],
            shadow_rate: 0.02,
            plan_cache_mb: 64,
            max_inflight: 64,
            reply_timeout_ms: 120_000,
            trace_rate: 0.0,
            trace_slow_us: 0,
            trace_buffer: 256,
            slo_p99_us: 0,
            slo_error_rate: 0.0,
            // Fidelity drift is the silent failure mode this system exists
            // to prevent, so the MSE envelope alert defaults on; latency
            // and error-rate budgets are deployment-specific and default
            // off.
            slo_mse_factor: 8.0,
            slo_eval_ms: 1_000,
        }
    }
}

impl ServerConfig {
    fn shard_config(&self) -> ShardConfig {
        let shards = if self.shards == 0 {
            crate::util::threadpool::num_threads().clamp(1, 16)
        } else {
            // Each shard is an OS thread + engine seed stream; clamp
            // explicit values so a config typo cannot exhaust the process.
            self.shards.clamp(1, 64)
        };
        ShardConfig {
            shards,
            max_batch: self.max_batch,
            max_wait: Duration::from_micros(self.max_wait_us),
            queue_cap: self.queue_cap,
            seed: self.seed,
            prewarm_bits: self.prewarm_bits.clone(),
            shadow_rate: self.shadow_rate,
            plan_cache_bytes: self.plan_cache_mb << 20,
            reply_timeout: Duration::from_millis(self.reply_timeout_ms),
            trace: TraceConfig {
                rate: self.trace_rate,
                slow_us: self.trace_slow_us,
                buffer: self.trace_buffer,
            },
            slo: SloPolicy {
                p99_us: self.slo_p99_us,
                error_rate: self.slo_error_rate,
                mse_factor: self.slo_mse_factor,
                eval_ms: self.slo_eval_ms,
            },
        }
    }
}

/// Run the server until a `shutdown` command arrives. Blocks.
///
/// The model zoo is trained/loaded once and shared read-only across all
/// shards; each shard runs its own engine + batcher worker thread.
pub fn serve(cfg: &ServerConfig) -> Result<()> {
    let listener = TcpListener::bind(&cfg.addr)
        .with_context(|| format!("binding {}", cfg.addr))?;
    listener.set_nonblocking(true)?;
    let shard_cfg = cfg.shard_config();
    let metrics = Arc::new(Metrics::new(shard_cfg.shards));

    println!(
        "dither-serve: loading model zoo (train_n={}) ...",
        cfg.train_n
    );
    let zoo = Arc::new(Zoo::load(cfg.train_n, cfg.seed));
    for m in zoo.models() {
        println!(
            "  {:<14} float test accuracy {:.3}",
            m.spec.name(),
            m.float_accuracy
        );
    }
    if !shard_cfg.prewarm_bits.is_empty() {
        println!(
            "dither-serve: prewarming plan caches for k in {:?} (all schemes) ...",
            shard_cfg.prewarm_bits
        );
    }
    let journal = Arc::new(Journal::default());
    journal.publish(
        Severity::Info,
        EventKind::ProcessStart,
        &[
            ("kernel", crate::kernels::active_id().name()),
            ("schemes", &scheme_names()),
        ],
    );
    let pool = Arc::new(ShardPool::start(&shard_cfg, zoo, &metrics, journal));
    println!(
        "dither-serve listening on {} ({} shards, max_batch={}, queue_cap={}, kernel={})",
        cfg.addr,
        pool.num_shards(),
        cfg.max_batch,
        cfg.queue_cap,
        crate::kernels::active_id().name()
    );

    let mut conns = WorkerPool::new();
    let mut conn_id = 0u64;
    let max_inflight = cfg.max_inflight.max(1);
    while !pool.is_shutting_down() {
        match listener.accept() {
            Ok((stream, _addr)) => {
                conn_id += 1;
                let id = conn_id;
                let pool = pool.clone();
                let metrics = metrics.clone();
                conns.spawn(format!("dither-conn-{id}"), move || {
                    let _ = handle_connection(stream, id, &pool, &metrics, max_inflight);
                });
                // Reap periodically under sustained accept load too, not
                // just on idle ticks, so dead handles stay bounded.
                if conn_id % 64 == 0 {
                    conns.reap_finished();
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // Idle tick: reap finished connection threads so the
                // handle list stays proportional to live connections.
                conns.reap_finished();
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                pool.stop();
                pool.join();
                return Err(e.into());
            }
        }
    }
    let panicked = pool.join();
    conns.join_all();
    println!("dither-serve stopped");
    if panicked > 0 {
        crate::bail!("{panicked} shard worker(s) panicked");
    }
    Ok(())
}

/// One ping round-trip against a server at `addr`; true on a `pong`.
/// Connect and read are both bounded by a 10 s timeout.
pub fn ping(addr: &str) -> bool {
    ping_within(addr, Duration::from_secs(10))
}

fn ping_within(addr: &str, io_timeout: Duration) -> bool {
    use std::net::ToSocketAddrs;
    let Ok(mut addrs) = addr.to_socket_addrs() else {
        return false;
    };
    let Some(sock) = addrs.next() else {
        return false;
    };
    let Ok(stream) = TcpStream::connect_timeout(&sock, io_timeout) else {
        return false;
    };
    let Ok(clone) = stream.try_clone() else {
        return false;
    };
    let mut reader = BufReader::new(clone);
    let mut writer = stream;
    if writer.set_read_timeout(Some(io_timeout)).is_err()
        || writeln!(writer, "{{\"cmd\":\"ping\"}}").is_err()
    {
        return false;
    }
    let mut line = String::new();
    reader.read_line(&mut line).is_ok() && line.contains("pong")
}

/// Block until the server at `addr` answers a ping, up to `timeout`
/// (clients and tests use this to wait out the zoo's first-run training).
/// Returns false if the deadline passes first; each attempt's I/O is
/// bounded by the remaining budget so a blackholed address cannot
/// overshoot the deadline by the OS connect timeout.
pub fn wait_ready(addr: &str, timeout: Duration) -> bool {
    let deadline = Instant::now() + timeout;
    loop {
        let remaining = deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return false;
        }
        let budget = remaining
            .min(Duration::from_secs(10))
            .max(Duration::from_millis(100));
        if ping_within(addr, budget) {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// One pipelined connection: a reader (this thread) that parses request
/// lines and submits them to the connection's shard without waiting for
/// replies, plus a writer thread that drains completed responses out of
/// order. Every reply funnels through one mpsc channel — control acks and
/// per-request [`ReplyTo`] completions alike — so the socket has a single
/// writer and the drain-on-shutdown guarantee falls out of channel
/// disconnection: the writer exits only after the reader and every
/// in-flight reply sender are gone.
fn handle_connection(
    stream: TcpStream,
    conn_id: u64,
    pool: &ShardPool,
    metrics: &Metrics,
    max_inflight: usize,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(Duration::from_millis(250)))?;
    // Bounded writes: a client that stops reading its socket would
    // otherwise park the writer thread forever once the TCP send buffer
    // fills. On write timeout the writer exits; the reader notices on its
    // next send and abandons the connection.
    let write_half = stream.try_clone()?;
    write_half.set_write_timeout(Some(Duration::from_secs(30)))?;
    // Bounded reply funnel: a connection's reply backlog can no longer
    // grow without bound. A sender blocking on a full channel is the
    // designed backpressure and is bounded by the writer's write timeout
    // (see WRITER_CONTROL_SLACK for the trade-off).
    let (tx, rx) = sync_channel::<String>(max_inflight + WRITER_CONTROL_SLACK);
    // Writer-death flag: accepted infer requests never touch `tx`
    // directly (their replies flow through ReplyTo sends, whose failures
    // are ignored), so the reader polls this to tear the connection down
    // instead of serving a dead socket forever.
    let writer_alive = Arc::new(AtomicBool::new(true));
    let alive = writer_alive.clone();
    let shard = pool.route(conn_id);
    let writer_metrics = metrics.shard(shard);
    let writer = std::thread::Builder::new()
        .name(format!("dither-conn-{conn_id}-writer"))
        .spawn(move || writer_loop(write_half, rx, &alive, &writer_metrics))?;
    let result = read_loop(stream, shard, pool, metrics, max_inflight, &tx, &writer_alive);
    // Drop the reader's sender so the writer exits once every in-flight
    // reply (each ReplyTo holds a clone) has been delivered — this is
    // what drains all accepted ids when shutdown lands mid-stream.
    drop(tx);
    let _ = writer.join();
    result
}

/// The connection's writer half: drain response lines in completion
/// order. Ready lines are coalesced into one flush so a burst of batch
/// completions costs one syscall, not one per reply (each flush and its
/// line count feed the connection's shard metrics). Clears `alive` on
/// exit so the reader notices a dead socket even when no control reply
/// ever fails.
fn writer_loop(
    stream: TcpStream,
    rx: Receiver<String>,
    alive: &AtomicBool,
    metrics: &ShardMetrics,
) {
    drain_replies(stream, rx, alive, |lines| metrics.record_flush(lines));
}

/// The writer-drain protocol shared by the server's connection writers
/// and the cluster proxy's client writers: pull one line, greedily append
/// every other ready line, flush once, report the coalesced count, exit
/// on any socket error and clear `alive` so the reader side tears down.
pub(crate) fn drain_replies(
    stream: TcpStream,
    rx: Receiver<String>,
    alive: &AtomicBool,
    mut on_flush: impl FnMut(usize),
) {
    let mut out = BufWriter::new(stream);
    'drain: while let Ok(line) = rx.recv() {
        let mut lines = 1usize;
        if writeln!(out, "{line}").is_err() {
            break 'drain;
        }
        while let Ok(more) = rx.try_recv() {
            if writeln!(out, "{more}").is_err() {
                break 'drain;
            }
            lines += 1;
        }
        if out.flush().is_err() {
            break 'drain;
        }
        on_flush(lines);
    }
    alive.store(false, Ordering::Release);
}

/// The connection's reader half: parse request lines and dispatch them.
/// The read loop ticks on a short timeout so the thread notices server
/// shutdown even while a client keeps the socket open; a failed send to
/// the writer channel means the socket died and ends the connection.
#[allow(clippy::too_many_arguments)]
fn read_loop(
    stream: TcpStream,
    shard: usize,
    pool: &ShardPool,
    metrics: &Metrics,
    max_inflight: usize,
    tx: &SyncSender<String>,
    writer_alive: &AtomicBool,
) -> Result<()> {
    let shard_metrics = metrics.shard(shard);
    // Accepted-but-unanswered requests on this connection. Incremented
    // here (via ReplyTo::with_window), decremented by each ReplyTo as its
    // reply or cancellation goes out; this thread is the only
    // incrementer, so the window check below cannot race over the bound.
    // Control verbs (ping/hello/stats/trace/metrics/watch/unwatch) never
    // touch the window — they stay answerable even at `max_inflight=1`
    // with the lone slot pinned by a slow request.
    let inflight = Arc::new(AtomicUsize::new(0));
    // This connection's live journal subscriptions. Their queues fill on
    // the publisher side; this loop is the only drain.
    let mut watches: Vec<Arc<Subscription>> = Vec::new();
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut result: Result<()> = Ok(());
    loop {
        // Writer gone (socket closed or write timed out): abandon the
        // connection instead of feeding the engine from a dead client.
        // Checked every iteration — read timeout ticks land here too.
        if !writer_alive.load(Ordering::Acquire) {
            break;
        }
        // Push pending watch events toward the writer. `try_send` keeps
        // the reader from blocking on its own reply funnel: when the
        // channel is full the line goes back to the front of its
        // subscription queue and delivery resumes on a later iteration
        // (the 250 ms read timeout guarantees pump progress even on an
        // otherwise idle connection).
        'pump: for sub in &watches {
            while let Some(event_line) = sub.pop() {
                match tx.try_send(event_line) {
                    Ok(()) => {}
                    Err(std::sync::mpsc::TrySendError::Full(l)) => {
                        sub.requeue_front(l);
                        break 'pump;
                    }
                    // Writer exited; the alive check above ends the
                    // connection next iteration.
                    Err(std::sync::mpsc::TrySendError::Disconnected(_)) => break 'pump,
                }
            }
        }
        // `read_line` appends, so a partial line survives a timeout tick
        // and completes on the next read.
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if pool.is_shutting_down() {
                    break;
                }
                continue;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                result = Err(e.into());
                break;
            }
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            line.clear();
            continue;
        }
        // Raw HTTP scrape support: a real Prometheus server speaks
        // `GET /metrics HTTP/1.1`, not newline JSON. Serve one exposition
        // response and close, like any HTTP/1.0 endpoint would.
        if trimmed.starts_with("GET ") {
            let _ = tx.send(http_metrics_response(&exposition(metrics, pool)));
            break;
        }
        // Clock reads for the parse span only happen when tracing can
        // observe them (`--trace-rate 0 --trace-slow-us 0` reads none).
        let parse_start = pool.tracer().enabled().then(Instant::now);
        let mut stop = false;
        let sent = match parse_message(trimmed) {
            Ok(Message::Ping) => tx.send("{\"pong\":true}".to_string()),
            Ok(Message::Hello) => tx.send(format_hello(
                max_inflight,
                &crate::rounding::SchemeRegistry::global().wire_names(),
                crate::kernels::active_id().name(),
            )),
            Ok(Message::Stats) => tx.send(metrics.snapshot_json()),
            Ok(Message::Trace(q)) => {
                let tracer = pool.tracer();
                tx.send(format_traces(&tracer.query(
                    q.min_us,
                    q.model.as_deref(),
                    q.scheme.as_deref(),
                    q.limit,
                )))
            }
            Ok(Message::Metrics) => tx.send(format_metrics_reply(&exposition(metrics, pool))),
            Ok(Message::Watch(q)) => {
                let sub =
                    pool.journal()
                        .subscribe(q.severity.unwrap_or(Severity::Info), q.kinds, 0);
                let ack = format_watch_ack(sub.id());
                watches.push(sub);
                tx.send(ack)
            }
            Ok(Message::Unwatch(id)) => {
                // Only this connection's own subscriptions can be torn
                // down — a connection cannot unwatch someone else's id.
                let removed =
                    watches.iter().any(|s| s.id() == id) && pool.journal().unsubscribe(id);
                watches.retain(|s| s.id() != id);
                tx.send(format_unwatch_ack(id, removed))
            }
            Ok(Message::Shutdown) => {
                pool.close();
                stop = true;
                tx.send("{\"stopping\":true}".to_string())
            }
            Ok(Message::Infer(req)) => handle_infer(
                req,
                shard,
                pool,
                &shard_metrics,
                &inflight,
                max_inflight,
                parse_start,
                tx,
            ),
            Err(e) => {
                shard_metrics.record_error();
                // Echo the id when the malformed line carried one, so a
                // pipelined client can attribute the failure. Malformed
                // lines (unknown schemes included) never parse on retry.
                tx.send(format_error(line_id(trimmed), &e, false))
            }
        };
        if sent.is_err() {
            break; // writer gone: socket closed or write timed out
        }
        line.clear();
        if stop {
            break;
        }
    }
    // Tear down this connection's subscriptions on every exit path so
    // the journal stops queueing events for a dead watcher.
    for sub in &watches {
        pool.journal().unsubscribe(sub.id());
    }
    result
}

/// Comma-joined wire names of every registered rounding scheme, for the
/// build-info gauge and the process-start event.
fn scheme_names() -> String {
    crate::rounding::SchemeRegistry::global()
        .wire_names()
        .join(",")
}

/// The full Prometheus exposition for this process: the request/engine
/// families from [`Metrics::prometheus`] plus the journal's event and
/// alert families and the build-identity gauges. Served on both the
/// `GET /metrics` fast path and the `{"cmd":"metrics"}` verb.
fn exposition(metrics: &Metrics, pool: &ShardPool) -> String {
    let mut text = metrics.prometheus(pool.tracer());
    let mut extra = PromText::new();
    pool.journal().append_prometheus(&mut extra);
    obs::append_build_info(
        &mut extra,
        &format!("{}", PROTO_VERSION as u32),
        crate::kernels::active_id().name(),
        &scheme_names(),
    );
    text.push_str(&extra.finish());
    text
}

/// A minimal HTTP/1.0 response carrying the Prometheus exposition, for
/// scrapers that speak `GET /metrics` at the TCP port instead of the
/// `{"cmd":"metrics"}` verb. Shared by the server and the cluster proxy.
pub(crate) fn http_metrics_response(body: &str) -> String {
    format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

/// Dispatch one inference request: enforce the in-flight window and
/// submit to the shard's batcher. Auto-precision requests keep their
/// parse-time placeholder key — the shard worker resolves the concrete
/// `(scheme, k)` once per drained batch, so adjacent auto requests
/// coalesce onto one engine call. Never blocks on the reply — completion
/// flows back through the [`ReplyTo`] into the connection's writer
/// channel. Admitted requests get their trace context here (a local
/// sampling decision, or adoption of a proxy-propagated `"trace"` tag)
/// with the parse and admit spans already stamped.
#[allow(clippy::too_many_arguments)]
fn handle_infer(
    req: InferenceRequest,
    shard: usize,
    pool: &ShardPool,
    shard_metrics: &Arc<ShardMetrics>,
    inflight: &Arc<AtomicUsize>,
    max_inflight: usize,
    parse_start: Option<Instant>,
    tx: &SyncSender<String>,
) -> std::result::Result<(), SendError<String>> {
    // Deprecated-alias telemetry: counted per use, before any outcome.
    if req.deprecated_mode {
        shard_metrics.record_deprecated_field();
    }
    let admit_start = parse_start.is_some().then(Instant::now);
    // Window first: a bounced request only needs its id echoed back.
    if inflight.load(Ordering::Acquire) >= max_inflight {
        shard_metrics.record_rejected();
        return tx.send(format_overloaded(req.id));
    }
    // Only *admitted* requests get a trace context; upstream-propagated
    // tags keep the proxy's sampling decision (and trace id).
    let tracer = pool.tracer();
    let mut trace = match req.trace {
        Some((id, flags)) => tracer.adopt(req.id, id, flags),
        None => tracer.begin(req.id),
    };
    if let Some(b) = trace.as_deref_mut() {
        let admitted = Instant::now();
        if let (Some(parse), Some(admit)) = (parse_start, admit_start) {
            b.span(Stage::Parse, parse, admit);
            b.span(Stage::Admit, admit, admitted);
        }
    }
    let respond_to = ReplyTo::new(req.id, tx.clone())
        .with_window(inflight.clone())
        .with_cancel_metrics(shard_metrics.clone());
    let submitted = pool.submit(
        shard,
        Pending {
            req,
            respond_to,
            enqueued: Instant::now(),
            trace,
        },
    );
    match submitted {
        Ok(()) => {}
        Err(SubmitError::Overloaded(p)) => {
            shard_metrics.record_rejected();
            let id = p.req.id;
            p.respond_to.send(format_overloaded(id));
        }
        Err(SubmitError::Closed(p)) => {
            shard_metrics.record_error();
            let id = p.req.id;
            // Shutdown is transient from the client's point of view: the
            // same request can succeed against a restarted server.
            p.respond_to.send(format_error(id, "shutting down", true));
        }
    }
    Ok(())
}
