//! The sharded serving core: K worker shards, each owning an [`Engine`]
//! and a bounded dynamic [`Batcher`], with connections hash-routed onto
//! shards.
//!
//! Sharding is what lets the coordinator scale with cores: every shard has
//! its own queue, its own batching worker, its own engine seed stream and
//! its own metrics slot, so the request hot path shares no locks between
//! shards (the model weights are shared read-only through `Arc<Zoo>`).
//! Routing is by connection, not by request, so one client's pipelined
//! requests all land in a single shard's batcher (responses may complete
//! out of order; the id echo matches them up client-side).

use crate::coordinator::batcher::{
    worker_loop, BatchKey, Batcher, Pending, ReplyWatchdog, SubmitError,
};
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::fidelity::{prior_mse, AutoSnapshot, AutoView, MAX_K};
use crate::linalg::Variant;
use crate::nn::PlanKey;
use crate::obs::{Journal, MseCell, SloEvaluator, SloPolicy};
use crate::rounding::SchemeId;
use crate::trace::{TraceConfig, Tracer};
use crate::train::{ModelSpec, Zoo};
use crate::util::rng::counter_hash;
use crate::util::threadpool::WorkerPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How often the pool's refresher thread merges every shard's estimators
/// and recent-latency windows into a fresh [`AutoView`] snapshot. Short
/// enough that a latency regression redirects auto traffic within a
/// fraction of one metrics window, long enough to keep the merge off the
/// request hot path.
const AUTO_VIEW_REFRESH: Duration = Duration::from_millis(50);

/// How often the SLO evaluator thread checks the stop flag between
/// ticks, so a 1 s `--slo-eval-ms` cadence never holds shutdown hostage
/// for a full tick.
const SLO_POLL: Duration = Duration::from_millis(25);

/// Shard-pool policy.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of worker shards (≥ 1).
    pub shards: usize,
    /// Maximum dynamic-batch size per shard.
    pub max_batch: usize,
    /// Batch linger time.
    pub max_wait: Duration,
    /// Bounded per-shard queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Base seed for the per-shard engine rounding streams.
    pub seed: u64,
    /// Bit widths whose weight-side plans are prewarmed (the paper's trio
    /// of schemes, every model) into each shard's plan cache before
    /// traffic is accepted. Empty disables prewarming.
    pub prewarm_bits: Vec<u32>,
    /// Fraction of request rows shadow-checked against the exact f64
    /// forward pass per shard (0 disables shadow sampling).
    pub shadow_rate: f64,
    /// Per-shard plan-cache byte budget (0 disables plan caching).
    pub plan_cache_bytes: usize,
    /// Reply-watchdog deadline per dispatched batch (zero disables the
    /// watchdog).
    pub reply_timeout: Duration,
    /// Request-tracing policy (`--trace-rate` / `--trace-slow-us` /
    /// `--trace-buffer`); the pool owns one [`Tracer`] shared by every
    /// shard worker and the connection readers.
    pub trace: TraceConfig,
    /// Declared SLOs (`--slo-p99-us` / `--slo-error-rate` /
    /// `--slo-mse-factor` / `--slo-eval-ms`); when enabled the pool runs
    /// one burn-rate evaluator thread publishing into the journal.
    pub slo: SloPolicy,
}

/// K running serving shards plus their routing table.
pub struct ShardPool {
    batchers: Vec<Arc<Batcher>>,
    workers: Mutex<WorkerPool>,
    /// Deadline sweeper over dispatched replies (None when disabled). Its
    /// thread lives in its own pool so [`ShardPool::join`] can keep it
    /// sweeping until every shard worker has drained.
    watchdog: Option<Arc<ReplyWatchdog>>,
    sweeper: Mutex<WorkerPool>,
    /// The process tracer: sampling decisions at admission (connection
    /// readers), span finishing in the shard workers, `trace` queries.
    tracer: Arc<Tracer>,
    /// The merged auto-resolution snapshot every shard worker prices
    /// `"scheme":"auto"` batches against, refreshed by the pool's
    /// refresher thread so all shards converge on one view.
    auto_view: Arc<AutoView>,
    /// Stops the auto-view refresher and the SLO evaluator at
    /// [`ShardPool::join`].
    refresher_stop: Arc<AtomicBool>,
    /// The process event journal: shard workers publish scheme switches,
    /// the SLO evaluator publishes burn-rate alerts, and the server's
    /// watch connections subscribe.
    journal: Arc<Journal>,
}

impl ShardPool {
    /// Spawn `cfg.shards` worker shards over a shared model zoo. Each
    /// shard gets its own engine (decorrelated seed stream) and the
    /// matching [`Metrics`] slot. The pool shares `journal` with every
    /// worker and, when `cfg.slo` is enabled, spawns the burn-rate
    /// evaluator thread publishing into it.
    pub fn start(
        cfg: &ShardConfig,
        zoo: Arc<Zoo>,
        metrics: &Metrics,
        journal: Arc<Journal>,
    ) -> ShardPool {
        let shards = cfg.shards.max(1);
        // Zoo-level prewarming: build the hot configurations' weight plans
        // once and hand shared Arcs to every shard's cache, so the first
        // request of a prewarmed configuration never pays planning.
        let prewarmed = if cfg.prewarm_bits.is_empty() {
            Vec::new()
        } else {
            zoo.prewarm_plans(&cfg.prewarm_bits, &SchemeId::PAPER, Variant::Separate, cfg.seed)
        };
        let mut workers = WorkerPool::new();
        // One reply watchdog serves every shard: workers register each
        // dispatched batch, the sweeper thread answers `timeout` for
        // replies that outlive the deadline (a wedged engine call no
        // longer holds window slots and writer channels forever).
        let watchdog = if cfg.reply_timeout.is_zero() {
            None
        } else {
            Some(Arc::new(ReplyWatchdog::new(cfg.reply_timeout)))
        };
        let mut sweeper = WorkerPool::new();
        if let Some(dog) = &watchdog {
            let dog = dog.clone();
            sweeper.spawn("dither-reply-watchdog".to_string(), move || dog.run());
        }
        let tracer = Arc::new(Tracer::new(cfg.trace.clone()));
        // One merged auto view per process: seeded synchronously (the
        // first auto batch never races an empty snapshot), then refreshed
        // on the sweeper pool until join. Workers read it lock-cheap per
        // auto batch, so a shard's choices track what *every* shard has
        // measured, not just its own estimators.
        let metrics_handle = metrics.handle();
        let auto_view = Arc::new(AutoView::new(metrics_handle.auto_snapshot()));
        let refresher_stop = Arc::new(AtomicBool::new(false));
        {
            let view = auto_view.clone();
            let stop = refresher_stop.clone();
            sweeper.spawn("dither-auto-view".to_string(), move || {
                while !stop.load(Ordering::Acquire) {
                    std::thread::sleep(AUTO_VIEW_REFRESH);
                    view.store(metrics_handle.auto_snapshot());
                }
            });
        }
        let mut batchers = Vec::with_capacity(shards);
        let mut engines: Vec<Arc<Engine>> = Vec::with_capacity(shards);
        for i in 0..shards {
            let batcher = Arc::new(Batcher::new(cfg.max_batch, cfg.max_wait, cfg.queue_cap));
            let shard_metrics = metrics.shard(i);
            // Distinct per-shard rounding streams, but one shared prep
            // seed (the zoo prewarm seed): a plan evicted and rebuilt on
            // any shard reproduces the prewarmed plan bit for bit. The
            // engine's shadow path writes into the shard's metrics-owned
            // fidelity estimators, so `stats` and the auto-precision
            // controller see what this shard measured.
            let engine_seed = cfg.seed ^ ((i as u64 + 1) << 32);
            let engine = Arc::new(
                Engine::with_plan_cache(zoo.clone(), engine_seed, cfg.plan_cache_bytes)
                    .with_prep_seed(cfg.seed)
                    .with_shadow(cfg.shadow_rate, shard_metrics.fidelity().clone()),
            );
            for (key, plans) in &prewarmed {
                engine.install_prepared(key.clone(), plans.clone());
            }
            // Plan-aware batching: the batcher prefers keys whose plans
            // are resident in this shard's engine (Separate is the
            // serving placement, matching `Engine::infer_batch`).
            let res_engine = engine.clone();
            batcher.set_residency(move |key: &BatchKey| {
                res_engine.plan_resident(&PlanKey {
                    model: key.model.clone(),
                    bits: key.k,
                    scheme: key.scheme,
                    variant: Variant::Separate,
                })
            });
            engines.push(engine.clone());
            let b = batcher.clone();
            let dog = watchdog.clone();
            let shard_tracer = tracer.clone();
            let shard_view = auto_view.clone();
            let shard_journal = journal.clone();
            workers.spawn(format!("dither-shard-{i}"), move || {
                // Stop the batcher even if the worker panics: routed
                // requests then get an immediate "shutting down" reply
                // instead of queueing into a dead shard forever.
                struct StopOnExit(Arc<Batcher>);
                impl Drop for StopOnExit {
                    fn drop(&mut self) {
                        self.0.stop();
                    }
                }
                let _guard = StopOnExit(b.clone());
                worker_loop(
                    &b,
                    &engine,
                    &shard_metrics,
                    &shard_tracer,
                    &shard_view,
                    i,
                    dog.as_deref(),
                    Some(&shard_journal),
                );
            });
            batchers.push(batcher);
        }
        // The SLO evaluator rides the sweeper pool like the auto-view
        // refresher: one thread per process, stopped at join. Each tick
        // it folds lifetime counters + the fidelity snapshot into the
        // journal's alert set — the hot path never publishes for these.
        if cfg.slo.enabled() {
            let policy = cfg.slo;
            let stop = refresher_stop.clone();
            let handle = metrics.handle();
            let slo_tracer = tracer.clone();
            let slo_engines = engines.clone();
            let slo_journal = journal.clone();
            sweeper.spawn("dither-slo-eval".to_string(), move || {
                let mut eval = SloEvaluator::new(policy);
                let tick = Duration::from_millis(policy.eval_ms.max(1));
                let mut last = Instant::now();
                while !stop.load(Ordering::Acquire) {
                    std::thread::sleep(SLO_POLL.min(tick));
                    if last.elapsed() < tick {
                        continue;
                    }
                    last = Instant::now();
                    let mut sample = handle.slo_sample();
                    sample.slow_promoted = slo_tracer.slow_promoted();
                    sample.plan_evictions = slo_engines
                        .iter()
                        .map(|e| e.plan_cache_stats().evictions)
                        .sum();
                    let cells = mse_cells(&handle.auto_snapshot());
                    eval.observe(sample, &cells, &slo_journal);
                }
            });
        }
        ShardPool {
            batchers,
            workers: Mutex::new(workers),
            watchdog,
            sweeper: Mutex::new(sweeper),
            tracer,
            auto_view,
            refresher_stop,
            journal,
        }
    }

    /// The process event journal shared with every worker and the SLO
    /// evaluator; the server's watch connections subscribe to it.
    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// The pool's merged auto-resolution view (shared with every shard
    /// worker and refreshed every [`AUTO_VIEW_REFRESH`]).
    pub fn auto_view(&self) -> &Arc<AutoView> {
        &self.auto_view
    }

    /// The pool's reply watchdog, when one is running.
    pub fn watchdog(&self) -> Option<&Arc<ReplyWatchdog>> {
        self.watchdog.as_ref()
    }

    /// The pool's shared tracer (sampling, the trace ring, per-stage
    /// histograms). Always present; disabled configurations hand out no
    /// builders.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.batchers.len()
    }

    /// Hash-route a connection id to a shard index (stable for the
    /// connection's lifetime, uniform across shards).
    pub fn route(&self, conn_id: u64) -> usize {
        (counter_hash(0x5A4D_D17E, conn_id) % self.batchers.len() as u64) as usize
    }

    /// Submit a request to a shard's bounded queue.
    pub fn submit(&self, shard: usize, p: Pending) -> Result<(), SubmitError> {
        self.batchers[shard % self.batchers.len()].submit(p)
    }

    /// Graceful shutdown: every shard stops intake, drains its queue, then
    /// its worker exits.
    pub fn close(&self) {
        for b in &self.batchers {
            b.close();
        }
    }

    /// Hard shutdown: workers exit after their in-flight batch; queued
    /// requests error out when their channels drop.
    pub fn stop(&self) {
        for b in &self.batchers {
            b.stop();
        }
    }

    /// True once `close` or `stop` has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.batchers[0].is_shutting_down()
    }

    /// Number of events published to the pool's journal so far.
    pub fn events_published(&self) -> u64 {
        self.journal.published()
    }

    /// Join every shard worker; returns how many panicked. The watchdog
    /// sweeper keeps running until the workers have drained (their final
    /// batches deserve timeout coverage too), then stops and joins.
    pub fn join(&self) -> usize {
        let panicked = self.workers.lock().unwrap().join_all();
        if let Some(dog) = &self.watchdog {
            dog.stop();
        }
        self.refresher_stop.store(true, Ordering::Release);
        panicked + self.sweeper.lock().unwrap().join_all()
    }
}

/// Flatten the fidelity snapshot into the evaluator's [`MseCell`] rows:
/// every observed `(model, scheme, k)` cell with its measured MSE and
/// the scheme's prior envelope attached.
fn mse_cells(snapshot: &AutoSnapshot) -> Vec<MseCell> {
    let mut cells = Vec::new();
    for spec in ModelSpec::ALL {
        for mode in SchemeId::ALL {
            for k in 1..=MAX_K {
                let est = snapshot.estimates.get(spec.index(), mode, k);
                if est.samples > 0 {
                    cells.push(MseCell {
                        model: spec.name().to_string(),
                        scheme: mode.wire_name().to_string(),
                        k,
                        mse: est.mse(),
                        samples: est.samples,
                        prior: prior_mse(mode, k),
                    });
                }
            }
        }
    }
    cells
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::InferenceRequest;
    use crate::rounding::SchemeId;
    use crate::util::json::Json;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant;

    use crate::coordinator::batcher::ReplyTo;

    fn pool(shards: usize) -> (ShardPool, Metrics) {
        pool_tracing(shards, TraceConfig::default())
    }

    fn pool_tracing(shards: usize, trace: TraceConfig) -> (ShardPool, Metrics) {
        let cfg = ShardConfig {
            shards,
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            queue_cap: 64,
            seed: 7,
            prewarm_bits: vec![4],
            shadow_rate: 0.5,
            plan_cache_bytes: crate::coordinator::engine::DEFAULT_PLAN_CACHE_BYTES,
            reply_timeout: Duration::from_secs(120),
            trace,
            slo: SloPolicy::disabled(),
        };
        let metrics = Metrics::new(shards);
        let zoo = Arc::new(Zoo::load(200, 7));
        let pool = ShardPool::start(&cfg, zoo, &metrics, Arc::new(Journal::default()));
        (pool, metrics)
    }

    fn infer_pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = sync_channel(8);
        (
            Pending {
                req: InferenceRequest {
                    id,
                    model: "digits_linear".to_string(),
                    k: 4,
                    scheme: SchemeId::Dither,
                    auto: false,
                    deprecated_mode: false,
                    max_mse: None,
                    max_latency_us: None,
                    trace: None,
                    pixels: vec![0.3; 784],
                },
                respond_to: ReplyTo::new(id, tx),
                enqueued: Instant::now(),
                trace: None,
            },
            rx,
        )
    }

    #[test]
    fn routing_is_stable_and_covers_shards() {
        let (pool, _metrics) = pool(4);
        let mut hit = [false; 4];
        for conn in 0..64u64 {
            let a = pool.route(conn);
            assert_eq!(a, pool.route(conn), "routing must be stable");
            hit[a] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 connections should cover 4 shards");
        pool.close();
        assert_eq!(pool.join(), 0);
    }

    #[test]
    fn shards_serve_and_drain_on_close() {
        let (pool, metrics) = pool(2);
        let mut receivers = Vec::new();
        for id in 0..6u64 {
            let shard = pool.route(id);
            let (p, rx) = infer_pending(id);
            pool.submit(shard, p).unwrap();
            receivers.push((id, rx));
        }
        pool.close(); // graceful: queued work is still answered
        for (id, rx) in receivers {
            let line = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("response before shutdown");
            let json = Json::parse(&line).expect("valid response json");
            assert_eq!(json.get("id").unwrap().as_f64(), Some(id as f64));
            assert!(json.get("error").is_none(), "{line}");
            let shard = json.get("shard").unwrap().as_f64().unwrap() as usize;
            assert_eq!(shard, pool.route(id));
        }
        assert_eq!(pool.join(), 0);
        assert!(metrics.total_requests() >= 6);
        // shadow_rate 0.5: whichever shards served ≥ 2 requests recorded
        // logit errors into their metrics-owned fidelity estimators.
        let shadowed: u64 = (0..2).map(|i| metrics.shard(i).fidelity().total_samples()).sum();
        assert!(shadowed > 0, "shadow sampling must record logit errors");
    }

    #[test]
    fn traced_requests_record_full_timelines_into_the_pool_tracer() {
        use crate::trace::Stage;
        let (pool, _metrics) = pool_tracing(
            1,
            TraceConfig {
                rate: 1.0,
                slow_us: 0,
                buffer: 64,
            },
        );
        let tracer = pool.tracer().clone();
        assert!(tracer.enabled());
        let mut receivers = Vec::new();
        for id in 0..4u64 {
            let (mut p, rx) = infer_pending(id);
            p.trace = tracer.begin(id);
            assert!(p.trace.is_some(), "rate 1.0 samples every request");
            pool.submit(0, p).unwrap();
            receivers.push(rx);
        }
        pool.close();
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(30)).expect("response");
        }
        assert_eq!(pool.join(), 0);
        assert_eq!(tracer.committed(), 4);
        let traces = tracer.query(0, Some("digits_linear"), Some("dither"), 0);
        assert_eq!(traces.len(), 4);
        for trace in &traces {
            assert_eq!(trace.shard, Some(0));
            assert_eq!(trace.k, 4);
            let stages: Vec<Stage> = trace.spans.iter().map(|s| s.stage).collect();
            for want in [
                Stage::Queue,
                Stage::Assemble,
                Stage::Plan,
                Stage::Kernel,
                Stage::Serialize,
                Stage::Flush,
            ] {
                assert!(stages.contains(&want), "missing {want:?} in {stages:?}");
            }
            let kernel = trace.spans.iter().find(|s| s.stage == Stage::Kernel).unwrap();
            let note = kernel.note.as_deref().expect("kernel span is noted");
            assert!(note.ends_with("/dither"), "{note}");
        }
        // Stage histograms saw every span; the ring respects filters.
        assert!(!tracer.stage_snapshots().is_empty());
        assert!(tracer.query(0, Some("no_such_model"), None, 0).is_empty());
    }

    /// The closed SLO loop, end to end: a cold pool resolves a
    /// dual-budget auto request by the static cost walk; after injected
    /// per-scheme latency measurements make that pick blow the latency
    /// budget, the refresher folds the skew into the shared [`AutoView`]
    /// and the very same request redirects to a measured, feasible
    /// `(scheme, k)` — echoed on the wire with `"measured": true`.
    #[test]
    fn measured_latency_skew_redirects_auto_resolution() {
        use crate::fidelity::LATENCY_MIN_SAMPLES;
        let (pool, metrics) = pool(1);

        let auto_pending = |id: u64| {
            let (tx, rx) = sync_channel(8);
            (
                Pending {
                    req: InferenceRequest {
                        id,
                        model: "digits_linear".to_string(),
                        k: 0,
                        scheme: SchemeId::Dither,
                        auto: true,
                        deprecated_mode: false,
                        max_mse: Some(1e9),
                        max_latency_us: Some(10_000),
                        trace: None,
                        pixels: vec![0.3; 784],
                    },
                    respond_to: ReplyTo::new(id, tx),
                    enqueued: Instant::now(),
                    trace: None,
                },
                rx,
            )
        };
        let ask = |id: u64| -> Json {
            let (p, rx) = auto_pending(id);
            pool.submit(0, p).unwrap();
            let line = rx.recv_timeout(Duration::from_secs(30)).expect("auto reply");
            Json::parse(&line).expect("valid response json")
        };

        // Cold view: both budgets present, nothing measured — the static
        // cost walk serves its cheapest candidate, unmarked as measured.
        let cold = ask(1);
        assert_eq!(cold.get("scheme").unwrap().as_str(), Some("deterministic"));
        assert_eq!(cold.get("k").unwrap().as_f64(), Some(1.0));
        assert_eq!(cold.get("auto").unwrap().as_bool(), Some(true));
        assert!(cold.get("measured").is_none(), "cold choices are not measured");
        // Non-auto traffic is byte-compatible with the pre-SLO wire: no
        // auto/measured tags appear on a concrete-key reply.
        let (p, rx) = infer_pending(2);
        pool.submit(0, p).unwrap();
        let line = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(!line.contains("\"auto\"") && !line.contains("\"measured\""), "{line}");

        // Inject the skew straight into the shard's recent windows: the
        // deterministic scheme measures far over the 10 ms budget, dither
        // measures well under it. The deterministic samples ride an
        // out-of-range model slot, so they also exercise the
        // recent_dropped accounting for per-(model, k) cells.
        let shard = metrics.shard(0);
        for _ in 0..(LATENCY_MIN_SAMPLES * 8) {
            shard.record_request(SchemeId::Deterministic, usize::MAX, 1, 50_000);
            shard.record_request(SchemeId::Dither, 0, 2, 100);
        }

        // Within a few refresher ticks every shard prices the same skew,
        // and the identical request redirects off the static walk.
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut id = 10u64;
        let redirected = loop {
            let json = ask(id);
            id += 1;
            let scheme = json.get("scheme").unwrap().as_str().unwrap().to_string();
            if scheme != "deterministic" {
                break json;
            }
            assert!(
                Instant::now() < deadline,
                "auto resolution never picked up the measured latency skew"
            );
            std::thread::sleep(Duration::from_millis(25));
        };
        assert_eq!(
            redirected.get("scheme").unwrap().as_str(),
            Some("dither"),
            "the only fast measured scheme must win the walk"
        );
        assert_eq!(redirected.get("auto").unwrap().as_bool(), Some(true));
        assert_eq!(
            redirected.get("measured").unwrap().as_bool(),
            Some(true),
            "a measurement-driven choice must be echoed as measured"
        );
        pool.close();
        assert_eq!(pool.join(), 0);
        // The out-of-range model slot rode every injected deterministic
        // sample into the dropped counter, and the stats scrape shows it.
        let stats = metrics.snapshot_json();
        assert!(stats.contains("\"recent_dropped\":"), "{stats}");
        assert!(!stats.contains("\"recent_dropped\":0,"), "{stats}");
        assert!(stats.contains("\"auto_slo_requests\":"), "{stats}");
        // The redirect moved digits_linear to a new operating point, and
        // the worker journaled the switch with both endpoints labeled.
        let switch = pool
            .journal()
            .recent(64)
            .into_iter()
            .find(|e| e.kind == crate::obs::EventKind::SchemeSwitch)
            .expect("auto redirect must journal a scheme switch");
        assert_eq!(
            switch.labels.get("to_scheme").map(String::as_str),
            Some("dither"),
            "{switch:?}"
        );
        assert_eq!(
            switch.labels.get("from_scheme").map(String::as_str),
            Some("deterministic"),
            "{switch:?}"
        );
    }

    /// The evaluator thread end to end: a 1 µs p99 budget that any real
    /// traffic breaches must raise `latency_p99` on the pool's journal
    /// within a few ticks, and clear it once traffic stops and the fast
    /// window drains.
    #[test]
    fn slo_evaluator_thread_fires_and_clears_alerts() {
        use crate::obs::EventKind;
        let cfg = ShardConfig {
            shards: 1,
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            queue_cap: 64,
            seed: 7,
            prewarm_bits: vec![4],
            shadow_rate: 0.0,
            plan_cache_bytes: crate::coordinator::engine::DEFAULT_PLAN_CACHE_BYTES,
            reply_timeout: Duration::from_secs(120),
            trace: TraceConfig::default(),
            slo: SloPolicy {
                p99_us: 1,
                error_rate: 0.0,
                mse_factor: 0.0,
                eval_ms: 20,
            },
        };
        let metrics = Metrics::new(1);
        let zoo = Arc::new(Zoo::load(200, 7));
        let journal = Arc::new(Journal::default());
        let pool = ShardPool::start(&cfg, zoo, &metrics, journal.clone());
        // Keep traffic flowing until the alert fires: the baseline tick
        // may land after any single burst, so breaches must keep
        // appearing in fresh per-tick deltas.
        let deadline = Instant::now() + Duration::from_secs(20);
        let mut id = 0u64;
        while journal.active_alerts().is_empty() {
            assert!(
                Instant::now() < deadline,
                "latency_p99 never fired: {:?}",
                journal.recent(16)
            );
            let (p, rx) = infer_pending(id);
            id += 1;
            pool.submit(0, p).unwrap();
            rx.recv_timeout(Duration::from_secs(30)).expect("response");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            journal.active_alerts()[0].get("alert").map(String::as_str),
            Some("latency_p99")
        );
        // No further traffic: the fast window drains and the alert clears.
        let deadline = Instant::now() + Duration::from_secs(20);
        while !journal.active_alerts().is_empty() {
            assert!(Instant::now() < deadline, "alert never cleared");
            std::thread::sleep(Duration::from_millis(10));
        }
        let kinds: Vec<EventKind> = journal.recent(64).iter().map(|e| e.kind).collect();
        assert!(kinds.contains(&EventKind::AlertFired), "{kinds:?}");
        assert!(kinds.contains(&EventKind::AlertCleared), "{kinds:?}");
        assert!(pool.events_published() >= 2);
        pool.close();
        assert_eq!(pool.join(), 0);
    }
}
