//! The sharded serving core: K worker shards, each owning an [`Engine`]
//! and a bounded dynamic [`Batcher`], with connections hash-routed onto
//! shards.
//!
//! Sharding is what lets the coordinator scale with cores: every shard has
//! its own queue, its own batching worker, its own engine seed stream and
//! its own metrics slot, so the request hot path shares no locks between
//! shards (the model weights are shared read-only through `Arc<Zoo>`).
//! Routing is by connection, not by request, so one client's pipelined
//! requests all land in a single shard's batcher (responses may complete
//! out of order; the id echo matches them up client-side).

use crate::coordinator::batcher::{
    worker_loop, BatchKey, Batcher, Pending, ReplyWatchdog, SubmitError,
};
use crate::coordinator::engine::Engine;
use crate::coordinator::metrics::Metrics;
use crate::linalg::Variant;
use crate::nn::PlanKey;
use crate::rounding::SchemeId;
use crate::trace::{TraceConfig, Tracer};
use crate::train::Zoo;
use crate::util::rng::counter_hash;
use crate::util::threadpool::WorkerPool;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shard-pool policy.
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Number of worker shards (≥ 1).
    pub shards: usize,
    /// Maximum dynamic-batch size per shard.
    pub max_batch: usize,
    /// Batch linger time.
    pub max_wait: Duration,
    /// Bounded per-shard queue capacity (backpressure threshold).
    pub queue_cap: usize,
    /// Base seed for the per-shard engine rounding streams.
    pub seed: u64,
    /// Bit widths whose weight-side plans are prewarmed (the paper's trio
    /// of schemes, every model) into each shard's plan cache before
    /// traffic is accepted. Empty disables prewarming.
    pub prewarm_bits: Vec<u32>,
    /// Fraction of request rows shadow-checked against the exact f64
    /// forward pass per shard (0 disables shadow sampling).
    pub shadow_rate: f64,
    /// Per-shard plan-cache byte budget (0 disables plan caching).
    pub plan_cache_bytes: usize,
    /// Reply-watchdog deadline per dispatched batch (zero disables the
    /// watchdog).
    pub reply_timeout: Duration,
    /// Request-tracing policy (`--trace-rate` / `--trace-slow-us` /
    /// `--trace-buffer`); the pool owns one [`Tracer`] shared by every
    /// shard worker and the connection readers.
    pub trace: TraceConfig,
}

/// K running serving shards plus their routing table.
pub struct ShardPool {
    batchers: Vec<Arc<Batcher>>,
    workers: Mutex<WorkerPool>,
    /// Deadline sweeper over dispatched replies (None when disabled). Its
    /// thread lives in its own pool so [`ShardPool::join`] can keep it
    /// sweeping until every shard worker has drained.
    watchdog: Option<Arc<ReplyWatchdog>>,
    sweeper: Mutex<WorkerPool>,
    /// The process tracer: sampling decisions at admission (connection
    /// readers), span finishing in the shard workers, `trace` queries.
    tracer: Arc<Tracer>,
}

impl ShardPool {
    /// Spawn `cfg.shards` worker shards over a shared model zoo. Each
    /// shard gets its own engine (decorrelated seed stream) and the
    /// matching [`Metrics`] slot.
    pub fn start(cfg: &ShardConfig, zoo: Arc<Zoo>, metrics: &Metrics) -> ShardPool {
        let shards = cfg.shards.max(1);
        // Zoo-level prewarming: build the hot configurations' weight plans
        // once and hand shared Arcs to every shard's cache, so the first
        // request of a prewarmed configuration never pays planning.
        let prewarmed = if cfg.prewarm_bits.is_empty() {
            Vec::new()
        } else {
            zoo.prewarm_plans(&cfg.prewarm_bits, &SchemeId::PAPER, Variant::Separate, cfg.seed)
        };
        let mut workers = WorkerPool::new();
        // One reply watchdog serves every shard: workers register each
        // dispatched batch, the sweeper thread answers `timeout` for
        // replies that outlive the deadline (a wedged engine call no
        // longer holds window slots and writer channels forever).
        let watchdog = if cfg.reply_timeout.is_zero() {
            None
        } else {
            Some(Arc::new(ReplyWatchdog::new(cfg.reply_timeout)))
        };
        let mut sweeper = WorkerPool::new();
        if let Some(dog) = &watchdog {
            let dog = dog.clone();
            sweeper.spawn("dither-reply-watchdog".to_string(), move || dog.run());
        }
        let tracer = Arc::new(Tracer::new(cfg.trace.clone()));
        let mut batchers = Vec::with_capacity(shards);
        for i in 0..shards {
            let batcher = Arc::new(Batcher::new(cfg.max_batch, cfg.max_wait, cfg.queue_cap));
            let shard_metrics = metrics.shard(i);
            // Distinct per-shard rounding streams, but one shared prep
            // seed (the zoo prewarm seed): a plan evicted and rebuilt on
            // any shard reproduces the prewarmed plan bit for bit. The
            // engine's shadow path writes into the shard's metrics-owned
            // fidelity estimators, so `stats` and the auto-precision
            // controller see what this shard measured.
            let engine_seed = cfg.seed ^ ((i as u64 + 1) << 32);
            let engine = Arc::new(
                Engine::with_plan_cache(zoo.clone(), engine_seed, cfg.plan_cache_bytes)
                    .with_prep_seed(cfg.seed)
                    .with_shadow(cfg.shadow_rate, shard_metrics.fidelity().clone()),
            );
            for (key, plans) in &prewarmed {
                engine.install_prepared(key.clone(), plans.clone());
            }
            // Plan-aware batching: the batcher prefers keys whose plans
            // are resident in this shard's engine (Separate is the
            // serving placement, matching `Engine::infer_batch`).
            let res_engine = engine.clone();
            batcher.set_residency(move |key: &BatchKey| {
                res_engine.plan_resident(&PlanKey {
                    model: key.model.clone(),
                    bits: key.k,
                    scheme: key.scheme,
                    variant: Variant::Separate,
                })
            });
            let b = batcher.clone();
            let dog = watchdog.clone();
            let shard_tracer = tracer.clone();
            workers.spawn(format!("dither-shard-{i}"), move || {
                // Stop the batcher even if the worker panics: routed
                // requests then get an immediate "shutting down" reply
                // instead of queueing into a dead shard forever.
                struct StopOnExit(Arc<Batcher>);
                impl Drop for StopOnExit {
                    fn drop(&mut self) {
                        self.0.stop();
                    }
                }
                let _guard = StopOnExit(b.clone());
                worker_loop(&b, &engine, &shard_metrics, &shard_tracer, i, dog.as_deref());
            });
            batchers.push(batcher);
        }
        ShardPool {
            batchers,
            workers: Mutex::new(workers),
            watchdog,
            sweeper: Mutex::new(sweeper),
            tracer,
        }
    }

    /// The pool's reply watchdog, when one is running.
    pub fn watchdog(&self) -> Option<&Arc<ReplyWatchdog>> {
        self.watchdog.as_ref()
    }

    /// The pool's shared tracer (sampling, the trace ring, per-stage
    /// histograms). Always present; disabled configurations hand out no
    /// builders.
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.batchers.len()
    }

    /// Hash-route a connection id to a shard index (stable for the
    /// connection's lifetime, uniform across shards).
    pub fn route(&self, conn_id: u64) -> usize {
        (counter_hash(0x5A4D_D17E, conn_id) % self.batchers.len() as u64) as usize
    }

    /// Submit a request to a shard's bounded queue.
    pub fn submit(&self, shard: usize, p: Pending) -> Result<(), SubmitError> {
        self.batchers[shard % self.batchers.len()].submit(p)
    }

    /// Graceful shutdown: every shard stops intake, drains its queue, then
    /// its worker exits.
    pub fn close(&self) {
        for b in &self.batchers {
            b.close();
        }
    }

    /// Hard shutdown: workers exit after their in-flight batch; queued
    /// requests error out when their channels drop.
    pub fn stop(&self) {
        for b in &self.batchers {
            b.stop();
        }
    }

    /// True once `close` or `stop` has been called.
    pub fn is_shutting_down(&self) -> bool {
        self.batchers[0].is_shutting_down()
    }

    /// Join every shard worker; returns how many panicked. The watchdog
    /// sweeper keeps running until the workers have drained (their final
    /// batches deserve timeout coverage too), then stops and joins.
    pub fn join(&self) -> usize {
        let panicked = self.workers.lock().unwrap().join_all();
        if let Some(dog) = &self.watchdog {
            dog.stop();
        }
        panicked + self.sweeper.lock().unwrap().join_all()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol::InferenceRequest;
    use crate::rounding::SchemeId;
    use crate::util::json::Json;
    use std::sync::mpsc::sync_channel;
    use std::time::Instant;

    use crate::coordinator::batcher::ReplyTo;

    fn pool(shards: usize) -> (ShardPool, Metrics) {
        pool_tracing(shards, TraceConfig::default())
    }

    fn pool_tracing(shards: usize, trace: TraceConfig) -> (ShardPool, Metrics) {
        let cfg = ShardConfig {
            shards,
            max_batch: 8,
            max_wait: Duration::from_micros(500),
            queue_cap: 64,
            seed: 7,
            prewarm_bits: vec![4],
            shadow_rate: 0.5,
            plan_cache_bytes: crate::coordinator::engine::DEFAULT_PLAN_CACHE_BYTES,
            reply_timeout: Duration::from_secs(120),
            trace,
        };
        let metrics = Metrics::new(shards);
        let zoo = Arc::new(Zoo::load(200, 7));
        let pool = ShardPool::start(&cfg, zoo, &metrics);
        (pool, metrics)
    }

    fn infer_pending(id: u64) -> (Pending, std::sync::mpsc::Receiver<String>) {
        let (tx, rx) = sync_channel(8);
        (
            Pending {
                req: InferenceRequest {
                    id,
                    model: "digits_linear".to_string(),
                    k: 4,
                    scheme: SchemeId::Dither,
                    auto: false,
                    deprecated_mode: false,
                    max_mse: None,
                    pixels: vec![0.3; 784],
                },
                respond_to: ReplyTo::new(id, tx),
                enqueued: Instant::now(),
                trace: None,
            },
            rx,
        )
    }

    #[test]
    fn routing_is_stable_and_covers_shards() {
        let (pool, _metrics) = pool(4);
        let mut hit = [false; 4];
        for conn in 0..64u64 {
            let a = pool.route(conn);
            assert_eq!(a, pool.route(conn), "routing must be stable");
            hit[a] = true;
        }
        assert!(hit.iter().all(|&h| h), "64 connections should cover 4 shards");
        pool.close();
        assert_eq!(pool.join(), 0);
    }

    #[test]
    fn shards_serve_and_drain_on_close() {
        let (pool, metrics) = pool(2);
        let mut receivers = Vec::new();
        for id in 0..6u64 {
            let shard = pool.route(id);
            let (p, rx) = infer_pending(id);
            pool.submit(shard, p).unwrap();
            receivers.push((id, rx));
        }
        pool.close(); // graceful: queued work is still answered
        for (id, rx) in receivers {
            let line = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("response before shutdown");
            let json = Json::parse(&line).expect("valid response json");
            assert_eq!(json.get("id").unwrap().as_f64(), Some(id as f64));
            assert!(json.get("error").is_none(), "{line}");
            let shard = json.get("shard").unwrap().as_f64().unwrap() as usize;
            assert_eq!(shard, pool.route(id));
        }
        assert_eq!(pool.join(), 0);
        assert!(metrics.total_requests() >= 6);
        // shadow_rate 0.5: whichever shards served ≥ 2 requests recorded
        // logit errors into their metrics-owned fidelity estimators.
        let shadowed: u64 = (0..2).map(|i| metrics.shard(i).fidelity().total_samples()).sum();
        assert!(shadowed > 0, "shadow sampling must record logit errors");
    }

    #[test]
    fn traced_requests_record_full_timelines_into_the_pool_tracer() {
        use crate::trace::Stage;
        let (pool, _metrics) = pool_tracing(
            1,
            TraceConfig {
                rate: 1.0,
                slow_us: 0,
                buffer: 64,
            },
        );
        let tracer = pool.tracer().clone();
        assert!(tracer.enabled());
        let mut receivers = Vec::new();
        for id in 0..4u64 {
            let (mut p, rx) = infer_pending(id);
            p.trace = tracer.begin(id);
            assert!(p.trace.is_some(), "rate 1.0 samples every request");
            pool.submit(0, p).unwrap();
            receivers.push(rx);
        }
        pool.close();
        for rx in receivers {
            rx.recv_timeout(Duration::from_secs(30)).expect("response");
        }
        assert_eq!(pool.join(), 0);
        assert_eq!(tracer.committed(), 4);
        let traces = tracer.query(0, Some("digits_linear"), Some("dither"), 0);
        assert_eq!(traces.len(), 4);
        for trace in &traces {
            assert_eq!(trace.shard, Some(0));
            assert_eq!(trace.k, 4);
            let stages: Vec<Stage> = trace.spans.iter().map(|s| s.stage).collect();
            for want in [
                Stage::Queue,
                Stage::Assemble,
                Stage::Plan,
                Stage::Kernel,
                Stage::Serialize,
                Stage::Flush,
            ] {
                assert!(stages.contains(&want), "missing {want:?} in {stages:?}");
            }
            let kernel = trace.spans.iter().find(|s| s.stage == Stage::Kernel).unwrap();
            let note = kernel.note.as_deref().expect("kernel span is noted");
            assert!(note.ends_with("/dither"), "{note}");
        }
        // Stage histograms saw every span; the ring respects filters.
        assert!(!tracer.stage_snapshots().is_empty());
        assert!(tracer.query(0, Some("no_such_model"), None, 0).is_empty());
    }
}
