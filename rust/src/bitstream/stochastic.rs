//! Classic (unipolar) stochastic computing encoder — paper §II-A.
//!
//! A value `x ∈ [0,1]` is represented by `N` iid Bernoulli trials with
//! `P(X_i = 1) = x`. The estimator `X_s` is unbiased with
//! `Var(X_s) = x(1-x)/N = Ω(1/N)`, which is the suboptimal rate the paper's
//! dither scheme improves on.

use crate::bitstream::sequence::BitSeq;
use crate::util::rng::Xoshiro256pp;

/// Encoder for the unipolar stochastic-computing format.
#[derive(Clone, Copy, Debug, Default)]
pub struct StochasticEncoder;

impl StochasticEncoder {
    /// Encode `x` (clamped to [0,1]) as `n` iid Bernoulli(x) pulses.
    ///
    /// Perf: each `next_u64` supplies TWO Bernoulli trials by comparing its
    /// high and low 32-bit halves against a 32-bit threshold (xoshiro's
    /// halves are independently uniform). The threshold granularity of
    /// 2⁻³² introduces a bias ≤ 2.4e-10 — five orders below anything the
    /// EMSE experiments resolve — and halves the generator work, which
    /// dominates this encoder (§Perf: 0.49 → ~1 G pulses/s).
    pub fn encode(&self, x: f64, n: usize, rng: &mut Xoshiro256pp) -> BitSeq {
        let x = x.clamp(0.0, 1.0);
        if x <= 0.0 {
            return BitSeq::zeros(n);
        }
        if x >= 1.0 {
            return BitSeq::ones(n);
        }
        let threshold = (x * 4294967296.0) as u32; // x · 2^32
        let mut seq = BitSeq::zeros(n);
        let words = seq.words_mut();
        let full_words = n / 64;
        for w in words.iter_mut().take(full_words) {
            let mut word = 0u64;
            for b in 0..32 {
                let r = rng.next_u64();
                word |= u64::from((r as u32) < threshold) << (2 * b);
                word |= u64::from(((r >> 32) as u32) < threshold) << (2 * b + 1);
            }
            *w = word;
        }
        let rem = n % 64;
        if rem != 0 {
            let mut word = 0u64;
            let mut b = 0;
            while b + 1 < rem {
                let r = rng.next_u64();
                word |= u64::from((r as u32) < threshold) << b;
                word |= u64::from(((r >> 32) as u32) < threshold) << (b + 1);
                b += 2;
            }
            if b < rem {
                word |= u64::from((rng.next_u64() as u32) < threshold) << b;
            }
            words[full_words] = word;
        }
        seq
    }

    /// The N iid Bernoulli(1/2) control sequence for scaled addition (§IV-A).
    pub fn control(&self, n: usize, rng: &mut Xoshiro256pp) -> BitSeq {
        // p = 1/2 is exactly one random bit per pulse: take whole words.
        let mut seq = BitSeq::zeros(n);
        for w in seq.words_mut() {
            *w = rng.next_u64();
        }
        seq.mask_tail();
        seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn unbiased_mean() {
        let enc = StochasticEncoder;
        let mut rng = Xoshiro256pp::new(1);
        for &x in &[0.1, 0.25, 0.5, 0.73, 0.9] {
            let mut w = Welford::new();
            for _ in 0..2000 {
                w.push(enc.encode(x, 64, &mut rng).value());
            }
            assert!(
                (w.mean() - x).abs() < 0.01,
                "x={x} mean={}",
                w.mean()
            );
        }
    }

    #[test]
    fn variance_matches_binomial() {
        let enc = StochasticEncoder;
        let mut rng = Xoshiro256pp::new(2);
        let (x, n) = (0.3, 128usize);
        let mut w = Welford::new();
        for _ in 0..5000 {
            w.push(enc.encode(x, n, &mut rng).value());
        }
        let expected = x * (1.0 - x) / n as f64;
        assert!(
            (w.variance() - expected).abs() < 0.2 * expected,
            "var={} expected={expected}",
            w.variance()
        );
    }

    #[test]
    fn endpoints_are_exact() {
        let enc = StochasticEncoder;
        let mut rng = Xoshiro256pp::new(3);
        assert_eq!(enc.encode(0.0, 100, &mut rng).value(), 0.0);
        assert_eq!(enc.encode(1.0, 100, &mut rng).value(), 1.0);
        // Out-of-range inputs clamp.
        assert_eq!(enc.encode(-0.5, 100, &mut rng).value(), 0.0);
        assert_eq!(enc.encode(1.5, 100, &mut rng).value(), 1.0);
    }

    #[test]
    fn control_is_half_on_average() {
        let enc = StochasticEncoder;
        let mut rng = Xoshiro256pp::new(4);
        let mut w = Welford::new();
        for _ in 0..2000 {
            w.push(enc.control(100, &mut rng).value());
        }
        assert!((w.mean() - 0.5).abs() < 0.01);
    }

    #[test]
    fn encode_matches_paired_draw_reference() {
        // Golden pin for the word-filling path: bit pairs (2k, 2k+1) consume
        // one u64 draw each — low half → even bit, high half → odd bit — and
        // pairs never straddle a word (64 bits = 32 pairs), so a sequential
        // bit-by-bit reference with the same draw discipline must agree
        // exactly at every length class.
        let enc = StochasticEncoder;
        for n in [0usize, 1, 2, 63, 64, 65, 127, 128, 200] {
            for seed in [6u64, 77] {
                let x = 0.37;
                let threshold = (x * 4294967296.0) as u32;
                let mut rng = Xoshiro256pp::new(seed);
                let fast = enc.encode(x, n, &mut rng);
                let mut ref_rng = Xoshiro256pp::new(seed);
                let mut slow = BitSeq::zeros(n);
                let mut i = 0;
                while i < n {
                    let r = ref_rng.next_u64();
                    if (r as u32) < threshold {
                        slow.set(i, true);
                    }
                    if i + 1 < n && ((r >> 32) as u32) < threshold {
                        slow.set(i + 1, true);
                    }
                    i += 2;
                }
                assert_eq!(fast, slow, "n={n} seed={seed}");
            }
        }
    }

    #[test]
    fn non_multiple_of_64_lengths() {
        let enc = StochasticEncoder;
        let mut rng = Xoshiro256pp::new(5);
        for n in [1usize, 7, 63, 65, 127, 200] {
            let s = enc.encode(0.5, n, &mut rng);
            assert_eq!(s.len(), n);
            assert!(s.count_ones() <= n as u64);
        }
    }
}
