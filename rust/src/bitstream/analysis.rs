//! Bias / variance / EMSE estimation harness — the machinery behind the
//! paper's §V evaluation (Figs 1–6, Table I).
//!
//! For each operand pair `(x, y)` drawn from `U[0,1]²`, we run `T` trials of
//! a scheme+operation, conditioning on the pair as the paper does:
//!
//! * per-pair sample bias `b̂(x,y) = mean_t(est_t) - truth`
//! * per-pair EMSE contribution `L̂(x,y) = mean_t((est_t - truth)²)`
//!
//! and then aggregate over pairs: `L = E(L̂)`, `|Bias| = E(|b̂|)`, plus the
//! decomposed variance `Var = E(L̂ - b̂²)`. Deterministic-variant runs use a
//! single trial (the estimate never changes — §V footnote 2).

use crate::bitstream::ops::{Op, Scheme};
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_map;

/// Aggregated error statistics for one (scheme, op, N) cell.
#[derive(Clone, Copy, Debug)]
pub struct ErrorStats {
    /// Expected MSE `L = E_X(L_x)` — what Figs 1/3/5 plot.
    pub emse: f64,
    /// Mean absolute per-pair sample bias — what Figs 2/4/6 plot.
    pub bias_abs: f64,
    /// Mean signed bias (should be ≈0 for unbiased schemes).
    pub bias_signed: f64,
    /// Mean per-pair variance (EMSE minus squared bias).
    pub variance: f64,
}

/// Configuration for an evaluation sweep.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Number of operand pairs drawn from U[0,1]².
    pub pairs: usize,
    /// Trials per pair (deterministic scheme always uses 1).
    pub trials: usize,
    /// Master seed; pairs and trials are derived deterministically.
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            pairs: 200,
            trials: 200,
            seed: 0xA11CE,
        }
    }
}

impl EvalConfig {
    /// The paper's full-scale configuration (1000 pairs × 1000 trials).
    pub fn paper_scale() -> Self {
        Self {
            pairs: 1000,
            trials: 1000,
            seed: 0xA11CE,
        }
    }

    /// Draw the operand pairs (shared across schemes, as in the paper:
    /// "the set of pairs (x,y) are the same for the 3 schemes").
    pub fn draw_pairs(&self) -> Vec<(f64, f64)> {
        let mut rng = Xoshiro256pp::new(self.seed);
        (0..self.pairs)
            .map(|_| (rng.next_f64(), rng.next_f64()))
            .collect()
    }
}

/// Evaluate one (scheme, op, N) cell over the given operand pairs.
pub fn evaluate(
    scheme: Scheme,
    op: Op,
    n: usize,
    pairs: &[(f64, f64)],
    cfg: &EvalConfig,
) -> ErrorStats {
    let trials = if scheme.is_deterministic() { 1 } else { cfg.trials };
    // Parallel over pairs with order-preserving map; each pair gets an
    // independent RNG stream derived from (seed, scheme, op, n, pair index),
    // and the final reduction is sequential — results are therefore
    // bit-identical regardless of thread count.
    let per_pair = parallel_map(pairs, |idx, &(x, y)| {
        let mut rng = Xoshiro256pp::new(
            cfg.seed
                ^ (idx as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ ((scheme as u64) << 56)
                ^ ((op as u64) << 48)
                ^ ((n as u64) << 32),
        );
        let truth = op.truth(x, y);
        let mut sum = 0.0;
        let mut sum_sq = 0.0;
        for _ in 0..trials {
            let e = op.estimate(scheme, x, y, n, &mut rng);
            let d = e - truth;
            sum += d;
            sum_sq += d * d;
        }
        let t = trials as f64;
        (sum_sq / t, sum / t)
    });
    let mut emse = 0.0;
    let mut bias_abs = 0.0;
    let mut bias_signed = 0.0;
    for &(l_x, bias) in &per_pair {
        emse += l_x;
        bias_abs += bias.abs();
        bias_signed += bias;
    }
    let m = per_pair.len() as f64;
    let emse = emse / m;
    let bias_abs = bias_abs / m;
    let bias_signed = bias_signed / m;
    ErrorStats {
        emse,
        bias_abs,
        bias_signed,
        variance: (emse - bias_signed * bias_signed).max(0.0),
    }
}

/// Sweep an operation over `ns` for all three schemes.
///
/// Returns `results[scheme_index][n_index]` in `Scheme::ALL` order.
pub fn sweep(op: Op, ns: &[usize], cfg: &EvalConfig) -> Vec<Vec<ErrorStats>> {
    let pairs = cfg.draw_pairs();
    Scheme::ALL
        .iter()
        .map(|&scheme| {
            ns.iter()
                .map(|&n| evaluate(scheme, op, n, &pairs, cfg))
                .collect()
        })
        .collect()
}

/// Theoretical EMSE of stochastic computing representation under U[0,1]:
/// `L = 1/(6N)` (§II-A).
pub fn theory_stochastic_repr_emse(n: usize) -> f64 {
    1.0 / (6.0 * n as f64)
}

/// Theoretical EMSE of the deterministic variant's representation under
/// U[0,1]: `L = 1/(12N²)` (§II-B) — also the §II lower bound.
pub fn theory_deterministic_repr_emse(n: usize) -> f64 {
    1.0 / (12.0 * (n * n) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> EvalConfig {
        EvalConfig {
            pairs: 60,
            trials: 120,
            seed: 0xBEEF,
        }
    }

    #[test]
    fn stochastic_repr_emse_matches_theory() {
        let cfg = small_cfg();
        let pairs = cfg.draw_pairs();
        for &n in &[32usize, 128] {
            let s = evaluate(Scheme::Stochastic, Op::Represent, n, &pairs, &cfg);
            let th = theory_stochastic_repr_emse(n);
            assert!(
                (s.emse - th).abs() < 0.35 * th,
                "n={n} emse={} theory={th}",
                s.emse
            );
        }
    }

    #[test]
    fn deterministic_repr_emse_matches_theory() {
        let cfg = small_cfg();
        let pairs = cfg.draw_pairs();
        for &n in &[32usize, 128] {
            let s = evaluate(Scheme::DeterministicVariant, Op::Represent, n, &pairs, &cfg);
            let th = theory_deterministic_repr_emse(n);
            assert!(
                (s.emse - th).abs() < 0.5 * th,
                "n={n} emse={} theory={th}",
                s.emse
            );
        }
    }

    #[test]
    fn dither_emse_near_optimal_rate() {
        let cfg = small_cfg();
        let pairs = cfg.draw_pairs();
        for &n in &[32usize, 128] {
            let s = evaluate(Scheme::Dither, Op::Represent, n, &pairs, &cfg);
            // EMSE ≤ 2/N² (the §II-D variance bound; bias = 0).
            assert!(
                s.emse <= 2.2 / (n * n) as f64,
                "n={n} emse={}",
                s.emse
            );
        }
    }

    #[test]
    fn ordering_stochastic_worst_for_emse() {
        let cfg = small_cfg();
        let n = 64;
        for op in Op::ALL {
            let pairs = cfg.draw_pairs();
            let sc = evaluate(Scheme::Stochastic, op, n, &pairs, &cfg);
            let di = evaluate(Scheme::Dither, op, n, &pairs, &cfg);
            assert!(
                di.emse < sc.emse / 3.0,
                "{op:?}: dither {0} vs stochastic {1}",
                di.emse,
                sc.emse
            );
        }
    }

    #[test]
    fn dither_bias_below_stochastic_bias() {
        // SEM argument of §V: sample |bias| for dither shrinks faster.
        let cfg = small_cfg();
        let pairs = cfg.draw_pairs();
        let n = 128;
        let sc = evaluate(Scheme::Stochastic, Op::Represent, n, &pairs, &cfg);
        let di = evaluate(Scheme::Dither, Op::Represent, n, &pairs, &cfg);
        assert!(
            di.bias_abs < sc.bias_abs,
            "dither {} vs stochastic {}",
            di.bias_abs,
            sc.bias_abs
        );
    }

    #[test]
    fn results_reproducible_across_thread_counts() {
        let cfg = small_cfg();
        let pairs = cfg.draw_pairs();
        std::env::set_var("DITHER_THREADS", "1");
        let a = evaluate(Scheme::Dither, Op::Multiply, 64, &pairs, &cfg);
        std::env::set_var("DITHER_THREADS", "4");
        let b = evaluate(Scheme::Dither, Op::Multiply, 64, &pairs, &cfg);
        std::env::remove_var("DITHER_THREADS");
        assert_eq!(a.emse, b.emse);
        assert_eq!(a.bias_abs, b.bias_abs);
    }

    #[test]
    fn sweep_shape() {
        let cfg = EvalConfig {
            pairs: 10,
            trials: 10,
            seed: 1,
        };
        let out = sweep(Op::Represent, &[8, 16], &cfg);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|row| row.len() == 2));
    }
}
