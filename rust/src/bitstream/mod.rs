//! Bitstream computing core: the three schemes of the paper and the
//! arithmetic (§II–§IV) plus the evaluation harness (§V).
//!
//! * [`sequence::BitSeq`] — packed pulse sequences.
//! * [`stochastic::StochasticEncoder`] — classic stochastic computing (§II-A).
//! * [`deterministic::DeterministicEncoder`] — Jenson–Riedel deterministic
//!   variant, unary Format 1 + clock-division Format 2 (§II-B).
//! * [`dither::DitherEncoder`] — dither computing, the paper's contribution
//!   (§II-D), with prefix or spread placement of the deterministic pulses.
//! * [`ops`] — represent / multiply / average under a [`ops::Scheme`].
//! * [`analysis`] — bias/variance/EMSE estimation used by Figs 1–6, Table I.

pub mod analysis;
pub mod deterministic;
pub mod dither;
pub mod ops;
pub mod sequence;
pub mod stochastic;

pub use analysis::{
    evaluate, sweep, theory_deterministic_repr_emse, theory_stochastic_repr_emse, ErrorStats,
    EvalConfig,
};
pub use deterministic::DeterministicEncoder;
pub use dither::{DitherEncoder, DitherParams, Placement, ResidualSampling};
pub use ops::{average, control, encode_x, encode_y, multiply, represent, Op, Scheme};
pub use sequence::BitSeq;
pub use stochastic::StochasticEncoder;
