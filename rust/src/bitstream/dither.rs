//! Dither computing encoder — the paper's contribution (§II-D, §III-C).
//!
//! The idea: approximate `x` *deterministically* as closely as the length-N
//! sequence allows, and approximate only the remaining sub-1/N residue
//! *stochastically*, so the estimator is exactly unbiased (like stochastic
//! computing) while the variance collapses to `O(1/N²)` (like the
//! deterministic variant's EMSE):
//!
//! * `x ∈ [0, ½]`: `n = ⌊Nx⌋` pulses are deterministically 1, the other
//!   `N-n` are Bernoulli(δ) with `δ = Nr/(N-n)`, `r = x - n/N ∈ [0, 1/N)`.
//!   Then `E(X_s) = x` and `Var(X_s) ≤ 2/N²`.
//! * `x ∈ (½, 1]`: `n = ⌈Nx⌉` pulses are Bernoulli(1-δ) with `δ = rN/n`,
//!   `r = n/N - x`, the rest deterministically 0.
//!
//! Where the `n` "deterministic" pulses sit is governed by a permutation σ:
//! [`Placement::Prefix`] (σ = identity, used for the left operand and for
//! averaging) or [`Placement::Spread`] (σ spreads them evenly with a random
//! phase, §III-C's σ_y, used for the right multiplication operand).

use crate::bitstream::sequence::BitSeq;
use crate::util::rng::Xoshiro256pp;

/// Where the deterministic pulses of a dither encoding are placed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// σ = identity: deterministic pulses occupy a prefix (Format 1 analog).
    Prefix,
    /// σ spreads deterministic pulses evenly over the sequence with a random
    /// rotation `T` (Format 2 analog; §III-C's σ_y).
    Spread,
}

/// How the stochastic residual pulses are drawn.
///
/// §II-D specifies iid Bernoulli(δ) residuals, whose *count* is Binomial —
/// that alone contributes ≈ 0.5/N² to the representation EMSE, which is
/// enough to push dither's multiply/average EMSE *above* the deterministic
/// variant's, contradicting the paper's Figs 3–6. [`Systematic`] sampling
/// draws `⌊mδ⌋ + Bernoulli(frac(mδ))` ones placed evenly with a random
/// rotation: every slot still has inclusion probability exactly δ (the
/// estimator stays exactly unbiased and every §II-D bound still holds) but
/// the count variance collapses to ≤ 1/4 — realizing "stochastically
/// approximate the remaining difference" with the smallest possible noise,
/// and reproducing the paper's ordering. Ablation: `bench_ablation`
/// compares both (DESIGN.md §Ablations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResidualSampling {
    /// iid Bernoulli(δ) per slot (the paper's literal construction).
    Iid,
    /// Stratified: exact-count-in-expectation, evenly placed (default).
    Systematic,
}

/// The (n, δ, branch) parameterization of a dither encoding of `x`.
///
/// `lower_branch == true` means the `x ≤ ½` case: `n` sure ones plus
/// `N-n` Bernoulli(δ) pulses. `false` means the `x > ½` case: `n`
/// Bernoulli(1-δ) pulses plus `N-n` sure zeros.
#[derive(Clone, Copy, Debug)]
pub struct DitherParams {
    /// Number of "deterministic slot" pulses (meaning depends on branch).
    pub n: usize,
    /// Residual Bernoulli parameter δ ∈ [0, 2/N].
    pub delta: f64,
    /// Which half of the unit interval `x` fell in.
    pub lower_branch: bool,
}

impl DitherParams {
    /// Compute the encoding parameters for `x` (clamped to [0,1]) at length
    /// `len`. This is the arithmetic heart of §II-D.
    pub fn of(x: f64, len: usize) -> DitherParams {
        let x = x.clamp(0.0, 1.0);
        let nf = len as f64;
        if x <= 0.5 {
            let n = (nf * x).floor() as usize;
            let r = x - n as f64 / nf;
            let delta = if n >= len { 0.0 } else { (nf * r) / (nf - n as f64) };
            DitherParams {
                n,
                // Guard fp dust: δ must lie in [0, 1].
                delta: delta.clamp(0.0, 1.0),
                lower_branch: true,
            }
        } else {
            let n = (nf * x).ceil() as usize;
            let r = n as f64 / nf - x;
            let delta = if n == 0 { 0.0 } else { (r * nf) / n as f64 };
            DitherParams {
                n: n.min(len),
                delta: delta.clamp(0.0, 1.0),
                lower_branch: false,
            }
        }
    }

    /// The exact expectation of `X_s` under these parameters (= x, §II-D).
    pub fn expectation(&self, len: usize) -> f64 {
        let nf = len as f64;
        if self.lower_branch {
            (self.n as f64 + self.delta * (nf - self.n as f64)) / nf
        } else {
            self.n as f64 * (1.0 - self.delta) / nf
        }
    }

    /// The exact variance of `X_s` under these parameters (§II-D).
    pub fn variance(&self, len: usize) -> f64 {
        let nf = len as f64;
        let d = self.delta;
        if self.lower_branch {
            (nf - self.n as f64) * d * (1.0 - d) / (nf * nf)
        } else {
            self.n as f64 * d * (1.0 - d) / (nf * nf)
        }
    }
}

/// Encoder for the dither computing format.
#[derive(Clone, Copy, Debug)]
pub struct DitherEncoder {
    /// Placement of the deterministic pulses (σ).
    pub placement: Placement,
    /// Residual-pulse sampling strategy.
    pub residual: ResidualSampling,
}

impl Default for DitherEncoder {
    fn default() -> Self {
        Self {
            placement: Placement::Prefix,
            residual: ResidualSampling::Systematic,
        }
    }
}

impl DitherEncoder {
    /// Prefix-placement encoder (σ = identity).
    pub fn prefix() -> Self {
        Self {
            placement: Placement::Prefix,
            residual: ResidualSampling::Systematic,
        }
    }

    /// Spread-placement encoder (σ_y of §III-C).
    pub fn spread() -> Self {
        Self {
            placement: Placement::Spread,
            residual: ResidualSampling::Systematic,
        }
    }

    /// Switch the residual sampling strategy (for ablations).
    pub fn with_residual(mut self, residual: ResidualSampling) -> Self {
        self.residual = residual;
        self
    }

    /// Encode `x` as a length-`len` dither sequence.
    pub fn encode(&self, x: f64, len: usize, rng: &mut Xoshiro256pp) -> BitSeq {
        if len == 0 {
            return BitSeq::zeros(0);
        }
        let p = DitherParams::of(x, len);
        match self.placement {
            Placement::Prefix => encode_prefix(&p, len, self.residual, rng),
            Placement::Spread => encode_spread(&p, len, self.residual, rng),
        }
    }

    /// Dither control sequence for scaled addition (§IV-C): the alternating
    /// sequence `s_i = [i odd]` or its complement, each with probability ½
    /// — built from one alternating word constant per 64 pulses.
    pub fn control(&self, len: usize, rng: &mut Xoshiro256pp) -> BitSeq {
        let flip = rng.bernoulli(0.5);
        let word = if flip {
            0x5555_5555_5555_5555 // bit i set when i even
        } else {
            0xAAAA_AAAA_AAAA_AAAA // bit i set when i odd
        };
        BitSeq::from_words(len, vec![word; len.div_ceil(64)])
    }
}

/// Prefix placement: deterministic slots are positions `0..n`.
fn encode_prefix(
    p: &DitherParams,
    len: usize,
    residual: ResidualSampling,
    rng: &mut Xoshiro256pp,
) -> BitSeq {
    let mut seq = BitSeq::zeros(len);
    if p.lower_branch {
        // Ones on 0..n (word-filled — §Perf), residual(δ) on n..len.
        fill_prefix_ones(&mut seq, p.n);
        if p.delta > 0.0 {
            fill_range(&mut seq, p.n, len, p.delta, residual, rng);
        }
    } else {
        // Residual(1-δ) on 0..n, zeros elsewhere.
        if p.delta == 0.0 {
            fill_prefix_ones(&mut seq, p.n);
        } else {
            fill_range(&mut seq, 0, p.n, 1.0 - p.delta, residual, rng);
        }
    }
    seq
}

/// Set bits `0..n` word-parallel (64 bits per store).
fn fill_prefix_ones(seq: &mut BitSeq, n: usize) {
    let words = seq.words_mut();
    let full = n / 64;
    for w in words.iter_mut().take(full) {
        *w = u64::MAX;
    }
    let rem = n % 64;
    if rem != 0 {
        words[full] |= (1u64 << rem) - 1;
    }
}

/// Spread placement: the `n` deterministic slots are spread evenly with a
/// random rotation; the stochastic slots are the complement.
fn encode_spread(
    p: &DitherParams,
    len: usize,
    residual: ResidualSampling,
    rng: &mut Xoshiro256pp,
) -> BitSeq {
    let mut seq = BitSeq::zeros(len);
    let slots = spread_slots(p.n, len, rng);
    if p.lower_branch {
        // Deterministic ones on the spread slots...
        let mut is_slot = vec![false; len];
        for &s in &slots {
            seq.set(s, true);
            is_slot[s] = true;
        }
        // ...residual(δ) on the complement.
        if p.delta > 0.0 {
            let complement: Vec<usize> = (0..len).filter(|&i| !is_slot[i]).collect();
            fill_slots(&mut seq, &complement, p.delta, residual, rng);
        }
    } else {
        // Residual(1-δ) on the spread slots, zero elsewhere.
        if p.delta == 0.0 {
            for &s in &slots {
                seq.set(s, true);
            }
        } else {
            fill_slots(&mut seq, &slots, 1.0 - p.delta, residual, rng);
        }
    }
    seq
}

/// Fill a contiguous range with residual pulses of inclusion probability `p`.
fn fill_range(
    seq: &mut BitSeq,
    lo: usize,
    hi: usize,
    p: f64,
    residual: ResidualSampling,
    rng: &mut Xoshiro256pp,
) {
    match residual {
        ResidualSampling::Iid => fill_bernoulli(seq, lo, hi, p, rng),
        ResidualSampling::Systematic => {
            let m = hi - lo;
            if m == 0 {
                return;
            }
            if p > 0.5 {
                // Dense case (the x > ½ branch has p = 1-δ ≈ 1): word-fill
                // ones, then systematically CLEAR the few zeros — O(m/64 +
                // zeros) instead of O(m) single-bit sets (§Perf).
                let first_full = lo.div_ceil(64);
                let last_full = hi / 64;
                if first_full < last_full {
                    for w in &mut seq.words_mut()[first_full..last_full] {
                        *w = u64::MAX;
                    }
                }
                for i in lo..(first_full * 64).min(hi) {
                    seq.set(i, true);
                }
                for i in (last_full * 64).max(lo)..hi {
                    seq.set(i, true);
                }
                fill_systematic(
                    |i, s: &mut BitSeq| s.set(lo + i, false),
                    seq,
                    m,
                    1.0 - p,
                    rng,
                );
            } else {
                fill_systematic(|i, s: &mut BitSeq| s.set(lo + i, true), seq, m, p, rng);
            }
        }
    }
}

/// Fill an arbitrary slot list with residual pulses of probability `p`.
fn fill_slots(
    seq: &mut BitSeq,
    slots: &[usize],
    p: f64,
    residual: ResidualSampling,
    rng: &mut Xoshiro256pp,
) {
    match residual {
        ResidualSampling::Iid => {
            if p <= 0.0 {
                return;
            }
            if p >= 1.0 {
                for &s in slots {
                    seq.set(s, true);
                }
                return;
            }
            let threshold = (p * 18446744073709551616.0) as u64;
            for &s in slots {
                if rng.next_u64() < threshold {
                    seq.set(s, true);
                }
            }
        }
        ResidualSampling::Systematic => {
            fill_systematic(
                |i, s: &mut BitSeq| s.set(slots[i], true),
                seq,
                slots.len(),
                p,
                rng,
            );
        }
    }
}

/// Systematic (stratified) sampling core: choose `⌊mp⌋ + Bernoulli(frac)`
/// of `m` slots, evenly spaced with a uniformly random rotation. Every slot
/// has inclusion probability exactly `p`; the count varies by at most 1.
fn fill_systematic(
    mut set: impl FnMut(usize, &mut BitSeq),
    seq: &mut BitSeq,
    m: usize,
    p: f64,
    rng: &mut Xoshiro256pp,
) {
    if m == 0 || p <= 0.0 {
        return;
    }
    let target = p.min(1.0) * m as f64;
    let mut count = target.floor() as usize;
    if rng.bernoulli(target - count as f64) {
        count += 1;
    }
    let count = count.min(m);
    if count == 0 {
        return;
    }
    let offset = rng.below(m as u64) as usize;
    for i in 0..count {
        set(((i * m) / count + offset) % m, seq);
    }
}

/// Evenly-spaced slot positions: `σ(i) = (⌊i·len/m⌋ + offset) mod len` for
/// `i < m`, with a uniformly random rotation `offset`. Distinct because the
/// stride `len/m ≥ 1`.
pub fn spread_slots(m: usize, len: usize, rng: &mut Xoshiro256pp) -> Vec<usize> {
    if m == 0 || len == 0 {
        return Vec::new();
    }
    let m = m.min(len);
    let offset = rng.below(len as u64) as usize;
    (0..m)
        .map(|i| ((i * len) / m + offset) % len)
        .collect()
}

/// Fill positions `[lo, hi)` with iid Bernoulli(p) pulses.
///
/// Each 64-bit draw funds *two* trials — the low and high 32-bit halves
/// are compared against a 32-bit threshold, the same batching the
/// stochastic encoder uses — so the RNG is called once per two positions
/// instead of once per bit. Index order `lo..hi` is preserved.
fn fill_bernoulli(seq: &mut BitSeq, lo: usize, hi: usize, p: f64, rng: &mut Xoshiro256pp) {
    if p <= 0.0 {
        return;
    }
    if p >= 1.0 {
        for i in lo..hi {
            seq.set(i, true);
        }
        return;
    }
    let threshold = (p * 4294967296.0) as u32;
    let mut i = lo;
    while i + 1 < hi {
        let r = rng.next_u64();
        if (r as u32) < threshold {
            seq.set(i, true);
        }
        if ((r >> 32) as u32) < threshold {
            seq.set(i + 1, true);
        }
        i += 2;
    }
    if i < hi && (rng.next_u64() as u32) < threshold {
        seq.set(i, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn params_expectation_equals_x() {
        for len in [8usize, 64, 100, 127] {
            for k in 0..=200 {
                let x = k as f64 / 200.0;
                let p = DitherParams::of(x, len);
                assert!(
                    (p.expectation(len) - x).abs() < 1e-12,
                    "len={len} x={x} p={p:?}"
                );
            }
        }
    }

    #[test]
    fn params_delta_bound() {
        // §II-D: δ ≤ 2/N on both branches.
        for len in [16usize, 64, 256] {
            for k in 0..=1000 {
                let x = k as f64 / 1000.0;
                let p = DitherParams::of(x, len);
                assert!(
                    p.delta <= 2.0 / len as f64 + 1e-12,
                    "len={len} x={x} delta={}",
                    p.delta
                );
            }
        }
    }

    #[test]
    fn params_variance_bound() {
        // §II-D: Var(X_s) ≤ 2/N².
        for len in [16usize, 64, 256] {
            for k in 0..=1000 {
                let x = k as f64 / 1000.0;
                let p = DitherParams::of(x, len);
                let bound = 2.0 / (len as f64 * len as f64);
                assert!(p.variance(len) <= bound + 1e-15, "len={len} x={x}");
            }
        }
    }

    #[test]
    fn encode_is_unbiased() {
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(10);
        for &x in &[0.05, 0.31, 0.5, 0.52, 0.77, 0.99] {
            let mut w = Welford::new();
            for _ in 0..4000 {
                w.push(enc.encode(x, 64, &mut rng).value());
            }
            // SEM here is ~ (2/N)/sqrt(T) ≈ 5e-4; allow 5 sigma.
            assert!((w.mean() - x).abs() < 3e-3, "x={x} mean={}", w.mean());
        }
    }

    #[test]
    fn encode_error_within_one_pulse() {
        // Every sample satisfies |X_s - x| < 1/N + 1/N (det part is within
        // 1/N and the stochastic part only adds/removes ≤ N Bernoulli(2/N)).
        // We check the much tighter empirical bound that errors are O(1/N).
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(11);
        let n = 256;
        for &x in &[0.2, 0.5, 0.8] {
            for _ in 0..200 {
                let v = enc.encode(x, n, &mut rng).value();
                assert!(
                    (v - x).abs() < 20.0 / n as f64,
                    "x={x} v={v}"
                );
            }
        }
    }

    #[test]
    fn variance_is_order_inverse_n_squared() {
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(12);
        let x = 0.37;
        for &n in &[32usize, 128, 512] {
            let mut w = Welford::new();
            for _ in 0..3000 {
                w.push(enc.encode(x, n, &mut rng).value());
            }
            let bound = 2.0 / (n as f64 * n as f64);
            // Sample variance within 40% of the analytic bound's scale.
            assert!(
                w.variance() <= 1.4 * bound,
                "n={n} var={} bound={bound}",
                w.variance()
            );
        }
    }

    #[test]
    fn endpoints_exact() {
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(13);
        assert_eq!(enc.encode(0.0, 128, &mut rng).value(), 0.0);
        assert_eq!(enc.encode(1.0, 128, &mut rng).value(), 1.0);
    }

    #[test]
    fn exact_rationals_are_deterministic() {
        // x = m/N has r = 0, δ = 0: the encoding is fully deterministic.
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(14);
        let n = 64;
        for m in 0..=n {
            let x = m as f64 / n as f64;
            let a = enc.encode(x, n, &mut rng).value();
            let b = enc.encode(x, n, &mut rng).value();
            assert_eq!(a, x);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn spread_slots_are_distinct_and_even() {
        let mut rng = Xoshiro256pp::new(15);
        let slots = spread_slots(16, 64, &mut rng);
        assert_eq!(slots.len(), 16);
        let mut sorted = slots.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 16, "slots must be distinct");
        // Gaps between consecutive sorted slots ≈ 64/16 = 4.
        for pair in sorted.windows(2) {
            assert!(pair[1] - pair[0] <= 5);
        }
    }

    #[test]
    fn spread_encoding_also_unbiased() {
        let enc = DitherEncoder::spread();
        let mut rng = Xoshiro256pp::new(16);
        for &x in &[0.23, 0.5, 0.81] {
            let mut w = Welford::new();
            for _ in 0..4000 {
                w.push(enc.encode(x, 64, &mut rng).value());
            }
            assert!((w.mean() - x).abs() < 3e-3, "x={x} mean={}", w.mean());
        }
    }

    #[test]
    fn control_alternates_with_random_phase() {
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(17);
        let mut phases = [0u32; 2];
        for _ in 0..200 {
            let c = enc.control(64, &mut rng);
            // Exactly half the pulses are 1 and they alternate.
            assert_eq!(c.count_ones(), 32);
            for i in 0..63 {
                assert_ne!(c.get(i), c.get(i + 1));
            }
            phases[c.get(0) as usize] += 1;
        }
        // Both phases occur (probability each ~ 1/2).
        assert!(phases[0] > 50 && phases[1] > 50, "{phases:?}");
    }

    #[test]
    fn control_word_constant_matches_per_bit_reference() {
        // Golden pin for the word-constant rewrite: identical to the
        // original `from_fn(len, |i| (i % 2 == 1) ^ flip)` build at every
        // length class, consuming the same single RNG draw.
        let enc = DitherEncoder::prefix();
        for n in [0usize, 1, 2, 63, 64, 65, 129] {
            for seed in [17u64, 91, 4242] {
                let mut fast_rng = Xoshiro256pp::new(seed);
                let mut ref_rng = Xoshiro256pp::new(seed);
                let fast = enc.control(n, &mut fast_rng);
                let flip = ref_rng.bernoulli(0.5);
                let slow = BitSeq::from_fn(n, |i| (i % 2 == 1) ^ flip);
                assert_eq!(fast, slow, "n={n} seed={seed}");
                assert_eq!(fast_rng.next_u64(), ref_rng.next_u64(), "seed={seed}");
            }
        }
    }

    #[test]
    fn fill_bernoulli_stays_in_range_and_handles_edges() {
        let mut rng = Xoshiro256pp::new(20);
        for (lo, hi) in [(0usize, 0usize), (3, 4), (0, 64), (5, 70), (7, 100)] {
            let mut seq = BitSeq::zeros(128);
            fill_bernoulli(&mut seq, lo, hi, 0.5, &mut rng);
            for i in 0..128 {
                if !(lo..hi).contains(&i) {
                    assert!(!seq.get(i), "lo={lo} hi={hi} bit {i} leaked");
                }
            }
        }
        let mut all = BitSeq::zeros(70);
        fill_bernoulli(&mut all, 3, 70, 1.0, &mut rng);
        assert_eq!(all.count_ones(), 67);
        let mut none = BitSeq::zeros(70);
        fill_bernoulli(&mut none, 3, 70, 0.0, &mut rng);
        assert_eq!(none.count_ones(), 0);
    }

    #[test]
    fn fill_bernoulli_mean_matches_p() {
        // The paired-draw rewrite (two 32-bit trials per u64) must keep the
        // marginal inclusion probability at p.
        let mut rng = Xoshiro256pp::new(21);
        let (lo, hi, p) = (3usize, 1000usize, 0.25);
        let trials = 400;
        let mut total = 0u64;
        for _ in 0..trials {
            let mut seq = BitSeq::zeros(1024);
            fill_bernoulli(&mut seq, lo, hi, p, &mut rng);
            total += seq.count_ones();
        }
        let mean = total as f64 / trials as f64;
        let expect = p * (hi - lo) as f64;
        // Per-trial SD ≈ √(m·p·(1-p)) ≈ 13.7, SEM ≈ 0.69; allow ~6σ.
        assert!((mean - expect).abs() < 4.0, "mean={mean} expect={expect}");
    }

    #[test]
    fn zero_length_is_safe() {
        let enc = DitherEncoder::prefix();
        let mut rng = Xoshiro256pp::new(18);
        assert_eq!(enc.encode(0.5, 0, &mut rng).len(), 0);
    }
}
