//! Packed binary pulse sequences.
//!
//! A [`BitSeq`] is the length-`N` sequence of pulses `X_1..X_N` from the
//! paper (§II), stored 64 bits per `u64` word so the arithmetic operations
//! (bitwise-AND multiply, MUX scaled-add) and the value estimate
//! `X_s = (1/N)·Σ X_i` (a popcount) run word-parallel.

/// A fixed-length sequence of binary pulses, bit-packed into u64 words.
///
/// Bit `i` of the sequence lives at word `i / 64`, bit `i % 64`. Bits at
/// positions `>= len` in the last word are always kept zero (the invariant
/// every constructor and operation maintains), so `count_ones` is a plain
/// word-wise popcount.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSeq {
    words: Vec<u64>,
    len: usize,
}

impl BitSeq {
    /// All-zero sequence of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// All-one sequence of length `len`.
    pub fn ones(len: usize) -> Self {
        let mut s = Self::zeros(len);
        for w in &mut s.words {
            *w = u64::MAX;
        }
        s.mask_tail();
        s
    }

    /// Build from a predicate over bit index. Bits accumulate into a local
    /// word that is stored once per 64 positions (one memory write per
    /// word instead of a read-modify-write per bit).
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> bool) -> Self {
        let mut words = Vec::with_capacity(len.div_ceil(64));
        let mut i = 0;
        while i < len {
            let take = (len - i).min(64);
            let mut w = 0u64;
            for b in 0..take {
                w |= u64::from(f(i + b)) << b;
            }
            words.push(w);
            i += take;
        }
        Self { words, len }
    }

    /// Build from a bool slice, one word at a time.
    pub fn from_bools(bits: &[bool]) -> Self {
        let words = bits
            .chunks(64)
            .map(|chunk| {
                let mut w = 0u64;
                for (b, &bit) in chunk.iter().enumerate() {
                    w |= u64::from(bit) << b;
                }
                w
            })
            .collect();
        Self {
            words,
            len: bits.len(),
        }
    }

    /// Word-at-a-time construction: takes ownership of pre-filled backing
    /// words (bit `i` at word `i / 64`, bit `i % 64`) and masks the tail
    /// to restore the invariant. `words.len()` must be exactly
    /// `len.div_ceil(64)`.
    pub fn from_words(len: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), len.div_ceil(64), "word count must match len");
        let mut s = Self { words, len };
        s.mask_tail();
        s
    }

    /// Sequence length `N`.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if `N == 0`.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Get bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        let m = 1u64 << (i % 64);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Number of 1-pulses — a word-parallel popcount reduction routed
    /// through the active kernel.
    #[inline]
    pub fn count_ones(&self) -> u64 {
        crate::kernels::active().popcount_words(&self.words)
    }

    /// The value estimate `X_s = count_ones / N` (§II).
    #[inline]
    pub fn value(&self) -> f64 {
        if self.len == 0 {
            0.0
        } else {
            self.count_ones() as f64 / self.len as f64
        }
    }

    /// Bitwise AND — the stochastic-computing multiplier (§III).
    pub fn and(&self, other: &BitSeq) -> BitSeq {
        assert_eq!(self.len, other.len, "sequence lengths must match");
        let mut words = vec![0u64; self.words.len()];
        crate::kernels::active().and_words(&self.words, &other.words, &mut words);
        BitSeq {
            words,
            len: self.len,
        }
    }

    /// `popcount(self & other)` — the §III AND-multiply count without
    /// materializing the intermediate sequence. Routed through the active
    /// kernel's fused pass (the wide variant's headline win).
    pub fn and_count(&self, other: &BitSeq) -> u64 {
        assert_eq!(self.len, other.len, "sequence lengths must match");
        crate::kernels::active().and_popcount(&self.words, &other.words)
    }

    /// MUX select — the scaled-addition operator (§IV):
    /// `U_i = W_i·X_i + (1-W_i)·Y_i`, computed word-parallel as
    /// `(w & x) | (!w & y)`.
    pub fn mux(control: &BitSeq, x: &BitSeq, y: &BitSeq) -> BitSeq {
        assert_eq!(control.len, x.len, "sequence lengths must match");
        assert_eq!(control.len, y.len, "sequence lengths must match");
        let mut words = vec![0u64; control.words.len()];
        crate::kernels::active().mux_words(&control.words, &x.words, &y.words, &mut words);
        let mut s = BitSeq {
            words,
            len: control.len,
        };
        // `!w` may set tail bits; re-mask to preserve the invariant.
        s.mask_tail();
        s
    }

    /// Raw words (read-only; used by the perf-critical encoders).
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable raw words. Callers must uphold the tail-zero invariant or
    /// call [`BitSeq::mask_tail`] afterwards.
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Zero any bits at positions `>= len` in the final word.
    #[inline]
    pub fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Iterate bits as bools.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_ones_counts() {
        for n in [0usize, 1, 63, 64, 65, 130, 1024] {
            assert_eq!(BitSeq::zeros(n).count_ones(), 0);
            assert_eq!(BitSeq::ones(n).count_ones(), n as u64);
        }
    }

    #[test]
    fn ones_value_is_one() {
        assert_eq!(BitSeq::ones(100).value(), 1.0);
        assert_eq!(BitSeq::zeros(100).value(), 0.0);
        assert_eq!(BitSeq::zeros(0).value(), 0.0);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut s = BitSeq::zeros(130);
        s.set(0, true);
        s.set(63, true);
        s.set(64, true);
        s.set(129, true);
        assert!(s.get(0) && s.get(63) && s.get(64) && s.get(129));
        assert!(!s.get(1) && !s.get(65));
        assert_eq!(s.count_ones(), 4);
        s.set(63, false);
        assert_eq!(s.count_ones(), 3);
    }

    #[test]
    fn from_fn_matches_get() {
        let s = BitSeq::from_fn(200, |i| i % 3 == 0);
        for i in 0..200 {
            assert_eq!(s.get(i), i % 3 == 0);
        }
    }

    #[test]
    fn from_fn_matches_bit_by_bit_construction() {
        // Golden pin for the word-at-a-time rewrite: for assorted lengths
        // (including ragged tails) the word-accumulating path must equal
        // the original set()-per-bit construction exactly.
        for n in [0usize, 1, 7, 63, 64, 65, 127, 128, 200, 1000] {
            let f = |i: usize| (i * i + 3 * i) % 5 < 2;
            let fast = BitSeq::from_fn(n, f);
            let mut slow = BitSeq::zeros(n);
            for i in 0..n {
                if f(i) {
                    slow.set(i, true);
                }
            }
            assert_eq!(fast, slow, "n={n}");
            assert_eq!(fast.words().len(), n.div_ceil(64), "n={n}");
        }
    }

    #[test]
    fn from_bools_and_from_words_roundtrip() {
        let bits: Vec<bool> = (0..130).map(|i| i % 7 == 0).collect();
        let a = BitSeq::from_bools(&bits);
        let b = BitSeq::from_fn(130, |i| bits[i]);
        assert_eq!(a, b);
        let c = BitSeq::from_words(130, a.words().to_vec());
        assert_eq!(c, a);
        // from_words masks an over-filled tail back to the invariant.
        let d = BitSeq::from_words(70, vec![u64::MAX, u64::MAX]);
        assert_eq!(d.count_ones(), 70);
    }

    #[test]
    #[should_panic(expected = "word count")]
    fn from_words_wrong_word_count_panics() {
        let _ = BitSeq::from_words(65, vec![0]);
    }

    #[test]
    fn and_count_matches_and_then_count() {
        for n in [0usize, 1, 64, 65, 150, 1000] {
            let a = BitSeq::from_fn(n, |i| i % 2 == 0);
            let b = BitSeq::from_fn(n, |i| i % 3 == 0);
            assert_eq!(a.and_count(&b), a.and(&b).count_ones(), "n={n}");
        }
    }

    #[test]
    fn and_is_bitwise_product() {
        let a = BitSeq::from_fn(150, |i| i % 2 == 0);
        let b = BitSeq::from_fn(150, |i| i % 3 == 0);
        let c = a.and(&b);
        for i in 0..150 {
            assert_eq!(c.get(i), i % 6 == 0);
        }
    }

    #[test]
    fn mux_selects_per_bit() {
        let w = BitSeq::from_fn(100, |i| i % 2 == 0);
        let x = BitSeq::ones(100);
        let y = BitSeq::zeros(100);
        let u = BitSeq::mux(&w, &x, &y);
        for i in 0..100 {
            assert_eq!(u.get(i), i % 2 == 0);
        }
    }

    #[test]
    fn mux_preserves_tail_invariant() {
        // control all-zero selects y = ones; tail bits must stay zero.
        let w = BitSeq::zeros(70);
        let x = BitSeq::zeros(70);
        let y = BitSeq::ones(70);
        let u = BitSeq::mux(&w, &x, &y);
        assert_eq!(u.count_ones(), 70);
        assert_eq!(u.value(), 1.0);
    }

    #[test]
    #[should_panic(expected = "lengths must match")]
    fn and_length_mismatch_panics() {
        let _ = BitSeq::zeros(10).and(&BitSeq::zeros(11));
    }

    #[test]
    fn value_of_half() {
        let s = BitSeq::from_fn(128, |i| i < 64);
        assert_eq!(s.value(), 0.5);
    }
}
