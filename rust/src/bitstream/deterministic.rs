//! Deterministic variant of stochastic computing (Jenson & Riedel, ICCAD'16)
//! — paper §II-B, §III-B, §IV-B.
//!
//! Two operand formats:
//!
//! * **Format 1 (unary)**: the first `R = round(N·x)` pulses are 1. Used for
//!   the left multiplication operand and both averaging operands.
//! * **Format 2 (clock division)**: pulse `i` is 1 iff
//!   `⌊(i+1)·y⌋ ≠ ⌊i·y⌋`, which spreads `⌊N·y⌋` ones evenly. Used for the
//!   right multiplication operand so the AND of the two formats counts
//!   `≈ N·x·y` ones.
//!
//! Both are deterministic: `Var(X_s) = 0`, but the representation is biased
//! (`Θ(1/N)` bias), which is exactly the deficiency dither computing fixes.

use crate::bitstream::sequence::BitSeq;

/// Encoder for the deterministic variant's two formats.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeterministicEncoder;

impl DeterministicEncoder {
    /// Format 1 (unary): first `round(n·x)` pulses are 1.
    pub fn encode_unary(&self, x: f64, n: usize) -> BitSeq {
        let x = x.clamp(0.0, 1.0);
        let r = (n as f64 * x).round() as usize;
        let r = r.min(n);
        let mut seq = BitSeq::zeros(n);
        let words = seq.words_mut();
        let full = r / 64;
        for w in words.iter_mut().take(full) {
            *w = u64::MAX;
        }
        let rem = r % 64;
        if rem != 0 {
            words[full] = (1u64 << rem) - 1;
        }
        seq
    }

    /// Format 2 (clock division): pulse `i` is 1 iff `⌊(i+1)y⌋ ≠ ⌊iy⌋`.
    /// Exactly `⌊n·y⌋` ones, spread as evenly as possible.
    pub fn encode_clock_div(&self, y: f64, n: usize) -> BitSeq {
        let y = y.clamp(0.0, 1.0);
        BitSeq::from_fn(n, |i| {
            let a = (i as f64 * y).floor();
            let b = ((i + 1) as f64 * y).floor();
            a != b
        })
    }

    /// Deterministic alternating control sequence for scaled addition
    /// (§IV-B): `W_i = 1` for even `i` — one 0x5555… word constant per 64
    /// pulses instead of a per-bit build.
    pub fn control(&self, n: usize) -> BitSeq {
        BitSeq::from_words(n, vec![0x5555_5555_5555_5555; n.div_ceil(64)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unary_count_is_rounded() {
        let enc = DeterministicEncoder;
        assert_eq!(enc.encode_unary(0.5, 100).count_ones(), 50);
        assert_eq!(enc.encode_unary(0.504, 100).count_ones(), 50);
        assert_eq!(enc.encode_unary(0.505, 100).count_ones(), 51);
        assert_eq!(enc.encode_unary(0.0, 100).count_ones(), 0);
        assert_eq!(enc.encode_unary(1.0, 100).count_ones(), 100);
    }

    #[test]
    fn unary_is_prefix() {
        let enc = DeterministicEncoder;
        let s = enc.encode_unary(0.37, 200);
        let r = s.count_ones() as usize;
        for i in 0..200 {
            assert_eq!(s.get(i), i < r);
        }
    }

    #[test]
    fn unary_bias_bound() {
        // |X_s - x| <= 1/(2N) for unary rounding.
        let enc = DeterministicEncoder;
        let n = 128;
        for k in 0..100 {
            let x = k as f64 / 99.0;
            let err = (enc.encode_unary(x, n).value() - x).abs();
            assert!(err <= 0.5 / n as f64 + 1e-12, "x={x} err={err}");
        }
    }

    #[test]
    fn clock_div_count() {
        let enc = DeterministicEncoder;
        for &y in &[0.0, 0.1, 0.33, 0.5, 0.77, 1.0] {
            let s = enc.encode_clock_div(y, 128);
            assert_eq!(s.count_ones(), (128.0 * y).floor() as u64, "y={y}");
        }
    }

    #[test]
    fn clock_div_spreads_evenly() {
        // For y = 0.5 the ones should land on every other pulse.
        let enc = DeterministicEncoder;
        let s = enc.encode_clock_div(0.5, 64);
        let mut gaps = Vec::new();
        let mut last: Option<usize> = None;
        for i in 0..64 {
            if s.get(i) {
                if let Some(l) = last {
                    gaps.push(i - l);
                }
                last = Some(i);
            }
        }
        assert!(gaps.iter().all(|&g| g == 2), "gaps={gaps:?}");
    }

    #[test]
    fn unary_and_clock_div_multiply() {
        // AND of Format1(x) and Format2(y) counts ≈ N·x·y ones (§III-B:
        // |Z_s - xy| <= 2/N).
        let enc = DeterministicEncoder;
        let n = 256;
        for &(x, y) in &[(0.3, 0.7), (0.9, 0.2), (0.55, 0.55), (1.0, 0.4)] {
            let z = enc.encode_unary(x, n).and(&enc.encode_clock_div(y, n));
            let err = (z.value() - x * y).abs();
            assert!(err <= 2.0 / n as f64 + 1e-12, "x={x} y={y} err={err}");
        }
    }

    #[test]
    fn control_alternates() {
        let enc = DeterministicEncoder;
        let c = enc.control(101);
        assert_eq!(c.count_ones(), 51); // ceil(101/2) even indices 0,2,..,100
        assert!(c.get(0) && !c.get(1) && c.get(2));
    }

    #[test]
    fn control_word_constant_matches_per_bit_reference() {
        // Golden pin for the word-constant rewrite: identical to the
        // original `from_fn(n, |i| i % 2 == 0)` at every length class.
        let enc = DeterministicEncoder;
        for n in [0usize, 1, 2, 63, 64, 65, 100, 129] {
            assert_eq!(enc.control(n), BitSeq::from_fn(n, |i| i % 2 == 0), "n={n}");
        }
    }
}
