//! Scheme-level arithmetic: represent, multiply (§III), scaled-add (§IV).
//!
//! [`Scheme`] selects one of the three computing frameworks the paper
//! compares; the free functions produce the estimator value for one trial,
//! using the operand formats the paper prescribes per operation:
//!
//! | op        | left operand         | right operand        | control |
//! |-----------|----------------------|----------------------|---------|
//! | represent | scheme's x-format    | —                    | —       |
//! | multiply  | Format 1 / σ=prefix  | Format 2 / σ=spread  | —       |
//! | average   | Format 1 / σ=prefix  | Format 1 / σ=prefix  | scheme's W |

use crate::bitstream::deterministic::DeterministicEncoder;
use crate::bitstream::dither::DitherEncoder;
use crate::bitstream::sequence::BitSeq;
use crate::bitstream::stochastic::StochasticEncoder;
use crate::util::rng::Xoshiro256pp;

/// The three computing schemes compared throughout the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Classic unipolar stochastic computing (§II-A).
    Stochastic,
    /// Jenson–Riedel deterministic variant (§II-B).
    DeterministicVariant,
    /// The paper's dither computing (§II-D).
    Dither,
}

impl Scheme {
    /// All schemes, in the paper's comparison order.
    pub const ALL: [Scheme; 3] = [
        Scheme::Stochastic,
        Scheme::DeterministicVariant,
        Scheme::Dither,
    ];

    /// Display name matching the paper's figures.
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Stochastic => "stochastic",
            Scheme::DeterministicVariant => "deterministic",
            Scheme::Dither => "dither",
        }
    }

    /// Parse from CLI spelling.
    pub fn from_str(s: &str) -> Option<Scheme> {
        match s {
            "stochastic" | "sc" => Some(Scheme::Stochastic),
            "deterministic" | "det" => Some(Scheme::DeterministicVariant),
            "dither" => Some(Scheme::Dither),
            _ => None,
        }
    }

    /// Whether one trial fully determines the estimate (footnote 2 of §V:
    /// the deterministic variant needs only a single trial).
    pub fn is_deterministic(&self) -> bool {
        matches!(self, Scheme::DeterministicVariant)
    }
}

/// Encode `x` in the scheme's representation format (left-operand format).
pub fn encode_x(scheme: Scheme, x: f64, n: usize, rng: &mut Xoshiro256pp) -> BitSeq {
    match scheme {
        Scheme::Stochastic => StochasticEncoder.encode(x, n, rng),
        Scheme::DeterministicVariant => DeterministicEncoder.encode_unary(x, n),
        Scheme::Dither => DitherEncoder::prefix().encode(x, n, rng),
    }
}

/// Encode `y` in the scheme's right-multiplicand format.
pub fn encode_y(scheme: Scheme, y: f64, n: usize, rng: &mut Xoshiro256pp) -> BitSeq {
    match scheme {
        Scheme::Stochastic => StochasticEncoder.encode(y, n, rng),
        Scheme::DeterministicVariant => DeterministicEncoder.encode_clock_div(y, n),
        Scheme::Dither => DitherEncoder::spread().encode(y, n, rng),
    }
}

/// One-trial estimate of `x` (the §II representation experiment).
pub fn represent(scheme: Scheme, x: f64, n: usize, rng: &mut Xoshiro256pp) -> f64 {
    encode_x(scheme, x, n, rng).value()
}

/// One-trial estimate of `z = x·y` via bitwise AND (§III). The AND and
/// the popcount run as one fused kernel pass ([`BitSeq::and_count`]) —
/// the product sequence is never materialized.
pub fn multiply(scheme: Scheme, x: f64, y: f64, n: usize, rng: &mut Xoshiro256pp) -> f64 {
    let xs = encode_x(scheme, x, n, rng);
    let ys = encode_y(scheme, y, n, rng);
    if xs.is_empty() {
        0.0
    } else {
        xs.and_count(&ys) as f64 / xs.len() as f64
    }
}

/// The scheme's control sequence `W` for scaled addition (§IV).
pub fn control(scheme: Scheme, n: usize, rng: &mut Xoshiro256pp) -> BitSeq {
    match scheme {
        Scheme::Stochastic => StochasticEncoder.control(n, rng),
        Scheme::DeterministicVariant => DeterministicEncoder.control(n),
        Scheme::Dither => DitherEncoder::prefix().control(n, rng),
    }
}

/// One-trial estimate of `u = (x+y)/2` via MUX (§IV).
pub fn average(scheme: Scheme, x: f64, y: f64, n: usize, rng: &mut Xoshiro256pp) -> f64 {
    let xs = encode_x(scheme, x, n, rng);
    let ys = encode_x(scheme, y, n, rng);
    let w = control(scheme, n, rng);
    BitSeq::mux(&w, &xs, &ys).value()
}

/// The arithmetic operations the evaluation section sweeps.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// Representation of x (Figs 1–2).
    Represent,
    /// Product z = x·y (Figs 3–4).
    Multiply,
    /// Scaled addition u = (x+y)/2 (Figs 5–6).
    Average,
}

impl Op {
    /// All ops in figure order.
    pub const ALL: [Op; 3] = [Op::Represent, Op::Multiply, Op::Average];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Op::Represent => "represent",
            Op::Multiply => "multiply",
            Op::Average => "average",
        }
    }

    /// Ground-truth value for operands (x, y).
    pub fn truth(&self, x: f64, y: f64) -> f64 {
        match self {
            Op::Represent => x,
            Op::Multiply => x * y,
            Op::Average => 0.5 * (x + y),
        }
    }

    /// One-trial estimate under `scheme`.
    pub fn estimate(
        &self,
        scheme: Scheme,
        x: f64,
        y: f64,
        n: usize,
        rng: &mut Xoshiro256pp,
    ) -> f64 {
        match self {
            Op::Represent => represent(scheme, x, n, rng),
            Op::Multiply => multiply(scheme, x, y, n, rng),
            Op::Average => average(scheme, x, y, n, rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    fn mean_estimate(scheme: Scheme, op: Op, x: f64, y: f64, n: usize, trials: usize) -> f64 {
        let mut rng = Xoshiro256pp::new(99);
        let mut w = Welford::new();
        for _ in 0..trials {
            w.push(op.estimate(scheme, x, y, n, &mut rng));
        }
        w.mean()
    }

    #[test]
    fn multiply_means_converge_to_product() {
        for scheme in Scheme::ALL {
            let m = mean_estimate(scheme, Op::Multiply, 0.7, 0.6, 128, 3000);
            let tol = match scheme {
                Scheme::Stochastic => 0.01,
                // deterministic bias is O(1/N); dither mean error small.
                _ => 2.5 / 128.0,
            };
            assert!((m - 0.42).abs() < tol, "{scheme:?} mean={m}");
        }
    }

    #[test]
    fn average_means_converge() {
        for scheme in Scheme::ALL {
            let m = mean_estimate(scheme, Op::Average, 0.3, 0.8, 128, 3000);
            assert!((m - 0.55).abs() < 0.02, "{scheme:?} mean={m}");
        }
    }

    #[test]
    fn deterministic_multiply_error_bound() {
        // §III-B: |Z_s - xy| <= 2/N.
        let mut rng = Xoshiro256pp::new(7);
        let n = 256;
        for k in 0..50 {
            let x = (k as f64 + 0.5) / 50.0;
            let y = ((k * 7 % 50) as f64 + 0.5) / 50.0;
            let z = multiply(Scheme::DeterministicVariant, x, y, n, &mut rng);
            assert!((z - x * y).abs() <= 2.0 / n as f64 + 1e-12);
        }
    }

    #[test]
    fn dither_multiply_error_is_order_inverse_n() {
        // §III-C: |Z_s - z| <= c/N for a constant c.
        let mut rng = Xoshiro256pp::new(8);
        let n = 256;
        for k in 0..50 {
            let x = (k as f64 + 0.5) / 50.0;
            let y = ((k * 13 % 50) as f64 + 0.5) / 50.0;
            let z = multiply(Scheme::Dither, x, y, n, &mut rng);
            assert!(
                (z - x * y).abs() <= 8.0 / n as f64,
                "x={x} y={y} err={}",
                (z - x * y).abs()
            );
        }
    }

    #[test]
    fn dither_variance_beats_stochastic() {
        let n = 128;
        let (x, y) = (0.6, 0.7);
        let var = |scheme: Scheme| {
            let mut rng = Xoshiro256pp::new(9);
            let mut w = Welford::new();
            for _ in 0..3000 {
                w.push(Op::Multiply.estimate(scheme, x, y, n, &mut rng));
            }
            w.variance()
        };
        let vs = var(Scheme::Stochastic);
        let vd = var(Scheme::Dither);
        assert!(
            vd < vs / 4.0,
            "dither var {vd} should be well below stochastic {vs}"
        );
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::from_str("dither"), Some(Scheme::Dither));
        assert_eq!(Scheme::from_str("sc"), Some(Scheme::Stochastic));
        assert_eq!(Scheme::from_str("det"), Some(Scheme::DeterministicVariant));
        assert_eq!(Scheme::from_str("nope"), None);
    }

    #[test]
    fn op_truth_values() {
        assert_eq!(Op::Represent.truth(0.3, 0.9), 0.3);
        assert_eq!(Op::Multiply.truth(0.5, 0.5), 0.25);
        assert_eq!(Op::Average.truth(0.2, 0.6), 0.4);
    }
}
