//! Figs 1–6: EMSE `L` and sample |Bias| for representation (Figs 1–2),
//! multiplication (Figs 3–4) and scaled addition (Figs 5–6), for the three
//! schemes over a sweep of sequence lengths N.

use crate::bitstream::{sweep, ErrorStats, EvalConfig, Op, Scheme};
use crate::experiments::write_result;
use crate::util::json::Json;
use crate::util::stats::loglog_slope;

/// One figure's regenerated series.
pub struct FigureSeries {
    /// The operation the figure measures.
    pub op: Op,
    /// Sequence lengths (x axis).
    pub ns: Vec<usize>,
    /// Per-scheme stats, `Scheme::ALL` order.
    pub per_scheme: Vec<Vec<ErrorStats>>,
}

impl FigureSeries {
    /// Run the sweep for one operation.
    pub fn compute(op: Op, ns: &[usize], cfg: &EvalConfig) -> FigureSeries {
        FigureSeries {
            op,
            ns: ns.to_vec(),
            per_scheme: sweep(op, ns, cfg),
        }
    }

    /// EMSE series for one scheme.
    pub fn emse(&self, scheme: Scheme) -> Vec<f64> {
        let idx = Scheme::ALL.iter().position(|&s| s == scheme).unwrap();
        self.per_scheme[idx].iter().map(|s| s.emse).collect()
    }

    /// |Bias| series for one scheme.
    pub fn bias(&self, scheme: Scheme) -> Vec<f64> {
        let idx = Scheme::ALL.iter().position(|&s| s == scheme).unwrap();
        self.per_scheme[idx].iter().map(|s| s.bias_abs).collect()
    }

    /// Log-log slope of a series vs N.
    pub fn slope(&self, ys: &[f64]) -> Option<f64> {
        let xs: Vec<f64> = self.ns.iter().map(|&n| n as f64).collect();
        loglog_slope(&xs, ys)
    }
}

/// Print one figure (EMSE or |bias|) as an aligned table + slopes.
fn print_table(series: &FigureSeries, metric: &str) {
    println!("\n  {} of {} vs N:", metric, series.op.name());
    print!("  {:>6}", "N");
    for scheme in Scheme::ALL {
        print!("  {:>14}", scheme.name());
    }
    println!();
    for (i, &n) in series.ns.iter().enumerate() {
        print!("  {n:>6}");
        for (si, _) in Scheme::ALL.iter().enumerate() {
            let s = &series.per_scheme[si][i];
            let v = if metric == "EMSE" { s.emse } else { s.bias_abs };
            print!("  {v:>14.3e}");
        }
        println!();
    }
    print!("  {:>6}", "slope");
    for scheme in Scheme::ALL {
        let ys = if metric == "EMSE" {
            series.emse(scheme)
        } else {
            series.bias(scheme)
        };
        match series.slope(&ys) {
            Some(sl) => print!("  {sl:>14.2}"),
            None => print!("  {:>14}", "-"),
        }
    }
    println!();
}

fn series_json(series: &FigureSeries) -> Json {
    let mut fields = vec![
        ("op", Json::Str(series.op.name().to_string())),
        (
            "ns",
            Json::nums(&series.ns.iter().map(|&n| n as f64).collect::<Vec<_>>()),
        ),
    ];
    for (si, scheme) in Scheme::ALL.iter().enumerate() {
        let emse: Vec<f64> = series.per_scheme[si].iter().map(|s| s.emse).collect();
        let bias: Vec<f64> = series.per_scheme[si].iter().map(|s| s.bias_abs).collect();
        fields.push((
            match scheme {
                Scheme::Stochastic => "stochastic_emse",
                Scheme::DeterministicVariant => "deterministic_emse",
                Scheme::Dither => "dither_emse",
            },
            Json::nums(&emse),
        ));
        fields.push((
            match scheme {
                Scheme::Stochastic => "stochastic_bias",
                Scheme::DeterministicVariant => "deterministic_bias",
                Scheme::Dither => "dither_bias",
            },
            Json::nums(&bias),
        ));
    }
    Json::obj(fields)
}

/// Regenerate one of Figs 1–6. `fig` ∈ 1..=6.
pub fn run(fig: u32, ns: &[usize], cfg: &EvalConfig, out_dir: &str) -> FigureSeries {
    let (op, metric) = match fig {
        1 => (Op::Represent, "EMSE"),
        2 => (Op::Represent, "|Bias|"),
        3 => (Op::Multiply, "EMSE"),
        4 => (Op::Multiply, "|Bias|"),
        5 => (Op::Average, "EMSE"),
        6 => (Op::Average, "|Bias|"),
        _ => panic!("fig must be 1..=6"),
    };
    println!(
        "== Fig {fig}: {} of {} ({} pairs x {} trials) ==",
        metric,
        op.name(),
        cfg.pairs,
        cfg.trials
    );
    let series = FigureSeries::compute(op, ns, cfg);
    print_table(&series, metric);
    write_result(out_dir, &format!("fig{fig}"), series_json(&series));
    series
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> EvalConfig {
        EvalConfig {
            pairs: 30,
            trials: 60,
            seed: 5,
        }
    }

    #[test]
    fn emse_slopes_match_paper_orders() {
        // Stochastic ~ 1/N (slope ≈ -1); deterministic & dither ~ 1/N²
        // (slope ≈ -2). Tolerances are loose for the tiny config.
        let cfg = tiny_cfg();
        let series = FigureSeries::compute(Op::Represent, &[16, 64, 256], &cfg);
        let s_sto = series.slope(&series.emse(Scheme::Stochastic)).unwrap();
        let s_det = series
            .slope(&series.emse(Scheme::DeterministicVariant))
            .unwrap();
        let s_dit = series.slope(&series.emse(Scheme::Dither)).unwrap();
        assert!((-1.3..=-0.7).contains(&s_sto), "stochastic slope {s_sto}");
        assert!((-2.4..=-1.6).contains(&s_det), "deterministic slope {s_det}");
        assert!((-2.4..=-1.6).contains(&s_dit), "dither slope {s_dit}");
    }

    #[test]
    fn multiply_ordering_holds() {
        let cfg = tiny_cfg();
        let series = FigureSeries::compute(Op::Multiply, &[64], &cfg);
        let sto = series.emse(Scheme::Stochastic)[0];
        let dit = series.emse(Scheme::Dither)[0];
        assert!(dit < sto / 3.0, "dither {dit} vs stochastic {sto}");
    }

    #[test]
    fn json_record_is_valid() {
        let cfg = tiny_cfg();
        let series = FigureSeries::compute(Op::Average, &[16, 32], &cfg);
        let json = series_json(&series);
        assert!(json.get("dither_emse").is_some());
        assert_eq!(json.get("ns").unwrap().as_f64_vec().unwrap(), vec![16.0, 32.0]);
    }
}
