//! Regenerators for every table and figure in the paper's evaluation
//! (DESIGN.md §3 experiment index).
//!
//! * [`figs_bitstream`] — Figs 1–6: EMSE and |bias| of representation,
//!   multiplication and scaled addition vs N for the three schemes.
//! * [`table1`] — Table I: empirical asymptotic orders via log-log slopes.
//! * [`fig8`] — Fig 8: matmul Frobenius error vs bit width k.
//! * [`nn_figs`] — Figs 9–16: quantized-inference accuracy mean/variance
//!   vs k across rounding schemes, placements and the two tasks.
//! * [`runner`] — id → experiment dispatch used by the CLI and benches.
//!
//! Every experiment prints the series it regenerates and writes a JSON
//! record under `results/` for EXPERIMENTS.md.

pub mod fig8;
pub mod figs_bitstream;
pub mod nn_figs;
pub mod runner;
pub mod table1;

pub use runner::{run_experiment, ExperimentArgs, EXPERIMENT_IDS};

use crate::util::json::Json;

/// Write an experiment's JSON record under `out_dir` (best effort).
pub fn write_result(out_dir: &str, id: &str, json: Json) {
    let path = format!("{out_dir}/{id}.json");
    if let Some(parent) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(parent);
    }
    if let Err(e) = std::fs::write(&path, json.to_string()) {
        eprintln!("warning: could not write {path}: {e}");
    } else {
        println!("[wrote {path}]");
    }
}
