//! Experiment registry: id → regenerator, shared by the CLI and benches.

use crate::bail;
use crate::bitstream::EvalConfig;
use crate::experiments::{fig8, figs_bitstream, nn_figs, table1};
use crate::util::error::Result;

/// All experiment ids, in paper order.
pub const EXPERIMENT_IDS: [&str; 16] = [
    "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "table1", "fig8", "fig9", "fig10",
    "fig11", "fig12", "fig13", "fig14", "fig15", "fig16",
];

/// Shared experiment arguments (populated from CLI flags).
#[derive(Clone, Debug)]
pub struct ExperimentArgs {
    /// Operand pairs for Figs 1–6 / Table I.
    pub pairs: usize,
    /// Trials per pair for Figs 1–6 / Table I.
    pub trials: usize,
    /// N sweep for Figs 1–6 / Table I.
    pub ns: Vec<usize>,
    /// k sweep for Figs 8–16.
    pub ks: Vec<u32>,
    /// Matrix pairs for Fig 8.
    pub matmul_pairs: usize,
    /// Matrix dimension for Fig 8.
    pub dim: usize,
    /// Trials per (mode, k) for Figs 9–16.
    pub nn_trials: usize,
    /// Training set size for the model zoo.
    pub train_n: usize,
    /// Test set size for Figs 9–16.
    pub test_n: usize,
    /// Master seed.
    pub seed: u64,
    /// Output directory for JSON records.
    pub out_dir: String,
}

impl Default for ExperimentArgs {
    fn default() -> Self {
        Self {
            pairs: 200,
            trials: 200,
            ns: vec![4, 8, 16, 32, 64, 128, 256, 512, 1024],
            ks: (1..=8).collect(),
            matmul_pairs: 20,
            dim: 100,
            nn_trials: 10,
            train_n: 3000,
            test_n: 500,
            seed: 0xA11CE,
            out_dir: "results".to_string(),
        }
    }
}

impl ExperimentArgs {
    /// The paper's full-scale settings (slow: hours).
    pub fn paper_scale() -> Self {
        Self {
            pairs: 1000,
            trials: 1000,
            matmul_pairs: 100,
            nn_trials: 1000,
            train_n: 10_000,
            test_n: 10_000,
            ..Self::default()
        }
    }

    fn eval_config(&self) -> EvalConfig {
        EvalConfig {
            pairs: self.pairs,
            trials: self.trials,
            seed: self.seed,
        }
    }
}

/// Run one experiment by id ("fig1".."fig16", "table1", or "all").
pub fn run_experiment(id: &str, args: &ExperimentArgs) -> Result<()> {
    match id {
        "all" => {
            for id in EXPERIMENT_IDS {
                run_experiment(id, args)?;
                println!();
            }
            Ok(())
        }
        "fig1" | "fig2" | "fig3" | "fig4" | "fig5" | "fig6" => {
            let fig: u32 = id[3..].parse().unwrap();
            figs_bitstream::run(fig, &args.ns, &args.eval_config(), &args.out_dir);
            Ok(())
        }
        "table1" => {
            table1::run(&args.ns, &args.eval_config(), &args.out_dir);
            Ok(())
        }
        "fig8" => {
            let cfg = fig8::Fig8Config {
                pairs: args.matmul_pairs,
                dim: args.dim,
                ks: args.ks.clone(),
                hi: 0.5,
                seed: args.seed,
            };
            fig8::run(&cfg, &args.out_dir);
            Ok(())
        }
        "fig9" | "fig10" | "fig11" | "fig12" | "fig13" | "fig14" | "fig15" | "fig16" => {
            let fig: u32 = id[3..].parse().unwrap();
            let mut cfg = nn_figs::config_for_figure(fig);
            cfg.ks = args.ks.clone();
            cfg.trials = args.nn_trials;
            cfg.train_n = args.train_n;
            cfg.test_n = args.test_n;
            cfg.seed = args.seed;
            nn_figs::run(fig, &cfg, &args.out_dir);
            Ok(())
        }
        other => bail!(
            "unknown experiment {other:?}; available: all, {}",
            EXPERIMENT_IDS.join(", ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_errors() {
        let args = ExperimentArgs::default();
        assert!(run_experiment("fig99", &args).is_err());
    }

    #[test]
    fn tiny_fig1_runs_end_to_end() {
        let args = ExperimentArgs {
            pairs: 10,
            trials: 10,
            ns: vec![8, 16],
            out_dir: std::env::temp_dir()
                .join("dither_results_test")
                .to_string_lossy()
                .into_owned(),
            ..ExperimentArgs::default()
        };
        run_experiment("fig1", &args).unwrap();
        let path = format!("{}/fig1.json", args.out_dir);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(crate::util::json::Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn paper_scale_settings() {
        let p = ExperimentArgs::paper_scale();
        assert_eq!(p.pairs, 1000);
        assert_eq!(p.trials, 1000);
        assert_eq!(p.nn_trials, 1000);
    }
}
