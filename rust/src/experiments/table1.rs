//! Table I: asymptotic behaviour of Bias, Variance and EMSE for the three
//! schemes across the three operations, verified empirically as log-log
//! slopes over an N sweep.
//!
//! Expected orders (the paper's table):
//!
//! | metric     | Stoch.     | Determ.   | Dither     |
//! |------------|------------|-----------|------------|
//! | Bias       | 0          | Θ(1/N)    | 0          |
//! | Variance   | Ω(1/N)     | 0         | Θ(1/N²)    |
//! | EMSE       | Ω(1/N)     | Θ(1/N²)   | Θ(1/N²)    |
//!
//! "0" rows are checked as *magnitude far below the biased/variant scheme*
//! rather than as a slope (a sample estimate of an exactly-zero quantity is
//! sampling noise; its slope is the SEM's, as §V discusses).

use crate::bitstream::{evaluate, EvalConfig, Op, Scheme};
use crate::experiments::write_result;
use crate::util::json::Json;
use crate::util::stats::loglog_slope;

/// One (op, scheme) row of the empirical Table I.
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// Operation.
    pub op: Op,
    /// Scheme.
    pub scheme: Scheme,
    /// Slope of |bias| vs N (sample estimate; ≈ SEM slope for unbiased).
    pub bias_slope: Option<f64>,
    /// Slope of variance vs N.
    pub var_slope: Option<f64>,
    /// Slope of EMSE vs N.
    pub emse_slope: Option<f64>,
}

/// Compute the empirical Table I over the given N sweep.
pub fn compute(ns: &[usize], cfg: &EvalConfig) -> Vec<Table1Row> {
    let pairs = cfg.draw_pairs();
    let xs: Vec<f64> = ns.iter().map(|&n| n as f64).collect();
    let mut rows = Vec::new();
    for op in Op::ALL {
        for scheme in Scheme::ALL {
            let stats: Vec<_> = ns
                .iter()
                .map(|&n| evaluate(scheme, op, n, &pairs, cfg))
                .collect();
            let bias: Vec<f64> = stats.iter().map(|s| s.bias_abs).collect();
            let var: Vec<f64> = stats.iter().map(|s| s.variance).collect();
            let emse: Vec<f64> = stats.iter().map(|s| s.emse).collect();
            rows.push(Table1Row {
                op,
                scheme,
                bias_slope: loglog_slope(&xs, &bias),
                var_slope: loglog_slope(&xs, &var),
                emse_slope: loglog_slope(&xs, &emse),
            });
        }
    }
    rows
}

/// The paper's expected EMSE slope for a (scheme) column.
pub fn expected_emse_slope(scheme: Scheme) -> f64 {
    match scheme {
        Scheme::Stochastic => -1.0,
        Scheme::DeterministicVariant | Scheme::Dither => -2.0,
    }
}

/// Regenerate Table I: print the slope table and the paper's expectations.
pub fn run(ns: &[usize], cfg: &EvalConfig, out_dir: &str) -> Vec<Table1Row> {
    println!(
        "== Table I: empirical asymptotic orders (log-log slopes over N={ns:?}) ==\n"
    );
    println!(
        "  {:<10} {:<14} {:>12} {:>12} {:>12}   paper EMSE",
        "op", "scheme", "|bias| slope", "var slope", "EMSE slope"
    );
    let rows = compute(ns, cfg);
    for row in &rows {
        let fmt = |s: Option<f64>| match s {
            Some(v) => format!("{v:>12.2}"),
            None => format!("{:>12}", "-"),
        };
        println!(
            "  {:<10} {:<14} {} {} {}   Θ(N^{:.0})",
            row.op.name(),
            row.scheme.name(),
            fmt(row.bias_slope),
            fmt(row.var_slope),
            fmt(row.emse_slope),
            expected_emse_slope(row.scheme),
        );
    }
    println!(
        "\n  (unbiased schemes: the |bias| column tracks the SEM, falling ~N^-1 for\n   dither vs ~N^-0.5 for stochastic — the §V slope observation)"
    );
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("op", Json::Str(r.op.name().into())),
                    ("scheme", Json::Str(r.scheme.name().into())),
                    (
                        "bias_slope",
                        r.bias_slope.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("var_slope", r.var_slope.map(Json::Num).unwrap_or(Json::Null)),
                    (
                        "emse_slope",
                        r.emse_slope.map(Json::Num).unwrap_or(Json::Null),
                    ),
                    ("expected_emse_slope", Json::Num(expected_emse_slope(r.scheme))),
                ])
            })
            .collect(),
    );
    write_result(out_dir, "table1", json);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emse_slopes_match_expected_orders() {
        let cfg = EvalConfig {
            pairs: 30,
            trials: 60,
            seed: 11,
        };
        let rows = compute(&[16, 64, 256], &cfg);
        assert_eq!(rows.len(), 9);
        for row in &rows {
            let slope = row.emse_slope.expect("emse slope");
            let expected = expected_emse_slope(row.scheme);
            assert!(
                (slope - expected).abs() < 0.55,
                "{:?}/{:?}: slope {slope} vs expected {expected}",
                row.op,
                row.scheme
            );
        }
    }

    #[test]
    fn dither_variance_order_is_squared() {
        let cfg = EvalConfig {
            pairs: 30,
            trials: 80,
            seed: 13,
        };
        let rows = compute(&[16, 64, 256], &cfg);
        let dither_repr = rows
            .iter()
            .find(|r| r.scheme == Scheme::Dither && matches!(r.op, Op::Represent))
            .unwrap();
        let slope = dither_repr.var_slope.unwrap();
        assert!((-2.5..=-1.5).contains(&slope), "dither var slope {slope}");
    }
}
