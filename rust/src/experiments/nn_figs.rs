//! Figs 9–16: quantized-inference classification accuracy (mean and
//! variance over trials) vs bit width k, for the three rounding schemes.
//!
//! | Figs  | task    | network        | rounding placement          |
//! |-------|---------|----------------|-----------------------------|
//! | 9/10  | digits  | 1-layer softmax| per-partial (2pqr, Fig 7)   |
//! | 11/12 | digits  | 1-layer softmax| input rounded once (pq(r+1))|
//! | 13/14 | digits  | 1-layer softmax| matrices separate ((p+r)q)  |
//! | 15/16 | fashion | 3-layer MLP    | matrices separate           |
//!
//! Expected shapes: dither ≈ stochastic mean accuracy, both ≫ deterministic
//! for small k ≥ 2; dither variance < stochastic variance; the fashion task
//! shows a narrower beneficial-k window.

use crate::experiments::write_result;
use crate::linalg::Variant;
use crate::nn::{quantized_accuracy, ActivationRanges, QuantInferenceConfig};
use crate::rounding::SchemeId;
use crate::train::{trained_model, ModelSpec};
use crate::util::json::Json;
use crate::util::stats::Welford;
use crate::util::threadpool::parallel_map;

/// Configuration for one accuracy-vs-k sweep.
#[derive(Clone, Debug)]
pub struct NnFigConfig {
    /// Which evaluation model/task.
    pub spec: ModelSpec,
    /// Rounding placement.
    pub variant: Variant,
    /// Bit widths to sweep.
    pub ks: Vec<u32>,
    /// Trials per (mode, k) for the stochastic schemes (paper: 1000).
    pub trials: usize,
    /// Training set size (synthetic) for the model zoo.
    pub train_n: usize,
    /// Test set size.
    pub test_n: usize,
    /// Seed.
    pub seed: u64,
}

impl NnFigConfig {
    /// Defaults scaled for minutes-long runs; the CLI can raise them.
    pub fn new(spec: ModelSpec, variant: Variant) -> NnFigConfig {
        NnFigConfig {
            spec,
            variant,
            ks: (1..=8).collect(),
            trials: 10,
            train_n: 3000,
            test_n: 500,
            seed: 0x916,
        }
    }
}

/// Result: accuracy mean and variance per (mode, k).
pub struct NnFigResult {
    /// Bit widths.
    pub ks: Vec<u32>,
    /// Full-precision baseline accuracy.
    pub float_acc: f64,
    /// `mean[mode_index][k_index]` in `SchemeId::PAPER` order.
    pub mean: Vec<Vec<f64>>,
    /// Sample variance across trials.
    pub var: Vec<Vec<f64>>,
}

impl NnFigResult {
    /// Mean-accuracy series for one mode.
    pub fn mean_series(&self, mode: SchemeId) -> &[f64] {
        let idx = SchemeId::PAPER.iter().position(|&m| m == mode).unwrap();
        &self.mean[idx]
    }

    /// Variance series for one mode.
    pub fn var_series(&self, mode: SchemeId) -> &[f64] {
        let idx = SchemeId::PAPER.iter().position(|&m| m == mode).unwrap();
        &self.var[idx]
    }
}

/// Run the sweep.
pub fn compute(cfg: &NnFigConfig) -> NnFigResult {
    let (mlp, test, float_acc) =
        trained_model(cfg.spec, cfg.train_n, cfg.test_n, cfg.seed);
    let ranges = ActivationRanges::calibrate(&mlp, &test.images);
    // Work items: (mode index, k index, trial).
    let mut items = Vec::new();
    for (mi, &mode) in SchemeId::PAPER.iter().enumerate() {
        let trials = if mode == SchemeId::Deterministic {
            1
        } else {
            cfg.trials
        };
        for (ki, &k) in cfg.ks.iter().enumerate() {
            for t in 0..trials {
                items.push((mi, ki, k, mode, t as u64));
            }
        }
    }
    let accs = parallel_map(&items, |_, &(_mi, _ki, k, mode, t)| {
        let qcfg = QuantInferenceConfig {
            bits: k,
            mode,
            variant: cfg.variant,
            seed: cfg.seed ^ (t << 32) ^ ((k as u64) << 8) ^ mode as u64,
        };
        quantized_accuracy(&mlp, &test.images, &test.labels, &ranges, &qcfg)
    });
    let mut agg: Vec<Vec<Welford>> =
        vec![vec![Welford::new(); cfg.ks.len()]; SchemeId::PAPER.len()];
    for ((mi, ki, _, _, _), acc) in items.iter().zip(accs) {
        agg[*mi][*ki].push(acc);
    }
    NnFigResult {
        ks: cfg.ks.clone(),
        float_acc,
        mean: agg
            .iter()
            .map(|row| row.iter().map(Welford::mean).collect())
            .collect(),
        var: agg
            .iter()
            .map(|row| row.iter().map(Welford::variance).collect())
            .collect(),
    }
}

/// Figure-id → configuration mapping (Figs 9–16).
pub fn config_for_figure(fig: u32) -> NnFigConfig {
    match fig {
        9 | 10 => NnFigConfig::new(ModelSpec::DigitsLinear, Variant::PerPartial),
        11 | 12 => NnFigConfig::new(ModelSpec::DigitsLinear, Variant::InputOnce),
        13 | 14 => NnFigConfig::new(ModelSpec::DigitsLinear, Variant::Separate),
        15 | 16 => NnFigConfig::new(ModelSpec::FashionMlp, Variant::Separate),
        _ => panic!("fig must be 9..=16"),
    }
}

/// Regenerate one of Figs 9–16 (mean figures are odd ids 9/11/13/15,
/// variance figures are 10/12/14/16 — both series are computed either way).
pub fn run(fig: u32, cfg: &NnFigConfig, out_dir: &str) -> NnFigResult {
    let metric = if fig % 2 == 1 { "mean accuracy" } else { "accuracy variance" };
    println!(
        "== Fig {fig}: {} on {:?} / {} placement ({} trials, test_n known at print) ==\n",
        metric, cfg.spec, cfg.variant.name(), cfg.trials
    );
    let result = compute(cfg);
    println!("  float baseline accuracy: {:.4}\n", result.float_acc);
    print!("  {:>4}", "k");
    for mode in SchemeId::PAPER {
        print!("  {:>16}", mode.wire_name());
    }
    println!();
    for (ki, &k) in result.ks.iter().enumerate() {
        print!("  {k:>4}");
        for (mi, _) in SchemeId::PAPER.iter().enumerate() {
            let v = if fig % 2 == 1 {
                result.mean[mi][ki]
            } else {
                result.var[mi][ki]
            };
            print!("  {v:>16.6}");
        }
        println!();
    }
    let json = Json::obj(vec![
        (
            "ks",
            Json::nums(&result.ks.iter().map(|&k| k as f64).collect::<Vec<_>>()),
        ),
        ("float_acc", Json::Num(result.float_acc)),
        ("variant", Json::Str(cfg.variant.name().into())),
        ("trials", Json::Num(cfg.trials as f64)),
        (
            "deterministic_mean",
            Json::nums(result.mean_series(SchemeId::Deterministic)),
        ),
        (
            "dither_mean",
            Json::nums(result.mean_series(SchemeId::Dither)),
        ),
        (
            "stochastic_mean",
            Json::nums(result.mean_series(SchemeId::Stochastic)),
        ),
        (
            "dither_var",
            Json::nums(result.var_series(SchemeId::Dither)),
        ),
        (
            "stochastic_var",
            Json::nums(result.var_series(SchemeId::Stochastic)),
        ),
    ]);
    write_result(out_dir, &format!("fig{fig}"), json);
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(spec: ModelSpec, variant: Variant) -> NnFigConfig {
        NnFigConfig {
            spec,
            variant,
            ks: vec![1, 8],
            trials: 4,
            train_n: 400,
            test_n: 120,
            seed: 0xAB,
        }
    }

    #[test]
    fn digits_shape_unbiased_beats_deterministic_at_small_k() {
        // Per-partial placement: repeated roundings per element average out
        // even at k=1 (separate's single binary rounding per pixel is too
        // noisy for a reliable margin at this tiny test scale).
        let cfg = tiny(ModelSpec::DigitsLinear, Variant::PerPartial);
        let r = compute(&cfg);
        // k=8: everyone near the float baseline.
        let k8 = 1;
        for mode in SchemeId::PAPER {
            assert!(
                r.mean_series(mode)[k8] > r.float_acc - 0.08,
                "{mode:?} k=8 {}",
                r.mean_series(mode)[k8]
            );
        }
        // k=1: pixels in [0,1] inside the [-1,1] quantizer — deterministic
        // rounding maps every pixel to +1 (total information loss, §VII);
        // the unbiased schemes keep the class signal.
        let k1 = 0;
        let det = r.mean_series(SchemeId::Deterministic)[k1];
        let dit = r.mean_series(SchemeId::Dither)[k1];
        let sto = r.mean_series(SchemeId::Stochastic)[k1];
        assert!(dit > det + 0.1, "dither {dit} vs det {det} at k=1");
        assert!(sto > det + 0.1, "stochastic {sto} vs det {det} at k=1");
    }

    #[test]
    fn config_mapping_matches_paper() {
        assert_eq!(config_for_figure(9).variant, Variant::PerPartial);
        assert_eq!(config_for_figure(11).variant, Variant::InputOnce);
        assert_eq!(config_for_figure(13).variant, Variant::Separate);
        assert_eq!(config_for_figure(15).spec, ModelSpec::FashionMlp);
        assert_eq!(config_for_figure(16).variant, Variant::Separate);
    }

    #[test]
    #[should_panic(expected = "fig must be")]
    fn bad_figure_panics() {
        let _ = config_for_figure(8);
    }
}
