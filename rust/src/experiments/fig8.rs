//! Fig 8: Frobenius error `e_f = ‖C − Ĉ‖_F` of k-bit matrix multiplication
//! under traditional / stochastic / dither rounding, for matrices with
//! entries in `[0, 0.5)` (the narrow-range regime where unbiased rounding
//! wins) and the per-partial-product placement of Fig 7.
//!
//! Paper setting: 100 pairs of 100×100 matrices, N = 100, k sweep; we
//! default to a scaled-down pair count (CLI-overridable to paper scale).

use crate::experiments::write_result;
use crate::linalg::{frobenius_error, quant_matmul, Matrix, QuantMatmulConfig, Variant};
use crate::rounding::SchemeId;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_map;

/// Fig 8 configuration.
#[derive(Clone, Debug)]
pub struct Fig8Config {
    /// Number of (A, B) matrix pairs.
    pub pairs: usize,
    /// Square matrix dimension (paper: 100).
    pub dim: usize,
    /// Bit widths to sweep.
    pub ks: Vec<u32>,
    /// Entry range upper bound (paper: 0.5).
    pub hi: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for Fig8Config {
    fn default() -> Self {
        Self {
            pairs: 20,
            dim: 100,
            ks: (1..=8).collect(),
            hi: 0.5,
            seed: 0xF16_8,
        }
    }
}

/// Mean e_f per (mode, k).
pub struct Fig8Result {
    /// Bit widths.
    pub ks: Vec<u32>,
    /// `errors[mode_index][k_index]` in `SchemeId::PAPER` order.
    pub errors: Vec<Vec<f64>>,
}

impl Fig8Result {
    /// Series for one mode.
    pub fn series(&self, mode: SchemeId) -> &[f64] {
        let idx = SchemeId::PAPER.iter().position(|&m| m == mode).unwrap();
        &self.errors[idx]
    }

    /// Smallest k at which traditional rounding beats dither (the paper's
    /// threshold k̃), if any within the sweep.
    pub fn crossover_k(&self) -> Option<u32> {
        let det = self.series(SchemeId::Deterministic);
        let dit = self.series(SchemeId::Dither);
        self.ks
            .iter()
            .zip(det.iter().zip(dit))
            .find(|(_, (d, t))| d < t)
            .map(|(&k, _)| k)
    }
}

/// Run the Fig 8 sweep.
pub fn compute(cfg: &Fig8Config) -> Fig8Result {
    let pair_indices: Vec<usize> = (0..cfg.pairs).collect();
    // Per-pair, per-mode, per-k errors (parallel over pairs).
    let per_pair = parallel_map(&pair_indices, |_, &p| {
        let mut rng = Xoshiro256pp::new(cfg.seed ^ (p as u64) << 20);
        let a = Matrix::random_uniform(cfg.dim, cfg.dim, 0.0, cfg.hi, &mut rng);
        let b = Matrix::random_uniform(cfg.dim, cfg.dim, 0.0, cfg.hi, &mut rng);
        let c = a.matmul(&b);
        let mut errs = vec![vec![0.0; cfg.ks.len()]; SchemeId::PAPER.len()];
        for (mi, &mode) in SchemeId::PAPER.iter().enumerate() {
            for (ki, &k) in cfg.ks.iter().enumerate() {
                let mm = QuantMatmulConfig::unit(
                    k,
                    mode,
                    Variant::PerPartial,
                    cfg.seed ^ ((p as u64) << 8) ^ ((k as u64) << 3) ^ mi as u64,
                );
                let c_hat = quant_matmul(&a, &b, &mm);
                errs[mi][ki] = frobenius_error(&c, &c_hat);
            }
        }
        errs
    });
    let mut errors = vec![vec![0.0; cfg.ks.len()]; SchemeId::PAPER.len()];
    for pp in &per_pair {
        for (mi, row) in pp.iter().enumerate() {
            for (ki, &e) in row.iter().enumerate() {
                errors[mi][ki] += e / cfg.pairs as f64;
            }
        }
    }
    Fig8Result {
        ks: cfg.ks.clone(),
        errors,
    }
}

/// Regenerate Fig 8: print the table and record JSON.
pub fn run(cfg: &Fig8Config, out_dir: &str) -> Fig8Result {
    println!(
        "== Fig 8: matmul e_f vs k ({} pairs of {}x{} matrices, entries [0,{}), per-partial) ==\n",
        cfg.pairs, cfg.dim, cfg.dim, cfg.hi
    );
    let result = compute(cfg);
    print!("  {:>4}", "k");
    for mode in SchemeId::PAPER {
        print!("  {:>14}", mode.wire_name());
    }
    println!();
    for (ki, &k) in result.ks.iter().enumerate() {
        print!("  {k:>4}");
        for (mi, _) in SchemeId::PAPER.iter().enumerate() {
            print!("  {:>14.4}", result.errors[mi][ki]);
        }
        println!();
    }
    match result.crossover_k() {
        Some(k) => println!("\n  threshold k̃ (traditional beats dither) = {k}"),
        None => println!("\n  no crossover within the sweep (traditional never wins)"),
    }
    let json = Json::obj(vec![
        (
            "ks",
            Json::nums(&result.ks.iter().map(|&k| k as f64).collect::<Vec<_>>()),
        ),
        (
            "deterministic",
            Json::nums(result.series(SchemeId::Deterministic)),
        ),
        ("dither", Json::nums(result.series(SchemeId::Dither))),
        (
            "stochastic",
            Json::nums(result.series(SchemeId::Stochastic)),
        ),
    ]);
    write_result(out_dir, "fig8", json);
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Fig8Config {
        Fig8Config {
            pairs: 3,
            dim: 32,
            ks: vec![1, 2, 4, 8],
            hi: 0.5,
            seed: 3,
        }
    }

    #[test]
    fn shape_of_fig8_reproduced() {
        let r = compute(&tiny());
        let det = r.series(SchemeId::Deterministic);
        let dit = r.series(SchemeId::Dither);
        let sto = r.series(SchemeId::Stochastic);
        // Small k: unbiased schemes beat traditional; dither <= stochastic.
        assert!(dit[0] < det[0], "k=1: dither {} < det {}", dit[0], det[0]);
        assert!(sto[0] < det[0], "k=1: stochastic beats det");
        assert!(dit[0] <= sto[0] * 1.05, "k=1: dither ≲ stochastic");
        assert!(dit[1] < det[1], "k=2");
        // Errors decrease with k for every scheme.
        for s in [det, dit, sto] {
            assert!(s[3] < s[0] / 4.0, "error falls with k: {s:?}");
        }
    }

    #[test]
    fn k1_traditional_error_is_product_norm() {
        // Footnote 3: at k=1 traditional rounding zeroes A and B.
        let cfg = tiny();
        let mut rng = Xoshiro256pp::new(cfg.seed ^ 0);
        let a = Matrix::random_uniform(cfg.dim, cfg.dim, 0.0, cfg.hi, &mut rng);
        let b = Matrix::random_uniform(cfg.dim, cfg.dim, 0.0, cfg.hi, &mut rng);
        let c = a.matmul(&b);
        let r = compute(&Fig8Config { pairs: 1, ..cfg });
        let det_k1 = r.series(SchemeId::Deterministic)[0];
        assert!((det_k1 - c.frobenius_norm()).abs() / c.frobenius_norm() < 1e-9);
    }

    #[test]
    fn crossover_exists_for_narrow_range() {
        // With entries in [0, 0.5) the paper observes traditional rounding
        // eventually winning at high k.
        let r = compute(&Fig8Config {
            ks: (1..=10).collect(),
            ..tiny()
        });
        assert!(r.crossover_k().is_some(), "expected a crossover k̃");
    }
}
