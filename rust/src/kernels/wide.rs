//! The wide kernel: hand-unrolled 4×u64 word lanes for the bitstream ops
//! (with a fused AND+popcount that never materializes the intermediate
//! sequence) and 8-wide independent accumulator chains for the matmul
//! microkernel — straight-line Rust shaped so LLVM autovectorizes it. On
//! x86_64 the popcount paths call `popcnt`-enabled `target_feature`
//! functions when runtime detection reports the feature, so `count_ones`
//! lowers to the hardware instruction instead of the SWAR fallback.
//!
//! Bit-identity with the scalar kernel is structural, not incidental:
//! word ops are exact bitwise functions, and every f64 output cell keeps a
//! single accumulator chain walked in plain index order — the unrolling
//! only widens how many *independent* cells are in flight at once.

use super::{KernelId, Kernels};
use crate::util::rng::counter_hash;

/// The lane-parallel implementation of the kernel primitives.
#[derive(Clone, Copy, Debug, Default)]
pub struct WideKernels;

#[inline(always)]
fn popcount_unrolled(words: &[u64]) -> u64 {
    let mut acc = [0u64; 4];
    let mut chunks = words.chunks_exact(4);
    for c in &mut chunks {
        acc[0] += u64::from(c[0].count_ones());
        acc[1] += u64::from(c[1].count_ones());
        acc[2] += u64::from(c[2].count_ones());
        acc[3] += u64::from(c[3].count_ones());
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    for &w in chunks.remainder() {
        total += u64::from(w.count_ones());
    }
    total
}

#[inline(always)]
fn and_popcount_unrolled(a: &[u64], b: &[u64]) -> u64 {
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut acc = [0u64; 4];
    let mut i = 0;
    while i + 4 <= n {
        acc[0] += u64::from((a[i] & b[i]).count_ones());
        acc[1] += u64::from((a[i + 1] & b[i + 1]).count_ones());
        acc[2] += u64::from((a[i + 2] & b[i + 2]).count_ones());
        acc[3] += u64::from((a[i + 3] & b[i + 3]).count_ones());
        i += 4;
    }
    let mut total = acc[0] + acc[1] + acc[2] + acc[3];
    while i < n {
        total += u64::from((a[i] & b[i]).count_ones());
        i += 1;
    }
    total
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    // `#[inline(always)]` on the portable bodies lets LLVM inline them
    // here under the `popcnt` feature, so `count_ones` becomes one
    // instruction. Callers gate on `is_x86_feature_detected!`.
    #[target_feature(enable = "popcnt")]
    pub unsafe fn popcount(words: &[u64]) -> u64 {
        super::popcount_unrolled(words)
    }

    #[target_feature(enable = "popcnt")]
    pub unsafe fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
        super::and_popcount_unrolled(a, b)
    }
}

impl Kernels for WideKernels {
    fn id(&self) -> KernelId {
        KernelId::Wide
    }

    fn lanes(&self) -> usize {
        8
    }

    fn and_words(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        let n = out.len().min(a.len()).min(b.len());
        let mut i = 0;
        while i + 4 <= n {
            out[i] = a[i] & b[i];
            out[i + 1] = a[i + 1] & b[i + 1];
            out[i + 2] = a[i + 2] & b[i + 2];
            out[i + 3] = a[i + 3] & b[i + 3];
            i += 4;
        }
        while i < n {
            out[i] = a[i] & b[i];
            i += 1;
        }
    }

    fn mux_words(&self, w: &[u64], x: &[u64], y: &[u64], out: &mut [u64]) {
        let n = out.len().min(w.len()).min(x.len()).min(y.len());
        let mut i = 0;
        while i + 4 <= n {
            out[i] = (w[i] & x[i]) | (!w[i] & y[i]);
            out[i + 1] = (w[i + 1] & x[i + 1]) | (!w[i + 1] & y[i + 1]);
            out[i + 2] = (w[i + 2] & x[i + 2]) | (!w[i + 2] & y[i + 2]);
            out[i + 3] = (w[i + 3] & x[i + 3]) | (!w[i + 3] & y[i + 3]);
            i += 4;
        }
        while i < n {
            out[i] = (w[i] & x[i]) | (!w[i] & y[i]);
            i += 1;
        }
    }

    fn popcount_words(&self, words: &[u64]) -> u64 {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("popcnt") {
            // SAFETY: the `popcnt` feature was detected at runtime on the
            // line above; the function only requires that feature.
            return unsafe { x86::popcount(words) };
        }
        popcount_unrolled(words)
    }

    fn and_popcount(&self, a: &[u64], b: &[u64]) -> u64 {
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("popcnt") {
            // SAFETY: as above — gated on runtime detection of `popcnt`.
            return unsafe { x86::and_popcount(a, b) };
        }
        and_popcount_unrolled(a, b)
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        // One output cell = one chain: identical to scalar by contract.
        let mut acc = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    fn matmul_row(&self, arow: &[f64], bt: &[f64], out_row: &mut [f64]) {
        let q = arow.len();
        let r = out_row.len();
        let mut k = 0;
        while k + 8 <= r {
            let b0 = &bt[k * q..(k + 1) * q];
            let b1 = &bt[(k + 1) * q..(k + 2) * q];
            let b2 = &bt[(k + 2) * q..(k + 3) * q];
            let b3 = &bt[(k + 3) * q..(k + 4) * q];
            let b4 = &bt[(k + 4) * q..(k + 5) * q];
            let b5 = &bt[(k + 5) * q..(k + 6) * q];
            let b6 = &bt[(k + 6) * q..(k + 7) * q];
            let b7 = &bt[(k + 7) * q..(k + 8) * q];
            let mut acc = [0.0f64; 8];
            for j in 0..q {
                let a = arow[j];
                acc[0] += a * b0[j];
                acc[1] += a * b1[j];
                acc[2] += a * b2[j];
                acc[3] += a * b3[j];
                acc[4] += a * b4[j];
                acc[5] += a * b5[j];
                acc[6] += a * b6[j];
                acc[7] += a * b7[j];
            }
            out_row[k..k + 8].copy_from_slice(&acc);
            k += 8;
        }
        while k < r {
            let brow = &bt[k * q..(k + 1) * q];
            let mut acc = 0.0;
            for j in 0..q {
                acc += arow[j] * brow[j];
            }
            out_row[k] = acc;
            k += 1;
        }
    }

    fn round_row(&self, round: &mut dyn FnMut(f64, u64) -> f64, row: &mut [f64], seed: u64) {
        let n = row.len();
        let mut j = 0;
        while j + 4 <= n {
            // Batch the coordinate hashes (the per-element fixed cost) so
            // the four chains overlap; rounding order is unchanged.
            let u0 = counter_hash(seed, j as u64);
            let u1 = counter_hash(seed, j as u64 + 1);
            let u2 = counter_hash(seed, j as u64 + 2);
            let u3 = counter_hash(seed, j as u64 + 3);
            row[j] = round(row[j], u0);
            row[j + 1] = round(row[j + 1], u1);
            row[j + 2] = round(row[j + 2], u2);
            row[j + 3] = round(row[j + 3], u3);
            j += 4;
        }
        while j < n {
            row[j] = round(row[j], counter_hash(seed, j as u64));
            j += 1;
        }
    }
}
