//! The scalar reference kernel: the crate's original one-word /
//! one-element inner loops, extracted verbatim from
//! `bitstream/sequence.rs` and `linalg/matrix.rs`. Every other variant
//! must match this one bit for bit (`tests/kernel_equivalence.rs`).

use super::{KernelId, Kernels};
use crate::util::rng::counter_hash;

/// The one-word / one-element baseline implementation.
#[derive(Clone, Copy, Debug, Default)]
pub struct ScalarKernels;

impl Kernels for ScalarKernels {
    fn id(&self) -> KernelId {
        KernelId::Scalar
    }

    fn lanes(&self) -> usize {
        4
    }

    fn and_words(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = x & y;
        }
    }

    fn mux_words(&self, w: &[u64], x: &[u64], y: &[u64], out: &mut [u64]) {
        for (((o, &wv), &xv), &yv) in out.iter_mut().zip(w).zip(x).zip(y) {
            *o = (wv & xv) | (!wv & yv);
        }
    }

    fn popcount_words(&self, words: &[u64]) -> u64 {
        words.iter().map(|w| u64::from(w.count_ones())).sum()
    }

    fn and_popcount(&self, a: &[u64], b: &[u64]) -> u64 {
        // Faithful to the pre-kernel multiply path: materialize the AND,
        // then count it in a second pass (the wide variant fuses these).
        let anded: Vec<u64> = a.iter().zip(b).map(|(&x, &y)| x & y).collect();
        self.popcount_words(&anded)
    }

    fn dot(&self, a: &[f64], b: &[f64]) -> f64 {
        let mut acc = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            acc += x * y;
        }
        acc
    }

    fn matmul_row(&self, arow: &[f64], bt: &[f64], out_row: &mut [f64]) {
        let q = arow.len();
        let r = out_row.len();
        let mut k = 0;
        while k + 4 <= r {
            let b0 = &bt[k * q..(k + 1) * q];
            let b1 = &bt[(k + 1) * q..(k + 2) * q];
            let b2 = &bt[(k + 2) * q..(k + 3) * q];
            let b3 = &bt[(k + 3) * q..(k + 4) * q];
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
            for j in 0..q {
                let a = arow[j];
                a0 += a * b0[j];
                a1 += a * b1[j];
                a2 += a * b2[j];
                a3 += a * b3[j];
            }
            out_row[k..k + 4].copy_from_slice(&[a0, a1, a2, a3]);
            k += 4;
        }
        while k < r {
            let brow = &bt[k * q..(k + 1) * q];
            let mut acc = 0.0;
            for j in 0..q {
                acc += arow[j] * brow[j];
            }
            out_row[k] = acc;
            k += 1;
        }
    }

    fn round_row(&self, round: &mut dyn FnMut(f64, u64) -> f64, row: &mut [f64], seed: u64) {
        for (j, v) in row.iter_mut().enumerate() {
            *v = round(*v, counter_hash(seed, j as u64));
        }
    }
}
