//! Word/lane-parallel kernel layer with runtime dispatch.
//!
//! Every hot inner loop in the crate — the §III bitstream AND-multiply, the
//! §IV MUX scaled-add, popcount reductions over `u64` word slices, the
//! blocked f64 matmul microkernel, and per-row scheme rounding — is routed
//! through the [`Kernels`] trait so scalar and lane-parallel implementations
//! are interchangeable and A/B-able in the benches. Two variants are
//! registered:
//!
//! * [`KernelId::Scalar`] — the original one-word / one-element loops,
//!   extracted verbatim from `bitstream/sequence.rs` and
//!   `linalg/matrix.rs`; the reference every other variant must match bit
//!   for bit.
//! * [`KernelId::Wide`] — hand-unrolled 4×u64 word lanes for the bitstream
//!   ops (including a fused AND+popcount pass that skips the intermediate
//!   allocation of the scalar multiply path) and 8-wide independent
//!   accumulator chains for the matmul microkernel, written as
//!   straight-line Rust that LLVM autovectorizes; on x86_64 the popcount
//!   paths switch to `popcnt`-enabled `target_feature` functions when the
//!   CPU reports the feature at runtime.
//!
//! Selection happens once at startup: `--kernel auto|scalar|wide` on the
//! CLI, overridden by the `DITHER_KERNEL` environment variable, with
//! `auto` picking the best detected variant ([`auto_detect`]). The choice
//! is process-global ([`select`] / [`active`]) and is reported in the
//! `hello` handshake and `stats` JSON as `"kernel":"<name>"`.
//!
//! The hard contract, locked by `tests/kernel_equivalence.rs` and the
//! plan-execute / pipelined bit-identity suites: every variant preserves
//! per-cell accumulation order (each output cell keeps one accumulator
//! chain walked in index order — lane width only changes how many
//! *independent* chains run concurrently), so deterministic serving output
//! is bit-identical no matter which kernel is active, and the stochastic
//! schemes — whose random bits are pure counter-hash functions of their
//! coordinates — reproduce the exact same streams.

mod scalar;
mod wide;

pub use scalar::ScalarKernels;
pub use wide::WideKernels;

use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};

/// Identifier for a registered kernel implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelId {
    /// One-word / one-element scalar loops (the pre-kernel-layer code).
    Scalar,
    /// Unrolled 4×u64 word lanes + 8-wide matmul accumulator chains.
    Wide,
}

impl KernelId {
    /// Every registered kernel, the scalar reference variant first.
    pub const ALL: [KernelId; 2] = [KernelId::Scalar, KernelId::Wide];

    /// Stable lowercase name: used by `--kernel`, `DITHER_KERNEL`, the
    /// `hello`/`stats` JSON field and `kernel/<name>/...` bench keys.
    pub fn name(self) -> &'static str {
        match self {
            KernelId::Scalar => "scalar",
            KernelId::Wide => "wide",
        }
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Error for an unrecognized kernel spelling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseKernelError(String);

impl std::fmt::Display for ParseKernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "unknown kernel {:?} (expected auto, scalar or wide)",
            self.0
        )
    }
}

impl std::error::Error for ParseKernelError {}

impl FromStr for KernelId {
    type Err = ParseKernelError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(KernelId::Scalar),
            "wide" => Ok(KernelId::Wide),
            other => Err(ParseKernelError(other.to_string())),
        }
    }
}

/// The hot-primitive vtable. All word-slice operands are the raw `u64`
/// backing words of a `BitSeq` (tail bits beyond the logical length are
/// zero by that type's invariant); all f64 methods promise *strict
/// index-order accumulation per output cell* so results are bit-identical
/// across implementations.
pub trait Kernels: Send + Sync {
    /// Which registered variant this is.
    fn id(&self) -> KernelId;

    /// Output-column lane width of [`Kernels::matmul_row`] — how many
    /// independent per-cell accumulator chains the quantized-matmul callers
    /// should run concurrently (4 scalar, 8 wide).
    fn lanes(&self) -> usize;

    /// `out[i] = a[i] & b[i]` over word slices (§III AND-multiply).
    fn and_words(&self, a: &[u64], b: &[u64], out: &mut [u64]);

    /// `out[i] = (w[i] & x[i]) | (!w[i] & y[i])` — the §IV MUX scaled-add.
    fn mux_words(&self, w: &[u64], x: &[u64], y: &[u64], out: &mut [u64]);

    /// Total set bits over `words`.
    fn popcount_words(&self, words: &[u64]) -> u64;

    /// `popcount(a & b)` — the AND-multiply value estimate. The wide
    /// variant fuses the two passes without materializing the AND.
    fn and_popcount(&self, a: &[u64], b: &[u64]) -> u64;

    /// Dot product in strict index order. One output cell means one
    /// accumulator chain — bit-identity forbids a multi-accumulator
    /// reduction here; the lane-parallel win lives in
    /// [`Kernels::matmul_row`]'s independent output columns.
    fn dot(&self, a: &[f64], b: &[f64]) -> f64;

    /// One output row of `A×B`: `out_row[k] = Σ_j arow[j] · bt[k*q + j]`
    /// where `bt` is row-major transposed-B (`r × q`, `q = arow.len()`,
    /// `r = out_row.len()`). Every `out_row[k]` is accumulated in plain
    /// `j` order regardless of lane width.
    fn matmul_row(&self, arow: &[f64], bt: &[f64], out_row: &mut [f64]);

    /// Vectorized per-row rounding:
    /// `row[j] = round(row[j], counter_hash(seed, j))` for every `j`.
    /// The kernel batches the counter-hash computation; `round` is the
    /// scheme's scalar rounding function.
    fn round_row(&self, round: &mut dyn FnMut(f64, u64) -> f64, row: &mut [f64], seed: u64);
}

/// Upper bound on [`Kernels::lanes`] across all registered variants —
/// callers that block work by lane width can size stack buffers with this.
pub const MAX_LANES: usize = 8;

static SCALAR: ScalarKernels = ScalarKernels;
static WIDE: WideKernels = WideKernels;

/// Look up a kernel implementation by id, independent of the global pick
/// (used by the equivalence tests and the A/B benches).
pub fn get(id: KernelId) -> &'static dyn Kernels {
    match id {
        KernelId::Scalar => &SCALAR,
        KernelId::Wide => &WIDE,
    }
}

/// The best kernel for this host. The wide variant's unrolled loops are
/// plain portable Rust (its x86_64 `popcnt` fast path is gated per call at
/// runtime), so it is the right default everywhere.
pub fn auto_detect() -> KernelId {
    KernelId::Wide
}

/// Resolve a CLI/env spelling; `auto` maps to [`auto_detect`].
pub fn resolve(spec: &str) -> Result<KernelId, ParseKernelError> {
    if spec.trim().eq_ignore_ascii_case("auto") {
        Ok(auto_detect())
    } else {
        spec.parse()
    }
}

const KERNEL_UNSET: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(KERNEL_UNSET);

fn encode(id: KernelId) -> u8 {
    match id {
        KernelId::Scalar => 0,
        KernelId::Wide => 1,
    }
}

fn decode(v: u8) -> Option<KernelId> {
    match v {
        0 => Some(KernelId::Scalar),
        1 => Some(KernelId::Wide),
        _ => None,
    }
}

/// Install `id` as the process-global kernel. Normally called once at
/// startup (`main` resolves `DITHER_KERNEL` / `--kernel`); tests may
/// re-select freely because every variant is output-equivalent.
pub fn select(id: KernelId) {
    ACTIVE.store(encode(id), Ordering::Relaxed);
}

/// The process-global kernel id. First use resolves the `DITHER_KERNEL`
/// environment variable (panicking on an unknown spelling — fail fast at
/// startup) and falls back to [`auto_detect`].
pub fn active_id() -> KernelId {
    if let Some(id) = decode(ACTIVE.load(Ordering::Relaxed)) {
        return id;
    }
    let id = match std::env::var("DITHER_KERNEL") {
        Ok(spec) => resolve(&spec).unwrap_or_else(|e| panic!("DITHER_KERNEL: {e}")),
        Err(_) => auto_detect(),
    };
    select(id);
    id
}

/// The process-global kernel implementation (see [`active_id`]).
pub fn active() -> &'static dyn Kernels {
    get(active_id())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_parsing_round_trip() {
        for id in KernelId::ALL {
            assert_eq!(id.name().parse::<KernelId>().unwrap(), id);
            assert_eq!(get(id).id(), id);
        }
        assert_eq!("  WIDE ".parse::<KernelId>().unwrap(), KernelId::Wide);
        assert!("fast".parse::<KernelId>().is_err());
    }

    #[test]
    fn resolve_handles_auto() {
        assert_eq!(resolve("auto").unwrap(), auto_detect());
        assert_eq!(resolve("scalar").unwrap(), KernelId::Scalar);
        let err = resolve("simd").unwrap_err().to_string();
        assert!(err.contains("simd"), "{err}");
    }

    #[test]
    fn select_changes_the_active_kernel() {
        // The global is shared across concurrently-running tests, which is
        // safe because every kernel is output-equivalent; this test only
        // asserts that its own stores are visible to itself.
        select(KernelId::Scalar);
        assert_eq!(active_id(), KernelId::Scalar);
        assert_eq!(active().id(), KernelId::Scalar);
        select(auto_detect());
        assert_eq!(active_id(), auto_detect());
    }

    #[test]
    fn lane_widths_are_positive_and_bounded() {
        for id in KernelId::ALL {
            let lanes = get(id).lanes();
            assert!((1..=MAX_LANES).contains(&lanes), "{id}: lanes {lanes}");
        }
    }
}
