//! Native runtime descriptor: where the serving stack executes and which
//! AOT artifacts (if any) are on disk.
//!
//! The serving hot path runs the pure-Rust quantized engines
//! ([`crate::nn::quantized`]) — the PJRT/xla bridge that previously lived
//! here needed the external `xla` crate, which the offline toolchain does
//! not provide, so model execution moved in-tree and this module keeps the
//! environment/artifact introspection surface (`dither info`, manifest
//! validation for the Python AOT outputs).

use crate::runtime::manifest::Manifest;
use crate::util::error::Result;
use std::path::{Path, PathBuf};

/// The execution environment: native CPU plus an optional artifacts
/// directory produced by `python/compile/aot.py`.
pub struct Runtime {
    dir: PathBuf,
    manifest: Option<Manifest>,
}

impl Runtime {
    /// Describe the native runtime rooted at `artifacts_dir`. The manifest
    /// is loaded when present; a missing manifest is not an error (the
    /// native engines do not need it), but a *malformed* one is.
    pub fn native(artifacts_dir: &str) -> Result<Runtime> {
        let dir = PathBuf::from(artifacts_dir);
        let manifest = if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir)?)
        } else {
            None
        };
        Ok(Runtime { dir, manifest })
    }

    /// Platform name reported in logs and `dither info`.
    pub fn platform(&self) -> String {
        format!(
            "native-cpu ({} threads)",
            crate::util::threadpool::num_threads()
        )
    }

    /// The artifacts directory this runtime was rooted at.
    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }

    /// The AOT artifact manifest, when `manifest.json` exists.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_manifest_is_ok() {
        let rt = Runtime::native("/nonexistent/artifacts").unwrap();
        assert!(rt.manifest().is_none());
        assert!(rt.platform().starts_with("native-cpu"));
        assert_eq!(rt.artifacts_dir(), Path::new("/nonexistent/artifacts"));
    }

    #[test]
    fn malformed_manifest_is_an_error() {
        let dir = std::env::temp_dir().join("dither_rt_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        let res = Runtime::native(dir.to_str().unwrap());
        assert!(res.is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
