//! PJRT client wrapper: compile HLO-text artifacts once, execute many times.

use crate::linalg::Matrix;
use crate::runtime::manifest::{ArtifactMeta, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::sync::Mutex;

/// A PJRT CPU client plus a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<LoadedModel>>>,
}

/// A compiled model artifact ready to execute.
pub struct LoadedModel {
    exe: xla::PjRtLoadedExecutable,
    /// The artifact's manifest entry.
    pub meta: ArtifactMeta,
}

impl Runtime {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn cpu(artifacts_dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(artifacts_dir)?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    /// The manifest describing available artifacts.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact by logical name, memoized.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<LoadedModel>> {
        if let Some(m) = self.cache.lock().unwrap().get(name) {
            return Ok(m.clone());
        }
        let meta = self.manifest.find(name)?.clone();
        let path = self.manifest.dir.join(&meta.file);
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {}", path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let model = std::sync::Arc::new(LoadedModel { exe, meta });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), model.clone());
        Ok(model)
    }

    /// Pick the smallest batch artifact in `family` that fits `batch` rows
    /// (or the largest available if none fit).
    pub fn pick_batch_artifact(&self, family: &str, batch: usize) -> Result<String> {
        let fam = self.manifest.family(family);
        if fam.is_empty() {
            bail!("no artifacts for model family {family:?}");
        }
        let best = fam
            .iter()
            .find(|a| a.batch >= batch)
            .or_else(|| fam.last())
            .unwrap();
        Ok(best.name.clone())
    }
}

impl LoadedModel {
    /// Execute with the given input literals; returns the first output of
    /// the result tuple (our models return a 1-tuple).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.meta.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(out.to_tuple1()?)
    }

    /// Execute and read the output back as `(rows, cols, data)` of f32.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<(usize, usize, Vec<f32>)> {
        let lit = self.run(inputs)?;
        let shape = lit.array_shape()?;
        let dims = shape.dims();
        let data = lit.to_vec::<f32>()?;
        let (rows, cols) = match dims.len() {
            2 => (dims[0] as usize, dims[1] as usize),
            1 => (1, dims[0] as usize),
            _ => bail!("unexpected output rank {} for {}", dims.len(), self.meta.name),
        };
        Ok((rows, cols, data))
    }
}

/// Build an f32 literal of shape `rows × cols` from an f64 matrix.
pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    let data: Vec<f32> = m.data().iter().map(|&v| v as f32).collect();
    Ok(xla::Literal::vec1(&data).reshape(&[m.rows as i64, m.cols as i64])?)
}

/// Build an f32 literal from a batch slice of rows (padding with zeros up
/// to `batch` rows, which the caller must discard from the output).
pub fn padded_batch_literal(rows: &[&[f64]], cols: usize, batch: usize) -> Result<xla::Literal> {
    let mut data = vec![0.0f32; batch * cols];
    for (i, row) in rows.iter().enumerate() {
        for (j, &v) in row.iter().enumerate() {
            data[i * cols + j] = v as f32;
        }
    }
    Ok(xla::Literal::vec1(&data).reshape(&[batch as i64, cols as i64])?)
}

/// Build an f32 vector literal.
pub fn vec_literal(v: &[f64]) -> xla::Literal {
    let data: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&data)
}

/// i32 scalar literal.
pub fn i32_scalar(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// u32 scalar literal.
pub fn u32_scalar(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// f32 scalar literal.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_literal_roundtrip() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        let lit = matrix_literal(&m).unwrap();
        let back = lit.to_vec::<f32>().unwrap();
        assert_eq!(back.len(), 12);
        assert_eq!(back[5], 5.0);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3, 4]);
    }

    #[test]
    fn padded_batch_pads_with_zeros() {
        let r0 = [1.0, 2.0];
        let r1 = [3.0, 4.0];
        let rows: Vec<&[f64]> = vec![&r0, &r1];
        let lit = padded_batch_literal(&rows, 2, 4).unwrap();
        let v = lit.to_vec::<f32>().unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(i32_scalar(7).to_vec::<i32>().unwrap(), vec![7]);
        assert_eq!(u32_scalar(9).to_vec::<u32>().unwrap(), vec![9]);
        assert_eq!(f32_scalar(1.5).to_vec::<f32>().unwrap(), vec![1.5]);
    }
}
