//! Artifact manifest: what `python/compile/aot.py` produced and how to
//! feed it. Parsed with the in-tree JSON module and validated at load time
//! so a stale `artifacts/` directory fails fast with a clear message.

use crate::bail;
use crate::util::error::{Context, Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One AOT artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Logical name, e.g. `digits_linear_b32`.
    pub name: String,
    /// HLO text file name within the artifacts directory.
    pub file: String,
    /// Batch size the executable was lowered for.
    pub batch: usize,
    /// Human-readable input signature (order matters).
    pub inputs: Vec<String>,
    /// Human-readable output signature.
    pub outputs: Vec<String>,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Dither period `N` baked into the kernels.
    pub dither_n: usize,
    /// All artifacts.
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let json = Json::parse(text).map_err(|e| Error::msg(format!("manifest.json: {e}")))?;
        let format = json
            .get("format")
            .and_then(Json::as_str)
            .context("manifest missing 'format'")?;
        if format != "hlo-text" {
            bail!("unsupported artifact format {format:?} (expected hlo-text)");
        }
        let dither_n = json
            .get("dither_n")
            .and_then(Json::as_usize)
            .context("manifest missing 'dither_n'")?;
        let raw = json
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::with_capacity(raw.len());
        for a in raw {
            let get_str = |k: &str| -> Result<String> {
                Ok(a.get(k)
                    .and_then(Json::as_str)
                    .with_context(|| format!("artifact missing '{k}'"))?
                    .to_string())
            };
            let strings = |k: &str| -> Vec<String> {
                a.get(k)
                    .and_then(Json::as_arr)
                    .map(|v| {
                        v.iter()
                            .filter_map(Json::as_str)
                            .map(str::to_string)
                            .collect()
                    })
                    .unwrap_or_default()
            };
            artifacts.push(ArtifactMeta {
                name: get_str("name")?,
                file: get_str("file")?,
                batch: a
                    .get("batch")
                    .and_then(Json::as_usize)
                    .context("artifact missing 'batch'")?,
                inputs: strings("inputs"),
                outputs: strings("outputs"),
            });
        }
        Ok(Manifest {
            dir,
            dither_n,
            artifacts,
        })
    }

    /// Find an artifact by logical name.
    pub fn find(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| {
                Error::msg(format!(
                    "artifact {name:?} not in manifest (have: {})",
                    self.artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })
    }

    /// All artifacts for a model family, e.g. `digits_linear`, keyed by
    /// batch size.
    pub fn family(&self, prefix: &str) -> Vec<&ArtifactMeta> {
        let mut v: Vec<&ArtifactMeta> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.name
                    .strip_prefix(prefix)
                    .map(|rest| rest.starts_with("_b"))
                    .unwrap_or(false)
            })
            .collect();
        v.sort_by_key(|a| a.batch);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text",
        "dither_n": 64,
        "artifacts": [
            {"name": "digits_linear_b1", "file": "digits_linear_b1.hlo.txt",
             "batch": 1, "inputs": ["x(1,784)f32"], "outputs": ["logits(1,10)f32"]},
            {"name": "digits_linear_b32", "file": "digits_linear_b32.hlo.txt",
             "batch": 32, "inputs": ["x(32,784)f32"], "outputs": ["logits(32,10)f32"]},
            {"name": "fashion_mlp_b1", "file": "fashion_mlp_b1.hlo.txt",
             "batch": 1, "inputs": ["x(1,784)f32"], "outputs": ["logits(1,10)f32"]}
        ]
    }"#;

    #[test]
    fn parse_and_find() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.dither_n, 64);
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.find("digits_linear_b32").unwrap().batch, 32);
        assert!(m.find("nope").is_err());
    }

    #[test]
    fn family_sorted_by_batch() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let fam = m.family("digits_linear");
        assert_eq!(fam.len(), 2);
        assert_eq!(fam[0].batch, 1);
        assert_eq!(fam[1].batch, 32);
        // prefix must match the family boundary, not a substring.
        assert_eq!(m.family("digits").len(), 0);
        assert_eq!(m.family("fashion_mlp").len(), 1);
    }

    #[test]
    fn rejects_bad_format() {
        let bad = SAMPLE.replace("hlo-text", "protobuf");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
        assert!(Manifest::parse("{}", PathBuf::from("/tmp")).is_err());
        assert!(Manifest::parse("not json", PathBuf::from("/tmp")).is_err());
    }
}
