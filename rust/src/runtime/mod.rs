//! PJRT runtime bridge: load the AOT-compiled JAX/Pallas artifacts and run
//! them from the Rust hot path.
//!
//! Python runs exactly once (`make artifacts`); afterwards this module is
//! the only place the model executes: HLO text → `HloModuleProto` →
//! `PjRtClient::compile` → `execute`. One compiled executable per
//! (model, batch-size) artifact.

pub mod client;
pub mod manifest;

pub use client::{LoadedModel, Runtime};
pub use manifest::{ArtifactMeta, Manifest};
