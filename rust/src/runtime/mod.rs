//! Runtime environment: artifact manifest handling and the native
//! execution descriptor.
//!
//! The Python AOT pipeline (`make artifacts`) still emits HLO-text
//! artifacts plus `manifest.json` for the JAX/Pallas path; [`manifest`]
//! parses and validates those. [`native`] describes the in-process
//! execution environment the serving stack actually runs on — the
//! pure-Rust quantized engines — since the external `xla`/PJRT crate is
//! unavailable in the offline toolchain (see ROADMAP "Open items").

pub mod manifest;
pub mod native;

pub use manifest::{ArtifactMeta, Manifest};
pub use native::Runtime;
