//! Fixed-point matrix-multiplication engines with pluggable rounding —
//! §VII (Fig 7) and the §VIII variants.
//!
//! `C = A·B` is computed as if only a k-bit fixed-point multiplier existed:
//! each operand element is affinely rescaled into `[0, 2^k−1]`, rounded to
//! an integer level by the configured [`RoundingMode`], dequantized, and the
//! partial products accumulated exactly (the accumulator is not the paper's
//! concern; the rounding of the multiplier inputs is).
//!
//! Three rounding *placements* trade accuracy for rounding work:
//!
//! * [`Variant::PerPartial`] — both operands are rounded for every partial
//!   product (Fig 7): `2pqr` roundings. Dither indices: element `A_ij`'s
//!   use for output column `k` takes index `σ_A(k mod N_A)`, `B_jk`'s use
//!   for output row `i` takes `σ_B(i mod N_B)` — each element's uses sweep
//!   a full period, which is what drives the `Θ(1/N)` error of §VII.
//! * [`Variant::InputOnce`] — `A` rounded once per element, `B` per partial:
//!   `pq + pqr` roundings (§VIII, Figs 11–12).
//! * [`Variant::Separate`] — both matrices rounded once, then multiplied:
//!   `(p+r)·q` roundings (§VIII, Figs 13–16).

use crate::bitstream::dither::DitherParams;
use crate::linalg::matrix::Matrix;
use crate::rounding::{deterministic_bit, Quantizer, RoundingMode};
use crate::util::rng::{counter_hash, u64_to_unit_f64, Xoshiro256pp};
use crate::util::threadpool::parallel_chunks;

/// Rounding placement within the matmul (§VII–§VIII).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Round both operands per partial product — `2pqr` roundings (Fig 7).
    PerPartial,
    /// Round `A` once per element, `B` per partial — `pq(r+1)` roundings.
    InputOnce,
    /// Round both matrices once, multiply the rounded matrices — `(p+r)q`.
    Separate,
}

impl Variant {
    /// All variants in paper order.
    pub const ALL: [Variant; 3] = [Variant::PerPartial, Variant::InputOnce, Variant::Separate];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::PerPartial => "per-partial",
            Variant::InputOnce => "input-once",
            Variant::Separate => "separate",
        }
    }

    /// Parse from CLI spelling.
    pub fn from_str(s: &str) -> Option<Variant> {
        match s {
            "per-partial" | "perpartial" | "pp" => Some(Variant::PerPartial),
            "input-once" | "inputonce" | "io" => Some(Variant::InputOnce),
            "separate" | "sep" => Some(Variant::Separate),
            _ => None,
        }
    }

    /// Number of scalar rounding operations for a `p×q · q×r` product.
    pub fn rounding_ops(&self, p: usize, q: usize, r: usize) -> usize {
        match self {
            Variant::PerPartial => 2 * p * q * r,
            Variant::InputOnce => p * q * (r + 1),
            Variant::Separate => (p + r) * q,
        }
    }
}

/// Configuration for a quantized matrix multiplication.
#[derive(Clone, Debug)]
pub struct QuantMatmulConfig {
    /// Quantizer bit width `k`.
    pub bits: u32,
    /// Rounding scheme.
    pub mode: RoundingMode,
    /// Rounding placement.
    pub variant: Variant,
    /// Seed for all stochastic/dither randomness (vary per trial).
    pub seed: u64,
    /// Source range of `A`'s entries.
    pub range_a: (f64, f64),
    /// Source range of `B`'s entries.
    pub range_b: (f64, f64),
    /// Dither period for `A` (`None` → `r`, the per-element use count).
    pub n_a: Option<usize>,
    /// Dither period for `B` (`None` → `p`).
    pub n_b: Option<usize>,
}

impl QuantMatmulConfig {
    /// Config for unit-range operands (the Fig 8 setting).
    pub fn unit(bits: u32, mode: RoundingMode, variant: Variant, seed: u64) -> Self {
        Self {
            bits,
            mode,
            variant,
            seed,
            range_a: (0.0, 1.0),
            range_b: (0.0, 1.0),
            n_a: None,
            n_b: None,
        }
    }
}

/// Precomputed per-element quantization state: dequantized floor level, the
/// fractional residue the rounding bit decides on, and the element's dither
/// phase.
///
/// The phase deserves a note (DESIGN.md §Dither-index-alignment): §VII
/// specifies the dither index as `σ(i_s mod N)` with a global application
/// counter, but leaves the alignment between elements and index positions
/// unspecified — and a naive alignment where all elements of an output cell
/// share one position produces *coherent* per-cell rounding bias (all
/// elements with `frac > pos/N` round up together), which is catastrophically
/// worse than stochastic rounding. We give each element a fixed random phase
/// `ρ_e` into the period: use `t` of element `e` takes position
/// `σ((t + ρ_e) mod N)`. Each element still sweeps the full period across
/// its `N` uses (the §VII `Θ(1/N)` time-average argument is untouched),
/// while positions decorrelate across the contraction dimension.
struct PreMat {
    /// `lo + floor(scale(v))·step` per element (row-major).
    base: Vec<f64>,
    /// `scale(v) − floor(scale(v))` per element.
    frac: Vec<f64>,
    /// Per-element dither phase `ρ_e ∈ [0, N)`.
    phase: Vec<u32>,
    /// Branchless-dither tables (perf): `pos < n_det[e]` is the
    /// deterministic part of the dither bit; `u < u_thresh[e]` the residue
    /// Bernoulli; `is_or[e]` selects the §II-D branch (lower: OR, upper:
    /// AND). Precomputing these and evaluating the bit with pure bitwise
    /// ops removed the unpredictable per-element branches that dominated
    /// the per-partial inner loop.
    n_det: Vec<u32>,
    u_thresh: Vec<u64>,
    is_or: Vec<bool>,
    step: f64,
}

impl PreMat {
    fn build(m: &Matrix, q: &Quantizer, n: usize, seed: u64) -> PreMat {
        let max = q.max_level() as f64;
        let step = q.step();
        let count = m.rows * m.cols;
        let mut base = Vec::with_capacity(count);
        let mut frac = Vec::with_capacity(count);
        let mut phase = Vec::with_capacity(count);
        let mut n_det = Vec::with_capacity(count);
        let mut u_thresh = Vec::with_capacity(count);
        let mut is_or = Vec::with_capacity(count);
        for (e, &v) in m.data().iter().enumerate() {
            let s = q.scale(v).clamp(0.0, max);
            let fl = s.floor();
            let f = s - fl;
            base.push(q.lo + fl * step);
            frac.push(f);
            phase.push((counter_hash(seed ^ 0x9A5E, e as u64) % n as u64) as u32);
            let p = DitherParams::of(f, n);
            n_det.push(p.n as u32);
            let residue_p = if p.lower_branch { p.delta } else { 1.0 - p.delta };
            u_thresh.push((residue_p * 18446744073709551616.0) as u64);
            is_or.push(p.lower_branch);
        }
        PreMat {
            base,
            frac,
            phase,
            n_det,
            u_thresh,
            is_or,
            step,
        }
    }
}

/// The rounding bit for one use of one element.
///
/// `pos` is the (already permuted) dither index for this use; `u` the fresh
/// uniform word. Deterministic/stochastic ignore `pos`.
#[inline]
fn round_bit(mode: RoundingMode, frac: f64, n: usize, pos: usize, u: u64) -> bool {
    match mode {
        RoundingMode::Deterministic => deterministic_bit(frac),
        RoundingMode::Stochastic => u64_to_unit_f64(u) < frac,
        RoundingMode::Dither => {
            let params = DitherParams::of(frac, n);
            crate::rounding::dither_bit(&params, pos, u)
        }
    }
}

/// Hot-loop rounding bit: parameters come precomputed from [`PreMat`] and
/// the dither path is branchless — the §II-D bit is
/// `lower:  (pos < n) OR  (u < δ)`
/// `upper:  (pos < n) AND (u < 1-δ)`
/// evaluated as pure bitwise ops on precomputed thresholds (data-dependent
/// branches here mispredicted ~50% and dominated the per-partial loop).
#[inline]
fn round_bit_pre(
    mode: RoundingMode,
    pre: &PreMat,
    e: usize,
    pos: usize,
    u: impl FnOnce() -> u64,
) -> bool {
    match mode {
        RoundingMode::Deterministic => pre.frac[e] >= 0.5,
        RoundingMode::Stochastic => u64_to_unit_f64(u()) < pre.frac[e],
        RoundingMode::Dither => {
            let det = (pos as u32) < pre.n_det[e];
            let u_bit = u() < pre.u_thresh[e];
            let or = pre.is_or[e];
            // det ? (or | u_bit) : (or & u_bit)  — branch-free select.
            (det & (or | u_bit)) | (!det & or & u_bit)
        }
    }
}

/// Seeded permutation of `0..n`.
fn permutation(n: usize, seed: u64) -> Vec<usize> {
    let mut sigma: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::new(seed);
    rng.shuffle(&mut sigma);
    sigma
}

/// Phase-folded position table: `tab[phase·n + t] = σ((t + phase) mod n)`.
///
/// Turns the per-partial inner-loop position computation (add + modulo +
/// permutation load) into a single table load — n² u32 entries (40 KB for
/// n = 100) stay cache-resident (§Perf iteration 5).
fn position_table(sigma: &[usize]) -> Vec<u32> {
    let n = sigma.len();
    let mut tab = vec![0u32; n * n];
    for phase in 0..n {
        for t in 0..n {
            tab[phase * n + t] = sigma[(t + phase) % n] as u32;
        }
    }
    tab
}

/// Which axis a once-quantized matrix is contracted along in the matmul it
/// feeds (dither positions are stratified along that axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepAxis {
    /// Positions sweep along each row (left operand: `C = A·B` contracts
    /// `A` along its columns).
    Cols,
    /// Positions sweep along each column (right operand: `B` is contracted
    /// along its rows).
    Rows,
}

/// Quantize a whole matrix with one rounding per element (the `Separate` /
/// `InputOnce` building block), returning the dequantized matrix.
///
/// Dither positions SWEEP the period along the contraction axis (the
/// paper's global `i_s` counter semantics): every window of N contracted
/// elements covers the full dither sequence, so rounding errors are
/// *stratified exactly where the matmul sums them* — this is what beats
/// stochastic rounding's variance. Each line (row or column) gets its own
/// random rotation: a single shared phase would make every line reproduce
/// the *same* error pattern, coherently aligned with the other operand's
/// structure (measurably worse than stochastic rounding — see EXPERIMENTS.md
/// §Deviations); iid random positions degenerate to stochastic rounding.
pub fn quantize_matrix_once(
    m: &Matrix,
    quant: &Quantizer,
    mode: RoundingMode,
    n: usize,
    seed: u64,
    axis: SweepAxis,
) -> Matrix {
    let n = n.max(1);
    let pre = PreMat::build(m, quant, n, seed);
    let sigma = permutation(n, seed ^ 0x51);
    // Per-line rotations hoisted out of the element loop (§Perf).
    let lines = match axis {
        SweepAxis::Cols => m.rows,
        SweepAxis::Rows => m.cols,
    };
    let rots: Vec<usize> = (0..lines)
        .map(|l| (counter_hash(seed ^ 0x607, l as u64) % n as u64) as usize)
        .collect();
    let mut out = Matrix::zeros(m.rows, m.cols);
    for i in 0..m.rows {
        for j in 0..m.cols {
            let e = i * m.cols + j;
            let u = counter_hash(seed, e as u64);
            let (line, step_idx) = match axis {
                SweepAxis::Cols => (i, j), // sweep along the row
                SweepAxis::Rows => (j, i), // sweep along the column
            };
            let pos = sigma[(step_idx + rots[line]) % n];
            let bit = round_bit(mode, pre.frac[e], n, pos, u);
            out.data_mut()[e] = pre.base[e] + f64::from(bit) * pre.step;
        }
    }
    out
}

/// Quantized matrix product `Ĉ ≈ A·B` under the configured scheme,
/// placement and bit width.
pub fn quant_matmul(a: &Matrix, b: &Matrix, cfg: &QuantMatmulConfig) -> Matrix {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    let (p, q, r) = (a.rows, a.cols, b.cols);
    let quant_a = Quantizer::new(cfg.bits, cfg.range_a.0, cfg.range_a.1);
    let quant_b = Quantizer::new(cfg.bits, cfg.range_b.0, cfg.range_b.1);
    let n_a = cfg.n_a.unwrap_or(r).max(1);
    let n_b = cfg.n_b.unwrap_or(p).max(1);
    let seed_a = cfg.seed ^ 0xA0A0_A0A0;
    let seed_b = cfg.seed ^ 0xB1B1_B1B1;

    match cfg.variant {
        Variant::Separate => {
            let a_hat =
                quantize_matrix_once(a, &quant_a, cfg.mode, n_a, seed_a, SweepAxis::Cols);
            let b_hat =
                quantize_matrix_once(b, &quant_b, cfg.mode, n_b, seed_b, SweepAxis::Rows);
            a_hat.matmul(&b_hat)
        }
        Variant::InputOnce => {
            let a_hat =
                quantize_matrix_once(a, &quant_a, cfg.mode, n_a, seed_a, SweepAxis::Cols);
            let pre_b = PreMat::build(b, &quant_b, n_b, seed_b);
            let sigma_b = permutation(n_b, seed_b ^ 0x51);
            matmul_rounded_b(&a_hat, b, &pre_b, &sigma_b, cfg.mode, seed_b, p, q, r)
        }
        Variant::PerPartial => {
            let pre_a = PreMat::build(a, &quant_a, n_a, seed_a);
            let pre_b = PreMat::build(b, &quant_b, n_b, seed_b);
            let sigma_a = permutation(n_a, seed_a ^ 0x51);
            let sigma_b = permutation(n_b, seed_b ^ 0x51);
            matmul_per_partial(
                &pre_a, &pre_b, &sigma_a, &sigma_b, cfg.mode, seed_a, seed_b, p, q, r,
            )
        }
    }
}

/// `InputOnce` kernel: Â is fixed, B is rounded for every partial product
/// with per-element use index `i` (the output row).
#[allow(clippy::too_many_arguments)]
fn matmul_rounded_b(
    a_hat: &Matrix,
    _b: &Matrix,
    pre_b: &PreMat,
    sigma_b: &[usize],
    mode: RoundingMode,
    seed_b: u64,
    p: usize,
    q: usize,
    r: usize,
) -> Matrix {
    let mut out = Matrix::zeros(p, r);
    let blocks = parallel_chunks(p, |range| {
        let mut block = vec![0.0f64; range.len() * r];
        let n_b = sigma_b.len();
        for (bi, i) in range.clone().enumerate() {
            let arow = a_hat.row(i);
            for k in 0..r {
                let mut acc = 0.0;
                for j in 0..q {
                    let e_b = j * r + k;
                    let pos_b = sigma_b[(i + pre_b.phase[e_b] as usize) % n_b];
                    let bit_b = round_bit_pre(mode, pre_b, e_b, pos_b, || {
                        counter_hash(seed_b, (e_b as u64) << 24 | i as u64)
                    });
                    let b_val = pre_b.base[e_b] + f64::from(bit_b) * pre_b.step;
                    acc += arow[j] * b_val;
                }
                block[bi * r + k] = acc;
            }
        }
        (range.start, block)
    });
    for (start, block) in blocks {
        let rows = block.len() / r;
        out.data_mut()[start * r..(start + rows) * r].copy_from_slice(&block);
    }
    out
}

/// `PerPartial` kernel (Fig 7): both operands rounded per partial product.
#[allow(clippy::too_many_arguments)]
fn matmul_per_partial(
    pre_a: &PreMat,
    pre_b: &PreMat,
    sigma_a: &[usize],
    sigma_b: &[usize],
    mode: RoundingMode,
    seed_a: u64,
    seed_b: u64,
    p: usize,
    q: usize,
    r: usize,
) -> Matrix {
    let mut out = Matrix::zeros(p, r);
    let blocks = parallel_chunks(p, |range| {
        let mut block = vec![0.0f64; range.len() * r];
        let (n_a, n_b) = (sigma_a.len(), sigma_b.len());
        // Phase-folded tables are O(n²); fall back to modulo arithmetic for
        // large periods (e.g. n_b = batch rows in the thousands).
        const TABLE_CAP: usize = 1 << 11;
        let tab_a = (n_a <= TABLE_CAP).then(|| position_table(sigma_a));
        let tab_b = (n_b <= TABLE_CAP).then(|| position_table(sigma_b));
        for (bi, i) in range.clone().enumerate() {
            let i_mod = i % n_b;
            for k in 0..r {
                let k_mod = k % n_a;
                let mut acc = 0.0;
                for j in 0..q {
                    let e_a = i * q + j;
                    let e_b = j * r + k;
                    // Fresh uniform per (element, use): the use id is the
                    // output coordinate the element is consumed by. Dither
                    // positions sweep the period per element via its phase
                    // (phase-folded table lookup); the hash is evaluated
                    // lazily (residue slots only).
                    let pos_a = match &tab_a {
                        Some(t) => t[pre_a.phase[e_a] as usize * n_a + k_mod] as usize,
                        None => sigma_a[(k_mod + pre_a.phase[e_a] as usize) % n_a],
                    };
                    let pos_b = match &tab_b {
                        Some(t) => t[pre_b.phase[e_b] as usize * n_b + i_mod] as usize,
                        None => sigma_b[(i_mod + pre_b.phase[e_b] as usize) % n_b],
                    };
                    let bit_a = round_bit_pre(mode, pre_a, e_a, pos_a, || {
                        counter_hash(seed_a, (e_a as u64) << 24 | k as u64)
                    });
                    let bit_b = round_bit_pre(mode, pre_b, e_b, pos_b, || {
                        counter_hash(seed_b, (e_b as u64) << 24 | i as u64)
                    });
                    let a_val = pre_a.base[e_a] + f64::from(bit_a) * pre_a.step;
                    let b_val = pre_b.base[e_b] + f64::from(bit_b) * pre_b.step;
                    acc += a_val * b_val;
                }
                block[bi * r + k] = acc;
            }
        }
        (range.start, block)
    });
    for (start, block) in blocks {
        let rows = block.len() / r;
        out.data_mut()[start * r..(start + rows) * r].copy_from_slice(&block);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::frobenius_error;

    fn random_pair(p: usize, q: usize, r: usize, lo: f64, hi: f64, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::new(seed);
        (
            Matrix::random_uniform(p, q, lo, hi, &mut rng),
            Matrix::random_uniform(q, r, lo, hi, &mut rng),
        )
    }

    #[test]
    fn rounding_op_counts() {
        assert_eq!(Variant::PerPartial.rounding_ops(2, 3, 4), 48);
        assert_eq!(Variant::InputOnce.rounding_ops(2, 3, 4), 30);
        assert_eq!(Variant::Separate.rounding_ops(2, 3, 4), 18);
    }

    #[test]
    fn high_precision_recovers_product() {
        // At k = 16 every scheme/variant should be ~exact.
        let (a, b) = random_pair(8, 12, 6, 0.0, 1.0, 1);
        let c = a.matmul(&b);
        for mode in RoundingMode::ALL {
            for variant in Variant::ALL {
                let cfg = QuantMatmulConfig::unit(16, mode, variant, 42);
                let c_hat = quant_matmul(&a, &b, &cfg);
                let e = frobenius_error(&c, &c_hat) / c.frobenius_norm();
                assert!(e < 1e-3, "{mode:?}/{variant:?} rel err {e}");
            }
        }
    }

    #[test]
    fn unbiased_modes_beat_traditional_at_small_k_narrow_range() {
        // The §VII narrow-range scenario: entries in [0, 0.5), k = 2.
        let (a, b) = random_pair(24, 24, 24, 0.0, 0.5, 3);
        let c = a.matmul(&b);
        let err = |mode: RoundingMode| {
            let mut tot = 0.0;
            for t in 0..5u64 {
                let cfg = QuantMatmulConfig::unit(2, mode, Variant::PerPartial, 100 + t);
                tot += frobenius_error(&c, &quant_matmul(&a, &b, &cfg));
            }
            tot / 5.0
        };
        let det = err(RoundingMode::Deterministic);
        let dit = err(RoundingMode::Dither);
        let sto = err(RoundingMode::Stochastic);
        assert!(dit < det, "dither {dit} < deterministic {det}");
        assert!(sto < det, "stochastic {sto} < deterministic {det}");
        assert!(dit <= sto * 1.1, "dither {dit} ≲ stochastic {sto}");
    }

    #[test]
    fn k1_traditional_loses_everything_below_half() {
        // Footnote 3: at k=1 with entries in [0, 0.5), traditional rounding
        // zeroes both matrices, e_f = ‖AB‖_F.
        let (a, b) = random_pair(10, 10, 10, 0.0, 0.4999, 5);
        let c = a.matmul(&b);
        let cfg = QuantMatmulConfig::unit(1, RoundingMode::Deterministic, Variant::Separate, 7);
        let c_hat = quant_matmul(&a, &b, &cfg);
        assert_eq!(c_hat.frobenius_norm(), 0.0);
        assert!((frobenius_error(&c, &c_hat) - c.frobenius_norm()).abs() < 1e-12);
    }

    #[test]
    fn dither_per_partial_is_unbiased() {
        // E(Ĉ) = C: average Ĉ over trials, error should shrink.
        let (a, b) = random_pair(6, 6, 6, 0.0, 1.0, 9);
        let c = a.matmul(&b);
        let trials = 60;
        let mut mean = Matrix::zeros(6, 6);
        for t in 0..trials {
            let cfg = QuantMatmulConfig::unit(2, RoundingMode::Dither, Variant::PerPartial, t);
            let c_hat = quant_matmul(&a, &b, &cfg);
            for (m, v) in mean.data_mut().iter_mut().zip(c_hat.data()) {
                *m += v / trials as f64;
            }
        }
        let single_cfg = QuantMatmulConfig::unit(2, RoundingMode::Dither, Variant::PerPartial, 0);
        let single = frobenius_error(&c, &quant_matmul(&a, &b, &single_cfg));
        let averaged = frobenius_error(&c, &mean);
        assert!(
            averaged < single / 2.0,
            "trial-mean error {averaged} should be well below single-trial {single}"
        );
    }

    #[test]
    fn per_partial_comparable_to_separate_for_dither() {
        // Per-partial does 2pqr roundings vs (p+r)q for separate; with the
        // contraction-axis-stratified separate quantizer both land close —
        // per-partial must stay within a small factor (and both far below
        // the deterministic mode's error at this k; see the narrow-range
        // test above for that ordering).
        let (a, b) = random_pair(32, 32, 32, 0.0, 1.0, 11);
        let c = a.matmul(&b);
        let err = |variant: Variant| {
            let mut tot = 0.0;
            for t in 0..8u64 {
                let cfg = QuantMatmulConfig::unit(3, RoundingMode::Dither, variant, 200 + t);
                tot += frobenius_error(&c, &quant_matmul(&a, &b, &cfg));
            }
            tot / 8.0
        };
        let pp = err(Variant::PerPartial);
        let sep = err(Variant::Separate);
        assert!(
            pp < sep * 1.5,
            "per-partial {pp} should be comparable to separate {sep}"
        );
    }

    #[test]
    fn signed_ranges_work() {
        let mut rng = Xoshiro256pp::new(13);
        let a = Matrix::random_uniform(10, 10, 0.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(10, 10, -1.0, 1.0, &mut rng);
        let c = a.matmul(&b);
        let cfg = QuantMatmulConfig {
            bits: 8,
            mode: RoundingMode::Dither,
            variant: Variant::PerPartial,
            seed: 17,
            range_a: (0.0, 1.0),
            range_b: (-1.0, 1.0),
            n_a: None,
            n_b: None,
        };
        let c_hat = quant_matmul(&a, &b, &cfg);
        let rel = frobenius_error(&c, &c_hat) / c.frobenius_norm();
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn quantize_once_deterministic_matches_quantizer() {
        let mut rng = Xoshiro256pp::new(15);
        let m = Matrix::random_uniform(7, 5, 0.0, 1.0, &mut rng);
        let q = Quantizer::unit(3);
        let out = quantize_matrix_once(&m, &q, RoundingMode::Deterministic, 8, 0, SweepAxis::Cols);
        for i in 0..7 {
            for j in 0..5 {
                let expect = q.dequant(q.quantize_round(m.get(i, j)));
                assert!((out.get(i, j) - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reproducible_per_seed() {
        let (a, b) = random_pair(5, 5, 5, 0.0, 1.0, 21);
        let cfg = QuantMatmulConfig::unit(2, RoundingMode::Dither, Variant::PerPartial, 77);
        assert_eq!(quant_matmul(&a, &b, &cfg), quant_matmul(&a, &b, &cfg));
    }
}
