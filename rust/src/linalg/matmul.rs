//! Fixed-point matrix-multiplication engines with pluggable rounding —
//! §VII (Fig 7) and the §VIII variants — structured as an explicit
//! **plan → execute** pipeline.
//!
//! `C = A·B` is computed as if only a k-bit fixed-point multiplier existed:
//! each operand element is affinely rescaled into `[0, 2^k−1]`, rounded to
//! an integer level by the configured [`SchemeId`], dequantized, and the
//! partial products accumulated exactly (the accumulator is not the paper's
//! concern; the rounding of the multiplier inputs is).
//!
//! The paper's asymptotic win comes from the *encoding* of the operands, so
//! the expensive per-element encoding state (quantizer scaling, floor/residue
//! split, dither thresholds) is captured once per operand in a [`QuantPlan`]
//! and reused across executions. [`execute`] consumes either prepared plans
//! or raw matrices ([`Operand`]); [`quant_matmul`] is the thin
//! plan-both-sides-per-call compatibility wrapper over it.
//!
//! Three rounding *placements* trade accuracy for rounding work:
//!
//! * [`Variant::PerPartial`] — both operands are rounded for every partial
//!   product (Fig 7): `2pqr` roundings. Dither indices: element `A_ij`'s
//!   use for output column `k` takes index `σ_A(k mod N_A)`, `B_jk`'s use
//!   for output row `i` takes `σ_B(i mod N_B)` — each element's uses sweep
//!   a full period, which is what drives the `Θ(1/N)` error of §VII.
//! * [`Variant::InputOnce`] — `A` rounded once per element, `B` per partial:
//!   `pq + pqr` roundings (§VIII, Figs 11–12).
//! * [`Variant::Separate`] — both matrices rounded once, then multiplied:
//!   `(p+r)·q` roundings (§VIII, Figs 13–16).

use crate::bitstream::dither::DitherParams;
use crate::linalg::matrix::Matrix;
use crate::rounding::{gauss_bit, sr2_bit, srvb_bit, tpdf_bit, Quantizer, SchemeId};
use crate::util::rng::{counter_hash, u64_to_unit_f64, Xoshiro256pp};
use crate::util::threadpool::parallel_chunks;
use std::borrow::Cow;

/// Rounding placement within the matmul (§VII–§VIII).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Round both operands per partial product — `2pqr` roundings (Fig 7).
    PerPartial,
    /// Round `A` once per element, `B` per partial — `pq(r+1)` roundings.
    InputOnce,
    /// Round both matrices once, multiply the rounded matrices — `(p+r)q`.
    Separate,
}

impl Variant {
    /// All variants in paper order.
    pub const ALL: [Variant; 3] = [Variant::PerPartial, Variant::InputOnce, Variant::Separate];

    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Variant::PerPartial => "per-partial",
            Variant::InputOnce => "input-once",
            Variant::Separate => "separate",
        }
    }

    /// Parse from CLI spelling.
    pub fn from_str(s: &str) -> Option<Variant> {
        match s {
            "per-partial" | "perpartial" | "pp" => Some(Variant::PerPartial),
            "input-once" | "inputonce" | "io" => Some(Variant::InputOnce),
            "separate" | "sep" => Some(Variant::Separate),
            _ => None,
        }
    }

    /// Number of scalar rounding operations for a `p×q · q×r` product.
    pub fn rounding_ops(&self, p: usize, q: usize, r: usize) -> usize {
        match self {
            Variant::PerPartial => 2 * p * q * r,
            Variant::InputOnce => p * q * (r + 1),
            Variant::Separate => (p + r) * q,
        }
    }
}

/// Configuration for a quantized matrix multiplication.
#[derive(Clone, Debug)]
pub struct QuantMatmulConfig {
    /// Quantizer bit width `k`.
    pub bits: u32,
    /// Rounding scheme.
    pub mode: SchemeId,
    /// Rounding placement.
    pub variant: Variant,
    /// Seed for all stochastic/dither randomness (vary per trial).
    pub seed: u64,
    /// Source range of `A`'s entries.
    pub range_a: (f64, f64),
    /// Source range of `B`'s entries.
    pub range_b: (f64, f64),
    /// Dither period for `A` (`None` → `r`, the per-element use count).
    pub n_a: Option<usize>,
    /// Dither period for `B` (`None` → `p`).
    pub n_b: Option<usize>,
}

impl QuantMatmulConfig {
    /// Config for unit-range operands (the Fig 8 setting).
    pub fn unit(bits: u32, mode: SchemeId, variant: Variant, seed: u64) -> Self {
        Self {
            bits,
            mode,
            variant,
            seed,
            range_a: (0.0, 1.0),
            range_b: (0.0, 1.0),
            n_a: None,
            n_b: None,
        }
    }
}

/// Precomputed per-element quantization state: dequantized floor level, the
/// fractional residue the rounding bit decides on, and (dither only) the
/// branchless §II-D tables. Everything here depends only on
/// `(matrix, quantizer, mode, n)` — never on a seed — which is what makes a
/// [`QuantPlan`] reusable across requests with fresh randomness.
struct PreMat {
    /// `lo + floor(scale(v))·step` per element (row-major).
    base: Vec<f64>,
    /// `scale(v) − floor(scale(v))` per element.
    frac: Vec<f64>,
    /// Branchless-dither tables (perf): `pos < n_det[e]` is the
    /// deterministic part of the dither bit; `u < u_thresh[e]` the residue
    /// Bernoulli; `is_or[e]` selects the §II-D branch (lower: OR, upper:
    /// AND). Precomputing these and evaluating the bit with pure bitwise
    /// ops removed the unpredictable per-element branches that dominated
    /// the per-partial inner loop. Empty for non-dither modes.
    n_det: Vec<u32>,
    u_thresh: Vec<u64>,
    is_or: Vec<bool>,
    step: f64,
}

impl PreMat {
    fn build(m: &Matrix, q: &Quantizer, mode: SchemeId, n: usize) -> PreMat {
        let max = q.max_level() as f64;
        let step = q.step();
        let count = m.rows * m.cols;
        let dither = mode == SchemeId::Dither;
        let mut base = Vec::with_capacity(count);
        let mut frac = Vec::with_capacity(count);
        let mut n_det = Vec::with_capacity(if dither { count } else { 0 });
        let mut u_thresh = Vec::with_capacity(if dither { count } else { 0 });
        let mut is_or = Vec::with_capacity(if dither { count } else { 0 });
        for &v in m.data().iter() {
            let s = q.scale(v).clamp(0.0, max);
            let fl = s.floor();
            let f = s - fl;
            base.push(q.lo + fl * step);
            frac.push(f);
            if dither {
                let p = DitherParams::of(f, n);
                n_det.push(p.n as u32);
                let residue_p = if p.lower_branch { p.delta } else { 1.0 - p.delta };
                u_thresh.push((residue_p * 18446744073709551616.0) as u64);
                is_or.push(p.lower_branch);
            }
        }
        PreMat {
            base,
            frac,
            n_det,
            u_thresh,
            is_or,
            step,
        }
    }

    /// Heap footprint of the tables (plan-cache accounting).
    fn memory_bytes(&self) -> usize {
        self.base.len() * 8
            + self.frac.len() * 8
            + self.n_det.len() * 4
            + self.u_thresh.len() * 8
            + self.is_or.len()
    }
}

/// Per-element dither phases for one operand: element `e` starts its sweep
/// at `ρ_e = hash(seed, e) mod n`. Seed-dependent but cheap (one hash per
/// element), so it is derived per execution rather than stored in the plan.
///
/// The phase deserves a note (DESIGN.md §Dither-index-alignment): §VII
/// specifies the dither index as `σ(i_s mod N)` with a global application
/// counter, but leaves the alignment between elements and index positions
/// unspecified — and a naive alignment where all elements of an output cell
/// share one position produces *coherent* per-cell rounding bias (all
/// elements with `frac > pos/N` round up together), which is catastrophically
/// worse than stochastic rounding. We give each element a fixed random phase
/// `ρ_e` into the period: use `t` of element `e` takes position
/// `σ((t + ρ_e) mod N)`. Each element still sweeps the full period across
/// its `N` uses (the §VII `Θ(1/N)` time-average argument is untouched),
/// while positions decorrelate across the contraction dimension.
fn phases(count: usize, n: usize, seed: u64) -> Vec<u32> {
    (0..count)
        .map(|e| (counter_hash(seed ^ 0x9A5E, e as u64) % n as u64) as u32)
        .collect()
}

/// Hot-loop rounding bit: parameters come precomputed from [`PreMat`] and
/// the dither path is branchless — the §II-D bit is
/// `lower:  (pos < n) OR  (u < δ)`
/// `upper:  (pos < n) AND (u < 1-δ)`
/// evaluated as pure bitwise ops on precomputed thresholds (data-dependent
/// branches here mispredicted ~50% and dominated the per-partial loop).
#[inline]
fn round_bit_pre(
    mode: SchemeId,
    pre: &PreMat,
    e: usize,
    pos: usize,
    u: impl FnOnce() -> u64,
) -> bool {
    match mode {
        SchemeId::Deterministic => pre.frac[e] >= 0.5,
        SchemeId::Stochastic => u64_to_unit_f64(u()) < pre.frac[e],
        SchemeId::Dither => {
            let det = (pos as u32) < pre.n_det[e];
            let u_bit = u() < pre.u_thresh[e];
            let or = pre.is_or[e];
            // det ? (or | u_bit) : (or & u_bit)  — branch-free select.
            (det & (or | u_bit)) | (!det & or & u_bit)
        }
        // Literature-zoo schemes: stateless (frac, u) bits, position-free —
        // the same per-use uniform discipline as stochastic rounding.
        SchemeId::Sr2 => sr2_bit(pre.frac[e], u()),
        SchemeId::SrVb => srvb_bit(pre.frac[e], u()),
        SchemeId::Tpdf => tpdf_bit(pre.frac[e], u()),
        SchemeId::Gauss => gauss_bit(pre.frac[e], u()),
    }
}

/// Seeded permutation of `0..n`.
fn permutation(n: usize, seed: u64) -> Vec<usize> {
    let mut sigma: Vec<usize> = (0..n).collect();
    let mut rng = Xoshiro256pp::new(seed);
    rng.shuffle(&mut sigma);
    sigma
}

/// Phase-folded position table: `tab[phase·n + t] = σ((t + phase) mod n)`.
///
/// Turns the per-partial inner-loop position computation (add + modulo +
/// permutation load) into a single table load — n² u32 entries (40 KB for
/// n = 100) stay cache-resident (§Perf iteration 5).
fn position_table(sigma: &[usize]) -> Vec<u32> {
    let n = sigma.len();
    let mut tab = vec![0u32; n * n];
    for phase in 0..n {
        for t in 0..n {
            tab[phase * n + t] = sigma[(t + phase) % n] as u32;
        }
    }
    tab
}

/// Which axis a once-quantized matrix is contracted along in the matmul it
/// feeds (dither positions are stratified along that axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepAxis {
    /// Positions sweep along each row (left operand: `C = A·B` contracts
    /// `A` along its columns).
    Cols,
    /// Positions sweep along each column (right operand: `B` is contracted
    /// along its rows).
    Rows,
}

/// Prepared per-operand state for quantized multiplication: the quantizer,
/// the seed-independent per-element tables ([`PreMat`]), the dither
/// geometry (period + sweep axis), and — when the operand's rounded values
/// are request-invariant (frozen weight operands) — the fully materialized
/// quantized matrix.
///
/// Building a plan is the expensive half of a quantized matmul at serving
/// batch sizes (per-element scale/clamp/floor plus the §II-D dither
/// parameter derivation); [`execute`] reuses a plan across calls and only
/// derives the cheap seed-dependent state (phases, permutation, rotations)
/// per call.
///
/// The dither period `n` is clamped to `≥ 1` here and nowhere else — every
/// construction path flows through [`QuantPlan::plan_operand`], so a caller
/// can never build tables for `n = 0`.
pub struct QuantPlan {
    quant: Quantizer,
    mode: SchemeId,
    axis: SweepAxis,
    n: usize,
    rows: usize,
    cols: usize,
    /// Per-call quantization tables; dropped for frozen plans.
    pre: Option<PreMat>,
    /// Materialized quantized matrix (request-invariant operands only).
    rounded: Option<Matrix>,
}

impl QuantPlan {
    /// Prepare an operand for repeated quantized multiplication. `n` is the
    /// dither period (clamped to `≥ 1`; this is the single clamp site for
    /// the whole module) and `axis` the contraction sweep axis.
    pub fn plan_operand(
        m: &Matrix,
        quant: &Quantizer,
        mode: SchemeId,
        n: usize,
        axis: SweepAxis,
    ) -> QuantPlan {
        let n = n.max(1);
        QuantPlan {
            quant: *quant,
            mode,
            axis,
            n,
            rows: m.rows,
            cols: m.cols,
            pre: Some(PreMat::build(m, quant, mode, n)),
            rounded: None,
        }
    }

    /// Prepare a *frozen* operand: the quantized matrix is materialized now
    /// (with `seed` driving any dither/stochastic residue draws) and reused
    /// verbatim by every execution, and the per-call tables are dropped.
    ///
    /// Correct for operands whose rounded values are request-invariant —
    /// deterministic rounding (seed-free by definition) and dither weight
    /// operands, whose representation is deterministic to first order
    /// (§II-D): the serving path freezes one dither draw per weight matrix.
    /// Frozen plans execute under [`Variant::Separate`] only (the
    /// per-partial placements re-round per use by definition).
    pub fn plan_frozen(
        m: &Matrix,
        quant: &Quantizer,
        mode: SchemeId,
        n: usize,
        axis: SweepAxis,
        seed: u64,
    ) -> QuantPlan {
        let mut plan = QuantPlan::plan_operand(m, quant, mode, n, axis);
        let rounded = plan.quantize_once(seed).into_owned();
        plan.rounded = Some(rounded);
        plan.pre = None;
        plan
    }

    /// Quantize the whole operand with one rounding per element (the
    /// `Separate` / `InputOnce` building block). Frozen plans return the
    /// materialized matrix without touching `seed`.
    pub fn quantize_once(&self, seed: u64) -> Cow<'_, Matrix> {
        if let Some(rounded) = &self.rounded {
            return Cow::Borrowed(rounded);
        }
        let pre = self.pre().expect("plan holds tables or a frozen matrix");
        let (rows, cols) = (self.rows, self.cols);
        let count = rows * cols;
        let mut out = Matrix::zeros(rows, cols);
        let data = out.data_mut();
        match self.mode {
            SchemeId::Deterministic => {
                for e in 0..count {
                    let bit = pre.frac[e] >= 0.5;
                    data[e] = pre.base[e] + f64::from(bit) * pre.step;
                }
            }
            SchemeId::Stochastic => {
                for e in 0..count {
                    let bit = u64_to_unit_f64(counter_hash(seed, e as u64)) < pre.frac[e];
                    data[e] = pre.base[e] + f64::from(bit) * pre.step;
                }
            }
            SchemeId::Dither => {
                // Dither positions SWEEP the period along the contraction
                // axis (the paper's global `i_s` counter semantics): every
                // window of N contracted elements covers the full dither
                // sequence, so rounding errors are *stratified exactly
                // where the matmul sums them* — this is what beats
                // stochastic rounding's variance. Each line (row or column)
                // gets its own random rotation: a single shared phase would
                // make every line reproduce the *same* error pattern,
                // coherently aligned with the other operand's structure
                // (measurably worse than stochastic rounding — see
                // EXPERIMENTS.md §Deviations); iid random positions
                // degenerate to stochastic rounding.
                let n = self.n;
                let sigma = permutation(n, seed ^ 0x51);
                let lines = match self.axis {
                    SweepAxis::Cols => rows,
                    SweepAxis::Rows => cols,
                };
                let rots: Vec<usize> = (0..lines)
                    .map(|l| (counter_hash(seed ^ 0x607, l as u64) % n as u64) as usize)
                    .collect();
                for i in 0..rows {
                    for j in 0..cols {
                        let e = i * cols + j;
                        let (line, step_idx) = match self.axis {
                            SweepAxis::Cols => (i, j), // sweep along the row
                            SweepAxis::Rows => (j, i), // sweep along the column
                        };
                        let pos = sigma[(step_idx + rots[line]) % n];
                        let bit = round_bit_pre(self.mode, pre, e, pos, || {
                            counter_hash(seed, e as u64)
                        });
                        data[e] = pre.base[e] + f64::from(bit) * pre.step;
                    }
                }
            }
            // Zoo schemes: one counter-hashed uniform per element, same
            // discipline as the stochastic arm (position is irrelevant).
            zoo => {
                for e in 0..count {
                    let bit = round_bit_pre(zoo, pre, e, 0, || counter_hash(seed, e as u64));
                    data[e] = pre.base[e] + f64::from(bit) * pre.step;
                }
            }
        }
        Cow::Owned(out)
    }

    fn pre(&self) -> Option<&PreMat> {
        self.pre.as_ref()
    }

    /// Operand shape.
    pub fn dims(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Quantizer bit width the plan was built for.
    pub fn bits(&self) -> u32 {
        self.quant.bits
    }

    /// Rounding scheme the plan was built for.
    pub fn mode(&self) -> SchemeId {
        self.mode
    }

    /// Clamped dither period.
    pub fn n(&self) -> usize {
        self.n
    }

    /// True when the quantized matrix is materialized (request-invariant).
    pub fn is_frozen(&self) -> bool {
        self.rounded.is_some()
    }

    /// Approximate heap footprint (plan-cache accounting / logs).
    pub fn memory_bytes(&self) -> usize {
        let pre = self.pre.as_ref().map_or(0, PreMat::memory_bytes);
        let frozen = self.rounded.as_ref().map_or(0, |m| m.data().len() * 8);
        pre + frozen
    }
}

/// One side of an [`execute`] call: either a raw matrix (planned on the
/// fly from the config's quantizer — the one-shot path) or a prepared
/// [`QuantPlan`] (the serving path, where weight-side plans are cached).
pub enum Operand<'a> {
    /// Plan this matrix per call.
    Raw(&'a Matrix),
    /// Reuse a prepared plan.
    Plan(&'a QuantPlan),
}

impl Operand<'_> {
    fn dims(&self) -> (usize, usize) {
        match self {
            Operand::Raw(m) => (m.rows, m.cols),
            Operand::Plan(p) => p.dims(),
        }
    }
}

/// Quantize a whole matrix with one rounding per element, returning the
/// dequantized matrix. Thin wrapper over a one-shot [`QuantPlan`].
pub fn quantize_matrix_once(
    m: &Matrix,
    quant: &Quantizer,
    mode: SchemeId,
    n: usize,
    seed: u64,
    axis: SweepAxis,
) -> Matrix {
    QuantPlan::plan_operand(m, quant, mode, n, axis).quantize_once(seed).into_owned()
}

/// Quantized matrix product `Ĉ ≈ A·B` under the configured scheme,
/// placement and bit width — the plan-both-sides-per-call compatibility
/// wrapper over [`execute`].
pub fn quant_matmul(a: &Matrix, b: &Matrix, cfg: &QuantMatmulConfig) -> Matrix {
    execute(Operand::Raw(a), Operand::Raw(b), cfg)
}

/// Execute a quantized matrix product from per-operand state. Raw operands
/// are planned on the fly with the config's quantizers; prepared plans are
/// validated against the config (bit width and scheme must match — a plan's
/// dither period `n` intentionally overrides `cfg.n_a`/`cfg.n_b`, since the
/// plan owner fixed the stratification geometry at build time).
pub fn execute(a: Operand<'_>, b: Operand<'_>, cfg: &QuantMatmulConfig) -> Matrix {
    let (p, q) = a.dims();
    let (q2, r) = b.dims();
    assert_eq!(q, q2, "inner dimensions must match");
    let seed_a = cfg.seed ^ 0xA0A0_A0A0;
    let seed_b = cfg.seed ^ 0xB1B1_B1B1;

    let built_a;
    let plan_a = match a {
        Operand::Raw(m) => {
            let quant = Quantizer::new(cfg.bits, cfg.range_a.0, cfg.range_a.1);
            let n_a = cfg.n_a.unwrap_or(r);
            built_a = QuantPlan::plan_operand(m, &quant, cfg.mode, n_a, SweepAxis::Cols);
            &built_a
        }
        Operand::Plan(plan) => {
            check_plan(plan, cfg, cfg.range_a, SweepAxis::Cols, "A");
            plan
        }
    };
    let built_b;
    let plan_b = match b {
        Operand::Raw(m) => {
            let quant = Quantizer::new(cfg.bits, cfg.range_b.0, cfg.range_b.1);
            let n_b = cfg.n_b.unwrap_or(p);
            built_b = QuantPlan::plan_operand(m, &quant, cfg.mode, n_b, SweepAxis::Rows);
            &built_b
        }
        Operand::Plan(plan) => {
            check_plan(plan, cfg, cfg.range_b, SweepAxis::Rows, "B");
            plan
        }
    };

    match cfg.variant {
        Variant::Separate => {
            let a_hat = plan_a.quantize_once(seed_a);
            let b_hat = plan_b.quantize_once(seed_b);
            a_hat.matmul(&b_hat)
        }
        Variant::InputOnce => {
            let a_hat = plan_a.quantize_once(seed_a);
            matmul_rounded_b(&a_hat, plan_b, seed_b, p, q, r)
        }
        Variant::PerPartial => matmul_per_partial(plan_a, plan_b, seed_a, seed_b, p, q, r),
    }
}

fn check_plan(
    plan: &QuantPlan,
    cfg: &QuantMatmulConfig,
    range: (f64, f64),
    axis: SweepAxis,
    side: &str,
) {
    assert_eq!(
        plan.bits(),
        cfg.bits,
        "operand {side}: plan bit width != config bit width"
    );
    assert_eq!(
        plan.mode(),
        cfg.mode,
        "operand {side}: plan rounding scheme != config scheme"
    );
    // Bitwise range equality is intentional: prepared paths derive the
    // range from the same computation as the config, so any difference
    // means the plan was built for another source interval and would
    // execute with silently wrong scaling.
    let range_ok = plan.quant.lo.to_bits() == range.0.to_bits()
        && plan.quant.hi.to_bits() == range.1.to_bits();
    assert!(
        range_ok,
        "operand {side}: plan quantizer range ({}, {}) != config range ({}, {})",
        plan.quant.lo,
        plan.quant.hi,
        range.0,
        range.1
    );
    assert_eq!(plan.axis, axis, "operand {side}: plan sweep axis mismatch");
}

/// `InputOnce` kernel: Â is fixed, B is rounded for every partial product
/// with per-element use index `i` (the output row).
///
/// The inner loop is blocked by the active kernel's lane width (4 scalar,
/// 8 wide): consecutive `k` read *adjacent* `PreMat` entries (`e_b =
/// j·r + k`), turning the stride-r table walk into contiguous cache-line
/// reads, and `arow[j]` is loaded once per lane group. Each lane owns an
/// independent accumulator chain while per-cell accumulation order stays
/// the plain `j` order — results are bit-identical across lane widths.
fn matmul_rounded_b(
    a_hat: &Matrix,
    plan_b: &QuantPlan,
    seed_b: u64,
    p: usize,
    q: usize,
    r: usize,
) -> Matrix {
    let pre_b = plan_b
        .pre()
        .expect("the input-once placement requires an unfrozen weight-side plan");
    let n_b = plan_b.n();
    let mode = plan_b.mode();
    let phase_b = phases(q * r, n_b, seed_b);
    let sigma_b = permutation(n_b, seed_b ^ 0x51);
    let width = crate::kernels::active().lanes();
    let mut out = Matrix::zeros(p, r);
    let blocks = parallel_chunks(p, |range| {
        let mut block = vec![0.0f64; range.len() * r];
        for (bi, i) in range.clone().enumerate() {
            let arow = a_hat.row(i);
            let mut k0 = 0;
            while k0 < r {
                let lanes = (r - k0).min(width);
                let mut acc = [0.0f64; crate::kernels::MAX_LANES];
                for (j, &a_val) in arow.iter().enumerate() {
                    let row_b = j * r + k0;
                    for (lane, slot) in acc.iter_mut().enumerate().take(lanes) {
                        let e_b = row_b + lane;
                        let pos_b = sigma_b[(i + phase_b[e_b] as usize) % n_b];
                        let bit_b = round_bit_pre(mode, pre_b, e_b, pos_b, || {
                            counter_hash(seed_b, (e_b as u64) << 24 | i as u64)
                        });
                        let b_val = pre_b.base[e_b] + f64::from(bit_b) * pre_b.step;
                        *slot += a_val * b_val;
                    }
                }
                block[bi * r + k0..bi * r + k0 + lanes].copy_from_slice(&acc[..lanes]);
                k0 += lanes;
            }
        }
        (range.start, block)
    });
    for (start, block) in blocks {
        let rows = block.len() / r;
        out.data_mut()[start * r..(start + rows) * r].copy_from_slice(&block);
    }
    out
}

/// `PerPartial` kernel (Fig 7): both operands rounded per partial product.
///
/// Blocked like [`matmul_rounded_b`]: a lane-width group of output columns
/// per pass shares every A-side table load (`e_a = i·q + j` is
/// lane-invariant) and reads adjacent B-side entries, with one independent
/// accumulator chain per lane and the per-cell accumulation order
/// unchanged (bit-identical across lane widths).
fn matmul_per_partial(
    plan_a: &QuantPlan,
    plan_b: &QuantPlan,
    seed_a: u64,
    seed_b: u64,
    p: usize,
    q: usize,
    r: usize,
) -> Matrix {
    let pre_a = plan_a
        .pre()
        .expect("the per-partial placement requires an unfrozen left-operand plan");
    let pre_b = plan_b
        .pre()
        .expect("the per-partial placement requires an unfrozen weight-side plan");
    let (n_a, n_b) = (plan_a.n(), plan_b.n());
    let mode = plan_a.mode();
    let phase_a = phases(p * q, n_a, seed_a);
    let phase_b = phases(q * r, n_b, seed_b);
    let sigma_a = permutation(n_a, seed_a ^ 0x51);
    let sigma_b = permutation(n_b, seed_b ^ 0x51);
    let width = crate::kernels::active().lanes();
    let mut out = Matrix::zeros(p, r);
    let blocks = parallel_chunks(p, |range| {
        let mut block = vec![0.0f64; range.len() * r];
        // Phase-folded tables are O(n²); fall back to modulo arithmetic for
        // large periods (e.g. n_b = batch rows in the thousands).
        const TABLE_CAP: usize = 1 << 11;
        let tab_a = (n_a <= TABLE_CAP).then(|| position_table(&sigma_a));
        let tab_b = (n_b <= TABLE_CAP).then(|| position_table(&sigma_b));
        for (bi, i) in range.clone().enumerate() {
            let i_mod = i % n_b;
            let mut k0 = 0;
            while k0 < r {
                let lanes = (r - k0).min(width);
                let mut acc = [0.0f64; crate::kernels::MAX_LANES];
                for j in 0..q {
                    let e_a = i * q + j;
                    // Fresh uniform per (element, use): the use id is the
                    // output coordinate the element is consumed by. Dither
                    // positions sweep the period per element via its phase
                    // (phase-folded table lookup); the hash is evaluated
                    // lazily (residue slots only).
                    let pa = phase_a[e_a] as usize;
                    let row_b = j * r + k0;
                    for (lane, slot) in acc.iter_mut().enumerate().take(lanes) {
                        let k = k0 + lane;
                        let k_mod = k % n_a;
                        let pos_a = match &tab_a {
                            Some(t) => t[pa * n_a + k_mod] as usize,
                            None => sigma_a[(k_mod + pa) % n_a],
                        };
                        let e_b = row_b + lane;
                        let pb = phase_b[e_b] as usize;
                        let pos_b = match &tab_b {
                            Some(t) => t[pb * n_b + i_mod] as usize,
                            None => sigma_b[(i_mod + pb) % n_b],
                        };
                        let bit_a = round_bit_pre(mode, pre_a, e_a, pos_a, || {
                            counter_hash(seed_a, (e_a as u64) << 24 | k as u64)
                        });
                        let bit_b = round_bit_pre(mode, pre_b, e_b, pos_b, || {
                            counter_hash(seed_b, (e_b as u64) << 24 | i as u64)
                        });
                        let a_val = pre_a.base[e_a] + f64::from(bit_a) * pre_a.step;
                        let b_val = pre_b.base[e_b] + f64::from(bit_b) * pre_b.step;
                        *slot += a_val * b_val;
                    }
                }
                block[bi * r + k0..bi * r + k0 + lanes].copy_from_slice(&acc[..lanes]);
                k0 += lanes;
            }
        }
        (range.start, block)
    });
    for (start, block) in blocks {
        let rows = block.len() / r;
        out.data_mut()[start * r..(start + rows) * r].copy_from_slice(&block);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::matrix::frobenius_error;

    fn random_pair(p: usize, q: usize, r: usize, lo: f64, hi: f64, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Xoshiro256pp::new(seed);
        (
            Matrix::random_uniform(p, q, lo, hi, &mut rng),
            Matrix::random_uniform(q, r, lo, hi, &mut rng),
        )
    }

    #[test]
    fn rounding_op_counts() {
        assert_eq!(Variant::PerPartial.rounding_ops(2, 3, 4), 48);
        assert_eq!(Variant::InputOnce.rounding_ops(2, 3, 4), 30);
        assert_eq!(Variant::Separate.rounding_ops(2, 3, 4), 18);
    }

    #[test]
    fn high_precision_recovers_product() {
        // At k = 16 every scheme/variant should be ~exact.
        let (a, b) = random_pair(8, 12, 6, 0.0, 1.0, 1);
        let c = a.matmul(&b);
        for mode in SchemeId::ALL {
            for variant in Variant::ALL {
                let cfg = QuantMatmulConfig::unit(16, mode, variant, 42);
                let c_hat = quant_matmul(&a, &b, &cfg);
                let e = frobenius_error(&c, &c_hat) / c.frobenius_norm();
                assert!(e < 1e-3, "{mode:?}/{variant:?} rel err {e}");
            }
        }
    }

    #[test]
    fn unbiased_modes_beat_traditional_at_small_k_narrow_range() {
        // The §VII narrow-range scenario: entries in [0, 0.5), k = 2.
        let (a, b) = random_pair(24, 24, 24, 0.0, 0.5, 3);
        let c = a.matmul(&b);
        let err = |mode: SchemeId| {
            let mut tot = 0.0;
            for t in 0..5u64 {
                let cfg = QuantMatmulConfig::unit(2, mode, Variant::PerPartial, 100 + t);
                tot += frobenius_error(&c, &quant_matmul(&a, &b, &cfg));
            }
            tot / 5.0
        };
        let det = err(SchemeId::Deterministic);
        let dit = err(SchemeId::Dither);
        let sto = err(SchemeId::Stochastic);
        assert!(dit < det, "dither {dit} < deterministic {det}");
        assert!(sto < det, "stochastic {sto} < deterministic {det}");
        assert!(dit <= sto * 1.1, "dither {dit} ≲ stochastic {sto}");
    }

    #[test]
    fn k1_traditional_loses_everything_below_half() {
        // Footnote 3: at k=1 with entries in [0, 0.5), traditional rounding
        // zeroes both matrices, e_f = ‖AB‖_F.
        let (a, b) = random_pair(10, 10, 10, 0.0, 0.4999, 5);
        let c = a.matmul(&b);
        let cfg = QuantMatmulConfig::unit(1, SchemeId::Deterministic, Variant::Separate, 7);
        let c_hat = quant_matmul(&a, &b, &cfg);
        assert_eq!(c_hat.frobenius_norm(), 0.0);
        assert!((frobenius_error(&c, &c_hat) - c.frobenius_norm()).abs() < 1e-12);
    }

    #[test]
    fn dither_per_partial_is_unbiased() {
        // E(Ĉ) = C: average Ĉ over trials, error should shrink.
        let (a, b) = random_pair(6, 6, 6, 0.0, 1.0, 9);
        let c = a.matmul(&b);
        let trials = 60;
        let mut mean = Matrix::zeros(6, 6);
        for t in 0..trials {
            let cfg = QuantMatmulConfig::unit(2, SchemeId::Dither, Variant::PerPartial, t);
            let c_hat = quant_matmul(&a, &b, &cfg);
            for (m, v) in mean.data_mut().iter_mut().zip(c_hat.data()) {
                *m += v / trials as f64;
            }
        }
        let single_cfg = QuantMatmulConfig::unit(2, SchemeId::Dither, Variant::PerPartial, 0);
        let single = frobenius_error(&c, &quant_matmul(&a, &b, &single_cfg));
        let averaged = frobenius_error(&c, &mean);
        assert!(
            averaged < single / 2.0,
            "trial-mean error {averaged} should be well below single-trial {single}"
        );
    }

    #[test]
    fn per_partial_comparable_to_separate_for_dither() {
        // Per-partial does 2pqr roundings vs (p+r)q for separate; with the
        // contraction-axis-stratified separate quantizer both land close —
        // per-partial must stay within a small factor (and both far below
        // the deterministic mode's error at this k; see the narrow-range
        // test above for that ordering).
        let (a, b) = random_pair(32, 32, 32, 0.0, 1.0, 11);
        let c = a.matmul(&b);
        let err = |variant: Variant| {
            let mut tot = 0.0;
            for t in 0..8u64 {
                let cfg = QuantMatmulConfig::unit(3, SchemeId::Dither, variant, 200 + t);
                tot += frobenius_error(&c, &quant_matmul(&a, &b, &cfg));
            }
            tot / 8.0
        };
        let pp = err(Variant::PerPartial);
        let sep = err(Variant::Separate);
        assert!(
            pp < sep * 1.5,
            "per-partial {pp} should be comparable to separate {sep}"
        );
    }

    #[test]
    fn signed_ranges_work() {
        let mut rng = Xoshiro256pp::new(13);
        let a = Matrix::random_uniform(10, 10, 0.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(10, 10, -1.0, 1.0, &mut rng);
        let c = a.matmul(&b);
        let cfg = QuantMatmulConfig {
            bits: 8,
            mode: SchemeId::Dither,
            variant: Variant::PerPartial,
            seed: 17,
            range_a: (0.0, 1.0),
            range_b: (-1.0, 1.0),
            n_a: None,
            n_b: None,
        };
        let c_hat = quant_matmul(&a, &b, &cfg);
        let rel = frobenius_error(&c, &c_hat) / c.frobenius_norm();
        assert!(rel < 0.02, "rel err {rel}");
    }

    #[test]
    fn quantize_once_deterministic_matches_quantizer() {
        let mut rng = Xoshiro256pp::new(15);
        let m = Matrix::random_uniform(7, 5, 0.0, 1.0, &mut rng);
        let q = Quantizer::unit(3);
        let out = quantize_matrix_once(&m, &q, SchemeId::Deterministic, 8, 0, SweepAxis::Cols);
        for i in 0..7 {
            for j in 0..5 {
                let expect = q.dequant(q.quantize_round(m.get(i, j)));
                assert!((out.get(i, j) - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reproducible_per_seed() {
        let (a, b) = random_pair(5, 5, 5, 0.0, 1.0, 21);
        let cfg = QuantMatmulConfig::unit(2, SchemeId::Dither, Variant::PerPartial, 77);
        assert_eq!(quant_matmul(&a, &b, &cfg), quant_matmul(&a, &b, &cfg));
    }

    #[test]
    fn zero_period_is_clamped_in_the_plan() {
        // The n ≥ 1 clamp lives in QuantPlan::plan_operand alone; callers
        // passing n = 0 (or defaulting from a zero dimension) must not be
        // able to build tables for an empty period.
        let mut rng = Xoshiro256pp::new(23);
        let m = Matrix::random_uniform(4, 3, 0.0, 1.0, &mut rng);
        let q = Quantizer::unit(4);
        let plan = QuantPlan::plan_operand(&m, &q, SchemeId::Dither, 0, SweepAxis::Cols);
        assert_eq!(plan.n(), 1);
        let out = quantize_matrix_once(&m, &q, SchemeId::Dither, 0, 3, SweepAxis::Cols);
        assert_eq!((out.rows, out.cols), (4, 3));
        // And through the matmul config path with explicit zero periods.
        let (a, b) = random_pair(3, 3, 3, 0.0, 1.0, 24);
        let cfg = QuantMatmulConfig {
            bits: 6,
            mode: SchemeId::Dither,
            variant: Variant::PerPartial,
            seed: 5,
            range_a: (0.0, 1.0),
            range_b: (0.0, 1.0),
            n_a: Some(0),
            n_b: Some(0),
        };
        let c_hat = quant_matmul(&a, &b, &cfg);
        assert!(c_hat.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn planned_operands_match_raw_operands_bitwise() {
        // A prepared plan with the same geometry as the per-call default
        // must reproduce the raw path exactly, for every scheme and
        // placement (the plan only hoists seed-independent state).
        let (a, b) = random_pair(9, 7, 5, 0.0, 1.0, 31);
        for mode in SchemeId::ALL {
            for variant in Variant::ALL {
                let cfg = QuantMatmulConfig::unit(3, mode, variant, 404);
                let direct = quant_matmul(&a, &b, &cfg);
                let quant = Quantizer::unit(3);
                let plan_a = QuantPlan::plan_operand(&a, &quant, mode, 5, SweepAxis::Cols);
                let plan_b = QuantPlan::plan_operand(&b, &quant, mode, 9, SweepAxis::Rows);
                let planned = execute(Operand::Plan(&plan_a), Operand::Plan(&plan_b), &cfg);
                assert_eq!(direct, planned, "{mode:?}/{variant:?}");
            }
        }
    }

    #[test]
    fn variants_bit_identical_across_kernels() {
        // Lane width only changes how many independent per-cell chains run
        // concurrently; every (scheme, placement) must produce the same
        // bits under every kernel. r = 13 leaves ragged tails for both the
        // 4-wide and 8-wide blockings.
        use crate::kernels::{self, KernelId};
        let (a, b) = random_pair(9, 7, 13, 0.0, 1.0, 41);
        for mode in SchemeId::ALL {
            for variant in Variant::ALL {
                let cfg = QuantMatmulConfig::unit(3, mode, variant, 7);
                kernels::select(KernelId::Scalar);
                let scalar = quant_matmul(&a, &b, &cfg);
                kernels::select(KernelId::Wide);
                let wide = quant_matmul(&a, &b, &cfg);
                kernels::select(kernels::auto_detect());
                assert_eq!(scalar, wide, "{mode:?}/{variant:?}");
            }
        }
    }

    #[test]
    fn frozen_plan_matches_per_call_quantization() {
        let mut rng = Xoshiro256pp::new(37);
        let b = Matrix::random_uniform(6, 4, -1.0, 1.0, &mut rng);
        let quant = Quantizer::new(5, -1.0, 1.0);
        for mode in SchemeId::ALL {
            let plan = QuantPlan::plan_operand(&b, &quant, mode, 6, SweepAxis::Rows);
            let frozen = QuantPlan::plan_frozen(&b, &quant, mode, 6, SweepAxis::Rows, 88);
            assert!(frozen.is_frozen() && !plan.is_frozen());
            // The frozen matrix is exactly the per-call quantization under
            // the freeze seed; other seeds leave it untouched.
            assert_eq!(
                plan.quantize_once(88).as_ref(),
                frozen.quantize_once(88).as_ref(),
                "{mode:?}"
            );
            assert_eq!(
                frozen.quantize_once(88).as_ref(),
                frozen.quantize_once(1234).as_ref(),
                "{mode:?}"
            );
        }
    }
}
