//! Dense linear algebra and the quantized (reduced-precision) matmul
//! engines of §VII–§VIII.

pub mod matmul;
pub mod matrix;

pub use matmul::{
    execute, quant_matmul, quantize_matrix_once, Operand, QuantMatmulConfig, QuantPlan, SweepAxis,
    Variant,
};
pub use matrix::{frobenius_error, Matrix};
