//! Dense row-major f64 matrices — the linear-algebra substrate under the
//! quantized-matmul engines and the NN layers.

use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_chunks;

/// Dense row-major matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// From a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// From an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(
        rows: usize,
        cols: usize,
        lo: f64,
        hi: f64,
        rng: &mut Xoshiro256pp,
    ) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform(lo, hi)).collect();
        Self { rows, cols, data }
    }

    /// Element access.
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    /// Element assignment.
    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Row as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Raw data (row-major).
    #[inline]
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw data (row-major).
    #[inline]
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Exact (f64) matrix product, parallel over row blocks with a
    /// transposed-B inner kernel for contiguous access.
    ///
    /// The per-row microkernel is the active [`crate::kernels::Kernels`]
    /// variant's `matmul_row`: cache-blocked a lane width of output columns
    /// at a time (4 scalar, 8 wide) — the Bᵀ rows stream through cache
    /// together while the A row stays resident, and each column owns an
    /// independent accumulator chain so the multiplies pipeline across
    /// lanes instead of serializing on one dependency chain. Per-cell
    /// accumulation order is the plain `j` order in every variant (results
    /// are bit-identical to the naive triple loop, and across kernels).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "inner dimensions must match");
        let (p, q, r) = (self.rows, self.cols, other.cols);
        let bt = other.transpose();
        let mut out = Matrix::zeros(p, r);
        let kern = crate::kernels::active();
        // Compute disjoint row blocks in parallel, then stitch.
        let blocks = parallel_chunks(p, |range| {
            let mut block = vec![0.0f64; range.len() * r];
            for (bi, i) in range.clone().enumerate() {
                let arow = &self.data[i * q..(i + 1) * q];
                kern.matmul_row(arow, &bt.data, &mut block[bi * r..(bi + 1) * r]);
            }
            (range.start, block)
        });
        for (start, block) in blocks {
            let rows_in_block = block.len() / r;
            out.data[start * r..(start + rows_in_block) * r].copy_from_slice(&block);
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Elementwise difference.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        }
    }

    /// Frobenius norm ‖M‖_F (the paper's e_f metric base).
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0, |m, x| m.max(x.abs()))
    }
}

/// Frobenius-norm error `e_f = ‖C − Ĉ‖_F` (§VII).
pub fn frobenius_error(c: &Matrix, c_hat: &Matrix) -> f64 {
    c.sub(c_hat).frobenius_norm()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f64);
        let eye = Matrix::from_fn(3, 3, |i, j| f64::from(i == j));
        assert_eq!(a.matmul(&eye), a);
        assert_eq!(eye.matmul(&a), a);
    }

    #[test]
    fn matmul_known_product() {
        let a = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Matrix::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_rectangular_matches_naive() {
        let mut rng = Xoshiro256pp::new(3);
        let a = Matrix::random_uniform(17, 9, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(9, 23, -1.0, 1.0, &mut rng);
        let c = a.matmul(&b);
        for i in 0..17 {
            for k in 0..23 {
                let naive: f64 = (0..9).map(|j| a.get(i, j) * b.get(j, k)).sum();
                assert!((c.get(i, k) - naive).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256pp::new(4);
        let a = Matrix::random_uniform(5, 8, 0.0, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(3, 2), a.get(2, 3));
    }

    #[test]
    fn frobenius_norm_known() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]);
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-12);
        assert_eq!(frobenius_error(&m, &m), 0.0);
    }

    #[test]
    fn row_access() {
        let m = Matrix::from_fn(4, 3, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.row(2), &[20.0, 21.0, 22.0]);
    }

    #[test]
    #[should_panic(expected = "inner dimensions")]
    fn matmul_dimension_mismatch() {
        let _ = Matrix::zeros(2, 3).matmul(&Matrix::zeros(4, 2));
    }
}
