//! Stochastic rounding (§II-C, §VII): `⌊α⌋ + Bernoulli(α − ⌊α⌋)`.
//!
//! Unbiased (`E = α`) but with per-application variance `p(1−p)`; the mean
//! of `N` independent applications converges at `Θ(1/√N)` — the rate dither
//! rounding improves to `Θ(1/N)`.

use crate::util::rng::{counter_hash, u64_to_unit_f64};

/// Stateful scalar stochastic rounder (counter-seeded, reproducible).
#[derive(Clone, Debug)]
pub struct StochasticRounder {
    seed: u64,
    i_s: u64,
}

impl StochasticRounder {
    /// New rounder with the given seed.
    pub fn new(seed: u64) -> Self {
        Self { seed, i_s: 0 }
    }

    /// Number of roundings performed so far.
    pub fn count(&self) -> u64 {
        self.i_s
    }

    /// Round a (possibly negative) real to an integer level.
    #[inline]
    pub fn round(&mut self, v: f64) -> i64 {
        let fl = v.floor();
        let frac = v - fl;
        let u = u64_to_unit_f64(counter_hash(self.seed, self.i_s));
        self.i_s += 1;
        fl as i64 + i64::from(u < frac)
    }
}

/// Stateless stochastic-rounding bit: `1` with probability `frac`, driven by
/// an external uniform u64 (shared form with the matmul engines and the
/// Pallas kernel).
#[inline]
pub fn stochastic_bit(frac: f64, u: u64) -> bool {
    u64_to_unit_f64(u) < frac
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn unbiased_mean() {
        for &alpha in &[0.25, 1.7, 3.01, -0.6] {
            let mut r = StochasticRounder::new(11);
            let trials = 40_000;
            let mut w = Welford::new();
            for _ in 0..trials {
                w.push(r.round(alpha) as f64);
            }
            assert!((w.mean() - alpha).abs() < 8e-3, "alpha={alpha} mean={}", w.mean());
        }
    }

    #[test]
    fn outputs_are_adjacent_integers() {
        let mut r = StochasticRounder::new(1);
        for i in 0..1000 {
            let v = i as f64 * 0.0731 - 3.0;
            let out = r.round(v);
            assert!(out == v.floor() as i64 || out == v.ceil() as i64);
        }
    }

    #[test]
    fn variance_matches_bernoulli() {
        let alpha = 0.3;
        let mut r = StochasticRounder::new(5);
        let mut w = Welford::new();
        for _ in 0..50_000 {
            w.push(r.round(alpha) as f64);
        }
        let expected = alpha * (1.0 - alpha);
        assert!(
            (w.variance() - expected).abs() < 0.05 * expected,
            "var={} expected={expected}",
            w.variance()
        );
    }

    #[test]
    fn reproducible_given_seed() {
        let mut a = StochasticRounder::new(77);
        let mut b = StochasticRounder::new(77);
        for i in 0..100 {
            let v = i as f64 * 0.317;
            assert_eq!(a.round(v), b.round(v));
        }
    }

    #[test]
    fn integer_inputs_exact() {
        let mut r = StochasticRounder::new(2);
        for v in [-2.0, 0.0, 7.0] {
            assert_eq!(r.round(v), v as i64);
        }
    }
}
