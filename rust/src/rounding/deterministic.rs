//! Traditional round-to-nearest (§II-C's "deterministic rounding").
//!
//! `round(x) = ⌊x + 0.5⌋` — the paper's definition. Provably the minimal-
//! EMSE rounding (§II-C) but biased: `E(round(α)) ≠ α` for non-half-integer
//! fractional parts, which is what Figs 9–16 show hurting quantized
//! inference at small k.

/// Stateless round-to-nearest rounder.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeterministicRounder;

impl DeterministicRounder {
    /// Round a real to the nearest integer (half-up, per the paper).
    #[inline]
    pub fn round(&mut self, v: f64) -> i64 {
        (v + 0.5).floor() as i64
    }
}

/// Stateless deterministic-rounding bit: `1` iff `frac ≥ ½` (shared form
/// with the matmul engines).
#[inline]
pub fn deterministic_bit(frac: f64) -> bool {
    frac >= 0.5
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn half_up_rule() {
        let mut r = DeterministicRounder;
        assert_eq!(r.round(0.5), 1);
        assert_eq!(r.round(1.5), 2);
        assert_eq!(r.round(2.49), 2);
        assert_eq!(r.round(-0.5), 0); // ⌊-0.5+0.5⌋ = 0
        assert_eq!(r.round(-0.51), -1);
    }

    #[test]
    fn integers_fixed() {
        let mut r = DeterministicRounder;
        for v in [-3i64, 0, 7, 100] {
            assert_eq!(r.round(v as f64), v);
        }
    }

    #[test]
    fn bit_threshold() {
        assert!(!deterministic_bit(0.49));
        assert!(deterministic_bit(0.5));
        assert!(deterministic_bit(0.99));
        assert!(!deterministic_bit(0.0));
    }
}
