//! The redesigned scheme surface: [`SchemeId`], the [`Rounding`] trait and
//! the process-wide [`SchemeRegistry`].
//!
//! Earlier revisions threaded a closed three-way `RoundingMode` enum through
//! every layer; this module opens that surface so the stochastic-rounding
//! *literature* can be served next to the paper's schemes. The split of
//! responsibilities:
//!
//! * [`SchemeId`] stays a small `Copy` value — it is what plan keys, batch
//!   keys, wire messages and fidelity cells store, so the hot paths keep
//!   enum-cheap hashing and matching.
//! * [`Rounding`] carries the per-scheme *behaviour and metadata*: the
//!   stateless rounded-bit function, vectorized row rounding, determinism
//!   and weight-freezing flags, the controller's MSE prior shape, and the
//!   source citation surfaced in docs.
//! * [`SchemeRegistry`] resolves stable wire names to `&'static dyn
//!   Rounding` instances and enumerates the zoo for the protocol v2 hello.
//!
//! The serving kernels (`linalg::matmul`) still dispatch on [`SchemeId`]
//! directly — the registry is the control-plane surface, not an extra
//! virtual call inside the contraction loop.

use crate::bitstream::dither::DitherParams;
use crate::rounding::deterministic::deterministic_bit;
use crate::rounding::dither::dither_bit;
use crate::rounding::stochastic::stochastic_bit;
use crate::rounding::zoo::{gauss_bit, sr2_bit, srvb_bit, tpdf_bit};
use crate::util::rng::counter_hash;
use std::fmt;
use std::str::FromStr;

/// Stable identifier of a registered rounding scheme.
///
/// The first three variants are the paper's comparison
/// ([`SchemeId::PAPER`]); the rest is the literature zoo served behind the
/// same API. Wire names (and therefore [`FromStr`]/[`fmt::Display`]) are
/// part of the serving protocol and must stay stable.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SchemeId {
    /// Traditional round-to-nearest (biased, minimal per-application EMSE).
    Deterministic,
    /// Stochastic rounding: `⌊α⌋ + Bernoulli(frac)` (unbiased, `Θ(1/√N)`).
    Stochastic,
    /// Dither rounding (§VII): indexed dither-computing representation
    /// (unbiased, `Θ(1/N)`).
    Dither,
    /// Two-candidate improved stochastic rounding (Xia et al. 2020): the
    /// rounded-up probability is sharpened toward the nearer candidate,
    /// trading a small bias for lower per-application variance.
    Sr2,
    /// Variance-bounded stochastic rounding (El Arar et al. 2022): plain SR
    /// while `frac·(1−frac)` is small, blended toward round-to-nearest once
    /// the Bernoulli variance would exceed the bound.
    SrVb,
    /// TPDF (triangular) dithered rounding: the round-half-up threshold is
    /// jittered by triangular noise, confined to one quantizer step.
    Tpdf,
    /// Gaussian dithered rounding: the threshold is jittered by an
    /// Irwin–Hall(4) approximate Gaussian, confined to one quantizer step.
    Gauss,
}

impl SchemeId {
    /// Every registered scheme, in fidelity-slot order.
    pub const ALL: [SchemeId; SchemeId::COUNT] = [
        SchemeId::Deterministic,
        SchemeId::Stochastic,
        SchemeId::Dither,
        SchemeId::Sr2,
        SchemeId::SrVb,
        SchemeId::Tpdf,
        SchemeId::Gauss,
    ];

    /// The paper's three-way comparison, in its figure-legend order. Grids
    /// that reproduce the paper (prewarm, ablations, figures) iterate this
    /// subset; zoo-aware surfaces iterate [`SchemeId::ALL`].
    pub const PAPER: [SchemeId; 3] = [
        SchemeId::Deterministic,
        SchemeId::Dither,
        SchemeId::Stochastic,
    ];

    /// Number of registered schemes.
    pub const COUNT: usize = 7;

    /// Stable dense index for flat per-scheme tables (fidelity cells,
    /// metrics windows). The first three slots predate the zoo and must
    /// not move.
    pub fn slot(self) -> usize {
        match self {
            SchemeId::Deterministic => 0,
            SchemeId::Stochastic => 1,
            SchemeId::Dither => 2,
            SchemeId::Sr2 => 3,
            SchemeId::SrVb => 4,
            SchemeId::Tpdf => 5,
            SchemeId::Gauss => 6,
        }
    }

    /// Stable wire name used in the serving protocol, stats JSON and CLI.
    pub fn wire_name(self) -> &'static str {
        match self {
            SchemeId::Deterministic => "deterministic",
            SchemeId::Stochastic => "stochastic",
            SchemeId::Dither => "dither",
            SchemeId::Sr2 => "sr2",
            SchemeId::SrVb => "srvb",
            SchemeId::Tpdf => "tpdf",
            SchemeId::Gauss => "gauss",
        }
    }

    /// True when the scheme uses no randomness at all.
    pub fn is_deterministic(self) -> bool {
        self == SchemeId::Deterministic
    }

    /// True when a `Separate`-variant weight plan may be frozen at prepare
    /// time (the scheme's weight draw is either deterministic or reproduced
    /// from the prepare-time seed; see `nn/prepared.rs`). The stochastic
    /// family keeps weight draws fresh per request.
    pub fn frozen_weights(self) -> bool {
        matches!(self, SchemeId::Deterministic | SchemeId::Dither)
    }
}

impl fmt::Display for SchemeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.wire_name())
    }
}

/// Error from parsing an unknown scheme spelling.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ParseSchemeError {
    /// The spelling that failed to parse.
    pub input: String,
}

impl fmt::Display for ParseSchemeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "unknown rounding scheme `{}`", self.input)
    }
}

impl std::error::Error for ParseSchemeError {}

impl FromStr for SchemeId {
    type Err = ParseSchemeError;

    /// Parse a wire name; the legacy CLI spellings `det`, `traditional`
    /// and `sr` remain accepted aliases.
    fn from_str(s: &str) -> Result<SchemeId, ParseSchemeError> {
        match s {
            "deterministic" | "det" | "traditional" => Ok(SchemeId::Deterministic),
            "stochastic" | "sr" => Ok(SchemeId::Stochastic),
            "dither" => Ok(SchemeId::Dither),
            "sr2" => Ok(SchemeId::Sr2),
            "srvb" => Ok(SchemeId::SrVb),
            "tpdf" => Ok(SchemeId::Tpdf),
            "gauss" => Ok(SchemeId::Gauss),
            _ => Err(ParseSchemeError {
                input: s.to_string(),
            }),
        }
    }
}

/// Behaviour and metadata of one registered rounding scheme.
///
/// Implementations are stateless unit structs; per-call randomness comes in
/// through the `u` word (counter-hashed from a seed by the caller), so the
/// same `(frac, u)` always yields the same bit — the discipline that keeps
/// every serving path reproducible.
pub trait Rounding: Send + Sync {
    /// The scheme's stable identifier.
    fn id(&self) -> SchemeId;

    /// Stable wire name (delegates to [`SchemeId::wire_name`]).
    fn wire_name(&self) -> &'static str {
        self.id().wire_name()
    }

    /// True when the scheme uses no randomness.
    fn is_deterministic(&self) -> bool {
        self.id().is_deterministic()
    }

    /// True when `Separate` weight plans may be frozen at prepare time.
    fn frozen_weights(&self) -> bool {
        self.id().frozen_weights()
    }

    /// The rounded bit for fractional part `frac ∈ [0, 1)` given one
    /// uniform random word `u`. Every scheme is confined to one quantizer
    /// step: the rounded value is `⌊α⌋ + bit`.
    fn round_bit(&self, frac: f64, u: u64) -> bool;

    /// Round one real to an integer level (`⌊v⌋ + round_bit(frac, u)`).
    fn round_scalar(&self, v: f64, u: u64) -> i64 {
        let fl = v.floor();
        fl as i64 + i64::from(self.round_bit(v - fl, u))
    }

    /// Round a row of reals in place, drawing per-element randomness from
    /// `counter_hash(seed, j)` — the vectorized form used by control-plane
    /// consumers (the contraction engines keep their own fused loops).
    /// Routed through the active [`crate::kernels::Kernels`] variant, which
    /// batches the counter-hash computation; per-element results are
    /// identical across kernels because each bit is a pure function of
    /// `(value, seed, j)`.
    fn round_row(&self, row: &mut [f64], seed: u64) {
        crate::kernels::active().round_row(&mut |v, u| self.round_scalar(v, u) as f64, row, seed);
    }

    /// Prior per-logit MSE of an `n`-long contraction whose factors are
    /// rounded on quantizer step `step`, before any shadow measurements
    /// exist. Only has to *rank* candidates sanely — the online fidelity
    /// estimator replaces it once cells are warm.
    fn mse_prior(&self, step: f64, n: f64) -> f64;

    /// Citation for the scheme (paper section or literature reference).
    fn source(&self) -> &'static str;
}

/// Round-to-nearest ([`SchemeId::Deterministic`]).
pub struct DeterministicScheme;

impl Rounding for DeterministicScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Deterministic
    }
    fn round_bit(&self, frac: f64, _u: u64) -> bool {
        deterministic_bit(frac)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        n * step * step / 6.0
    }
    fn source(&self) -> &'static str {
        "paper §II-C (round-to-nearest)"
    }
}

/// Plain stochastic rounding ([`SchemeId::Stochastic`]).
pub struct StochasticScheme;

impl Rounding for StochasticScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Stochastic
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        stochastic_bit(frac, u)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        n * step / 6.0
    }
    fn source(&self) -> &'static str {
        "paper §II-C (stochastic rounding)"
    }
}

/// Dither rounding ([`SchemeId::Dither`]).
///
/// The registry entry draws one *marginal* bit of the §II-D representation
/// (random slot from the high bits of `u`, stochastic residue re-hashed
/// from `u`); the serving kernels keep the exact indexed-permutation form,
/// which needs the application counter this stateless surface cannot carry.
pub struct DitherScheme;

/// Representation length used by the stateless marginal dither bit.
const DITHER_MARGINAL_N: usize = 16;

impl Rounding for DitherScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Dither
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        let params = DitherParams::of(frac, DITHER_MARGINAL_N);
        let pos = (u >> 56) as usize % DITHER_MARGINAL_N;
        dither_bit(&params, pos, counter_hash(u, 0xD17E))
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        n * step * step / 6.0
    }
    fn source(&self) -> &'static str {
        "paper §VII (dither rounding)"
    }
}

/// Two-candidate improved stochastic rounding ([`SchemeId::Sr2`]).
pub struct Sr2Scheme;

impl Rounding for Sr2Scheme {
    fn id(&self) -> SchemeId {
        SchemeId::Sr2
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        sr2_bit(frac, u)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        // Sharpening the Bernoulli cuts variance but leaves an O(step)
        // per-element bias, so the contraction error scales as step².
        n * step * step / 3.0
    }
    fn source(&self) -> &'static str {
        "Xia et al. 2020 (improved two-candidate SR)"
    }
}

/// Variance-bounded stochastic rounding ([`SchemeId::SrVb`]).
pub struct SrVbScheme;

impl Rounding for SrVbScheme {
    fn id(&self) -> SchemeId {
        SchemeId::SrVb
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        srvb_bit(frac, u)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        // SR shape with the worst-case Bernoulli variance halved by the
        // bound — still Ω(step), cheaper constant.
        n * step / 12.0
    }
    fn source(&self) -> &'static str {
        "El Arar et al. 2022 (variance-bounded SR)"
    }
}

/// TPDF (triangular) dithered rounding ([`SchemeId::Tpdf`]).
pub struct TpdfScheme;

impl Rounding for TpdfScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Tpdf
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        tpdf_bit(frac, u)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        n * step * step / 4.0
    }
    fn source(&self) -> &'static str {
        "classical TPDF dither, one-step confined"
    }
}

/// Gaussian dithered rounding ([`SchemeId::Gauss`]).
pub struct GaussScheme;

impl Rounding for GaussScheme {
    fn id(&self) -> SchemeId {
        SchemeId::Gauss
    }
    fn round_bit(&self, frac: f64, u: u64) -> bool {
        gauss_bit(frac, u)
    }
    fn mse_prior(&self, step: f64, n: f64) -> f64 {
        n * step * step / 2.0
    }
    fn source(&self) -> &'static str {
        "Gaussian (Irwin–Hall) dither, one-step confined"
    }
}

/// The process-wide table of registered schemes, indexed by
/// [`SchemeId::slot`] and resolvable by wire name.
pub struct SchemeRegistry {
    entries: [&'static dyn Rounding; SchemeId::COUNT],
}

static REGISTRY: SchemeRegistry = SchemeRegistry {
    entries: [
        &DeterministicScheme,
        &StochasticScheme,
        &DitherScheme,
        &Sr2Scheme,
        &SrVbScheme,
        &TpdfScheme,
        &GaussScheme,
    ],
};

impl SchemeRegistry {
    /// The global registry over [`SchemeId::ALL`].
    pub fn global() -> &'static SchemeRegistry {
        &REGISTRY
    }

    /// The scheme instance for an id.
    pub fn get(&self, id: SchemeId) -> &'static dyn Rounding {
        self.entries[id.slot()]
    }

    /// Resolve a wire name (or legacy alias) to a scheme instance.
    pub fn resolve(&self, wire: &str) -> Option<&'static dyn Rounding> {
        wire.parse::<SchemeId>().ok().map(|id| self.get(id))
    }

    /// Iterate every registered scheme in slot order.
    pub fn iter(&self) -> impl Iterator<Item = &'static dyn Rounding> + '_ {
        self.entries.iter().copied()
    }

    /// Canonical wire names of every registered scheme, in slot order —
    /// the list the protocol v2 hello advertises.
    pub fn wire_names(&self) -> Vec<&'static str> {
        self.entries.iter().map(|s| s.wire_name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn wire_names_round_trip_through_fromstr_and_display() {
        for id in SchemeId::ALL {
            let wire = id.to_string();
            assert_eq!(wire, id.wire_name());
            assert_eq!(wire.parse::<SchemeId>(), Ok(id), "{wire}");
        }
        assert!("fuzzy".parse::<SchemeId>().is_err());
        assert!("".parse::<SchemeId>().is_err());
        let err = "fuzzy".parse::<SchemeId>().unwrap_err();
        assert_eq!(err.input, "fuzzy");
        assert!(err.to_string().contains("fuzzy"));
    }

    #[test]
    fn legacy_aliases_still_parse() {
        assert_eq!("traditional".parse(), Ok(SchemeId::Deterministic));
        assert_eq!("det".parse(), Ok(SchemeId::Deterministic));
        assert_eq!("sr".parse(), Ok(SchemeId::Stochastic));
    }

    #[test]
    fn slots_are_dense_and_stable() {
        let mut seen = [false; SchemeId::COUNT];
        for id in SchemeId::ALL {
            assert!(!seen[id.slot()], "{id} slot collides");
            seen[id.slot()] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // The pre-zoo slots are frozen (fidelity tables depend on them).
        assert_eq!(SchemeId::Deterministic.slot(), 0);
        assert_eq!(SchemeId::Stochastic.slot(), 1);
        assert_eq!(SchemeId::Dither.slot(), 2);
    }

    #[test]
    fn registry_resolves_every_wire_name_and_rejects_unknown() {
        let reg = SchemeRegistry::global();
        for id in SchemeId::ALL {
            let s = reg.resolve(id.wire_name()).expect("registered");
            assert_eq!(s.id(), id);
            assert_eq!(reg.get(id).id(), id);
        }
        assert!(reg.resolve("float128").is_none());
        assert_eq!(reg.wire_names().len(), SchemeId::COUNT);
        assert_eq!(reg.iter().count(), SchemeId::COUNT);
    }

    #[test]
    fn metadata_flags_match_the_id_table() {
        let reg = SchemeRegistry::global();
        for s in reg.iter() {
            assert_eq!(s.is_deterministic(), s.id() == SchemeId::Deterministic);
            assert_eq!(
                s.frozen_weights(),
                matches!(s.id(), SchemeId::Deterministic | SchemeId::Dither)
            );
            assert!(!s.source().is_empty());
        }
    }

    #[test]
    fn every_scheme_rounds_to_an_adjacent_integer() {
        let reg = SchemeRegistry::global();
        for s in reg.iter() {
            for i in 0..500u64 {
                let v = i as f64 * 0.173 - 40.0;
                let out = s.round_scalar(v, counter_hash(9, i));
                assert!(
                    out == v.floor() as i64 || out == v.ceil() as i64,
                    "{} v={v} out={out}",
                    s.wire_name()
                );
            }
            // Exact integers never move under any scheme.
            for v in [-3.0, 0.0, 7.0] {
                for i in 0..64u64 {
                    assert_eq!(s.round_scalar(v, counter_hash(3, i)), v as i64);
                }
            }
        }
    }

    #[test]
    fn round_row_matches_scalar_rounding() {
        let reg = SchemeRegistry::global();
        for s in reg.iter() {
            let mut row: Vec<f64> = (0..32).map(|j| j as f64 * 0.31 - 4.0).collect();
            let expect: Vec<f64> = row
                .iter()
                .enumerate()
                .map(|(j, &v)| s.round_scalar(v, counter_hash(5, j as u64)) as f64)
                .collect();
            s.round_row(&mut row, 5);
            assert_eq!(row, expect, "{}", s.wire_name());
        }
    }

    #[test]
    fn priors_are_positive_and_fall_with_finer_steps() {
        let reg = SchemeRegistry::global();
        for s in reg.iter() {
            let coarse = s.mse_prior(2.0 / 3.0, 784.0);
            let fine = s.mse_prior(2.0 / 15.0, 784.0);
            assert!(coarse > fine, "{}", s.wire_name());
            assert!(fine > 0.0, "{}", s.wire_name());
        }
    }

    #[test]
    fn scheme_bits_track_their_target_probability_at_the_midpoint() {
        // Every scheme's rounded bit must hit rate 1/2 at frac = 1/2 — the
        // common anchor of the whole zoo (biased schemes bend the curve
        // elsewhere, never at the midpoint).
        let reg = SchemeRegistry::global();
        for s in reg.iter() {
            if s.is_deterministic() {
                continue;
            }
            let mut w = Welford::new();
            for i in 0..40_000u64 {
                w.push(f64::from(u8::from(s.round_bit(0.5, counter_hash(31, i)))));
            }
            assert!(
                (w.mean() - 0.5).abs() < 0.02,
                "{} midpoint rate {}",
                s.wire_name(),
                w.mean()
            );
        }
    }
}
