//! Dither rounding (§VII): stochastic rounding revisited with the dither
//! computing representation driving the rounded bit.
//!
//! `d(α, i) = ⌊α⌋ + X_i` where `{X_i}` is the dither-computing
//! representation (§II-D) of the fractional part `α − ⌊α⌋`, and the index
//! `i = σ(i_s mod N)` advances with every rounding the rounder performs.
//! Over any window of `N` roundings of the same value the deterministic part
//! of the representation is reproduced *exactly*, so the time-averaged error
//! falls as `Θ(1/N)` instead of stochastic rounding's `Θ(1/√N)`.

use crate::bitstream::dither::DitherParams;
use crate::util::rng::{counter_hash, u64_to_unit_f64, Xoshiro256pp};

/// The dither-representation bit at (already permuted) position `pos`,
/// with `u` a fresh uniform u64 supplying the stochastic residue.
///
/// This is the stateless core shared by the scalar rounder, the matmul
/// engines and (structurally) the Pallas kernel.
#[inline]
pub fn dither_bit(params: &DitherParams, pos: usize, u: u64) -> bool {
    if params.lower_branch {
        // Deterministic 1s on the first n slots, Bernoulli(δ) elsewhere.
        pos < params.n || u64_to_unit_f64(u) < params.delta
    } else {
        // Bernoulli(1-δ) on the first n slots, deterministic 0 elsewhere.
        pos < params.n && u64_to_unit_f64(u) < 1.0 - params.delta
    }
}

/// Stateful scalar dither rounder: tracks the application counter `i_s` and
/// holds the fixed permutation σ (§VII: "we need to keep track of the index").
#[derive(Clone, Debug)]
pub struct DitherRounder {
    /// Sequence length `N` (one full period covers the deterministic part).
    pub n: usize,
    sigma: Vec<usize>,
    i_s: u64,
    rng: Xoshiro256pp,
    seed: u64,
}

impl DitherRounder {
    /// New rounder with period `n` and a seeded random permutation σ.
    pub fn new(n: usize, seed: u64) -> Self {
        assert!(n >= 1, "dither period must be >= 1");
        let mut rng = Xoshiro256pp::new(seed ^ 0xD17E);
        let mut sigma: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut sigma);
        Self {
            n,
            sigma,
            i_s: 0,
            rng,
            seed,
        }
    }

    /// New rounder with the identity permutation (useful in tests and in
    /// contexts that already randomize the traversal order).
    pub fn with_identity_sigma(n: usize, seed: u64) -> Self {
        let mut r = Self::new(n, seed);
        r.sigma = (0..n).collect();
        r
    }

    /// Number of roundings performed so far.
    pub fn count(&self) -> u64 {
        self.i_s
    }

    /// Round a (possibly negative) real to an integer level.
    pub fn round(&mut self, v: f64) -> i64 {
        let fl = v.floor();
        let frac = v - fl;
        let params = DitherParams::of(frac, self.n);
        let pos = self.sigma[(self.i_s % self.n as u64) as usize];
        // Fresh stochastic residue per application, reproducible from
        // (seed, i_s) — mirrors the Pallas kernel's counter PRNG.
        let u = counter_hash(self.seed, self.i_s);
        self.i_s += 1;
        let bit = dither_bit(&params, pos, u);
        fl as i64 + i64::from(bit)
    }

    /// Reset the application counter (start of a new period).
    pub fn reset(&mut self) {
        self.i_s = 0;
    }

    /// Re-randomize σ (e.g. between trials).
    pub fn reshuffle(&mut self) {
        let mut sigma: Vec<usize> = (0..self.n).collect();
        self.rng.shuffle(&mut sigma);
        self.sigma = sigma;
        self.i_s = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_average_is_nearly_exact() {
        // Rounding the same α for N consecutive applications reproduces the
        // deterministic part exactly: |mean - α| ≤ δ-residue scale ~ 2/N.
        for &alpha in &[3.14159, 0.731, 7.0, 0.08, 12.97] {
            let n = 64;
            let mut r = DitherRounder::new(n, 42);
            let sum: i64 = (0..n).map(|_| r.round(alpha)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - alpha).abs() <= 3.0 / n as f64 + 1e-9,
                "alpha={alpha} mean={mean}"
            );
        }
    }

    #[test]
    fn unbiased_over_many_windows() {
        let alpha = 2.3;
        let n = 32;
        let mut r = DitherRounder::new(n, 7);
        let trials = 20_000;
        let sum: i64 = (0..trials).map(|_| r.round(alpha)).sum();
        let mean = sum as f64 / trials as f64;
        assert!((mean - alpha).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn output_is_floor_or_ceil() {
        let mut r = DitherRounder::new(16, 3);
        for i in 0..1000 {
            let v = i as f64 * 0.137;
            let out = r.round(v);
            assert!(out == v.floor() as i64 || out == v.ceil() as i64, "v={v} out={out}");
        }
    }

    #[test]
    fn integers_round_exactly() {
        let mut r = DitherRounder::new(16, 5);
        for v in [0.0, 1.0, 5.0, 100.0, -3.0] {
            assert_eq!(r.round(v), v as i64);
        }
    }

    #[test]
    fn negative_values_supported() {
        // α < 0: floor/frac decomposition still yields an unbiased bit.
        let alpha = -1.75;
        let n = 32;
        let mut r = DitherRounder::new(n, 9);
        let trials = 20_000;
        let sum: i64 = (0..trials).map(|_| r.round(alpha)).sum();
        let mean = sum as f64 / trials as f64;
        assert!((mean - alpha).abs() < 5e-3, "mean={mean}");
        let out = r.round(alpha);
        assert!(out == -2 || out == -1);
    }

    #[test]
    fn variance_below_stochastic_rounding() {
        // Sum of N ditherings of α has much lower variance than N
        // independent stochastic roundings.
        let alpha = 0.37;
        let n = 64;
        let mut dither_sums = Vec::new();
        for t in 0..500 {
            let mut r = DitherRounder::new(n, 1000 + t);
            let s: i64 = (0..n).map(|_| r.round(alpha)).sum();
            dither_sums.push(s as f64 / n as f64);
        }
        let mut w = crate::util::stats::Welford::new();
        for &s in &dither_sums {
            w.push(s);
        }
        // Stochastic rounding variance of the mean: p(1-p)/N ≈ 0.0036.
        let stochastic_var = alpha * (1.0 - alpha) / n as f64;
        assert!(
            w.variance() < stochastic_var / 5.0,
            "dither window var {} vs stochastic {}",
            w.variance(),
            stochastic_var
        );
    }

    #[test]
    fn reset_and_reshuffle() {
        let mut r = DitherRounder::new(8, 1);
        let _ = r.round(0.5);
        assert_eq!(r.count(), 1);
        r.reset();
        assert_eq!(r.count(), 0);
        r.reshuffle();
        assert_eq!(r.count(), 0);
    }
}
