//! The k-bit fixed-point quantizer of §VII.
//!
//! `q(x) = round(x)` for `x ∈ [0, 2^k − 1]`, with underflow clamped to 0 and
//! overflow clamped to `2^k − 1`. Real inputs are affinely rescaled from
//! their source range into the quantizer's level range and (for error
//! measurement) dequantized back.

/// A k-bit quantizer over the level range `[0, 2^k − 1]` with an affine
/// mapping from a source interval `[lo, hi]`.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    /// Bit width `k ≥ 1`.
    pub bits: u32,
    /// Source-range lower bound.
    pub lo: f64,
    /// Source-range upper bound (must exceed `lo`).
    pub hi: f64,
}

impl Quantizer {
    /// Quantizer for values already in `[0, 1]` (the Fig 8 setting).
    pub fn unit(bits: u32) -> Self {
        Self::new(bits, 0.0, 1.0)
    }

    /// Quantizer with an explicit source range (e.g. `[-1, 1]` weights, §VII).
    pub fn new(bits: u32, lo: f64, hi: f64) -> Self {
        assert!(bits >= 1 && bits <= 32, "bit width must be in 1..=32");
        assert!(hi > lo, "source range must be non-degenerate");
        Self { bits, lo, hi }
    }

    /// Highest level: `2^k − 1`.
    #[inline]
    pub fn max_level(&self) -> u32 {
        if self.bits >= 32 {
            u32::MAX
        } else {
            (1u32 << self.bits) - 1
        }
    }

    /// Rescale a source value into level space `[0, 2^k − 1]` (unclamped,
    /// unrounded — the rounding schemes operate on this).
    #[inline]
    pub fn scale(&self, v: f64) -> f64 {
        (v - self.lo) / (self.hi - self.lo) * self.max_level() as f64
    }

    /// Clamp an integer-valued level into `[0, 2^k − 1]` (the paper's
    /// underflow/overflow rule).
    #[inline]
    pub fn clamp_level(&self, level: i64) -> u32 {
        level.clamp(0, self.max_level() as i64) as u32
    }

    /// Map a level back to source space.
    #[inline]
    pub fn dequant(&self, level: u32) -> f64 {
        self.lo + level as f64 / self.max_level() as f64 * (self.hi - self.lo)
    }

    /// Traditional (deterministic) quantization end-to-end:
    /// scale → round → clamp.
    #[inline]
    pub fn quantize_round(&self, v: f64) -> u32 {
        // round(x) = floor(x + 0.5), the paper's definition.
        self.clamp_level((self.scale(v) + 0.5).floor() as i64)
    }

    /// Quantization step in source units.
    #[inline]
    pub fn step(&self) -> f64 {
        (self.hi - self.lo) / self.max_level() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_range_k8() {
        let q = Quantizer::unit(8);
        assert_eq!(q.max_level(), 255);
        assert_eq!(q.quantize_round(0.0), 0);
        assert_eq!(q.quantize_round(1.0), 255);
        assert_eq!(q.quantize_round(0.5), 128); // 127.5 rounds half-up
        assert!((q.dequant(255) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn clamping_under_and_overflow() {
        let q = Quantizer::unit(4);
        assert_eq!(q.quantize_round(-0.3), 0);
        assert_eq!(q.quantize_round(1.7), 15);
        assert_eq!(q.clamp_level(-5), 0);
        assert_eq!(q.clamp_level(99), 15);
    }

    #[test]
    fn signed_range_weights() {
        let q = Quantizer::new(8, -1.0, 1.0);
        assert_eq!(q.quantize_round(-1.0), 0);
        assert_eq!(q.quantize_round(1.0), 255);
        let mid = q.quantize_round(0.0);
        assert!((127..=128).contains(&mid));
        // dequant(quantize(v)) within one step.
        for i in 0..100 {
            let v = -1.0 + 2.0 * i as f64 / 99.0;
            let err = (q.dequant(q.quantize_round(v)) - v).abs();
            assert!(err <= q.step() / 2.0 + 1e-12, "v={v} err={err}");
        }
    }

    #[test]
    fn k1_collapses_half_range_to_zero() {
        // §VII: with k=1 and inputs in [0, 1/2), traditional rounding sends
        // everything to level 0 (all information lost).
        let q = Quantizer::unit(1);
        assert_eq!(q.max_level(), 1);
        for i in 0..50 {
            let v = 0.4999 * i as f64 / 49.0;
            assert_eq!(q.quantize_round(v), 0, "v={v}");
        }
        assert_eq!(q.quantize_round(0.51), 1);
    }

    #[test]
    fn scale_dequant_inverse() {
        let q = Quantizer::new(6, 2.0, 10.0);
        for lvl in 0..=q.max_level() {
            let v = q.dequant(lvl);
            assert!((q.scale(v) - lvl as f64).abs() < 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "bit width")]
    fn zero_bits_rejected() {
        let _ = Quantizer::unit(0);
    }
}
