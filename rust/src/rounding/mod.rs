//! Rounding schemes for reduced-precision arithmetic (§II-C, §VII) and the
//! registry-backed scheme zoo.
//!
//! The paper's three-way comparison:
//!
//! * [`SchemeId::Deterministic`] — `round(α)`; lowest per-application
//!   EMSE (§II-C proves it minimal) but *biased*, which degrades iterated /
//!   correlated computations and wastes quantizer levels on narrow data.
//! * [`SchemeId::Stochastic`] — `⌊α⌋ + Bernoulli(frac)`; unbiased,
//!   `Θ(1/√N)` time-averaged error.
//! * [`SchemeId::Dither`] — the paper's scheme: the rounded bit follows
//!   the dither-computing representation of `frac`, indexed by an
//!   application counter; unbiased with `Θ(1/N)` time-averaged error.
//!
//! Beyond those, the [`zoo`] module serves the stochastic-rounding
//! literature (two-candidate improved SR, variance-bounded SR, TPDF and
//! Gaussian dither) behind the same API; [`scheme`] holds the open surface
//! — [`SchemeId`], the [`Rounding`] trait and the [`SchemeRegistry`] that
//! resolves wire names to scheme instances.
//!
//! [`ScalarRounder`] is the stateful uniform front-end; the stateless
//! `*_bit` functions are reused by the matmul engines and mirrored by the
//! Pallas kernel.

pub mod deterministic;
pub mod dither;
pub mod quantizer;
pub mod scheme;
pub mod stochastic;
pub mod zoo;

pub use deterministic::{deterministic_bit, DeterministicRounder};
pub use dither::{dither_bit, DitherRounder};
pub use quantizer::Quantizer;
pub use scheme::{ParseSchemeError, Rounding, SchemeId, SchemeRegistry};
pub use stochastic::{stochastic_bit, StochasticRounder};
pub use zoo::{gauss_bit, sr2_bit, srvb_bit, tpdf_bit};

use crate::util::rng::counter_hash;

/// Stateful scalar rounder for a registry (zoo) scheme: a counter-seeded
/// PRNG word per application, fed to the scheme's stateless bit function.
#[derive(Clone, Debug)]
pub struct ZooRounder {
    id: SchemeId,
    seed: u64,
    i_s: u64,
}

impl ZooRounder {
    /// New rounder for `id` with the given seed.
    pub fn new(id: SchemeId, seed: u64) -> Self {
        Self { id, seed, i_s: 0 }
    }

    /// Number of roundings performed so far.
    pub fn count(&self) -> u64 {
        self.i_s
    }

    /// Round a (possibly negative) real to an integer level.
    #[inline]
    pub fn round(&mut self, v: f64) -> i64 {
        let u = counter_hash(self.seed, self.i_s);
        self.i_s += 1;
        SchemeRegistry::global().get(self.id).round_scalar(v, u)
    }
}

/// Uniform stateful scalar rounder over every registered scheme.
#[derive(Clone, Debug)]
pub enum ScalarRounder {
    /// Round-to-nearest (stateless).
    Deterministic(DeterministicRounder),
    /// Stochastic rounding with a counter-seeded PRNG.
    Stochastic(StochasticRounder),
    /// Dither rounding with period `n` and permutation σ.
    Dither(DitherRounder),
    /// A literature-zoo scheme (counter-seeded stateless bit).
    Zoo(ZooRounder),
}

impl ScalarRounder {
    /// Build a rounder. `n` is the dither period (ignored by the others).
    pub fn new(scheme: SchemeId, n: usize, seed: u64) -> Self {
        match scheme {
            SchemeId::Deterministic => ScalarRounder::Deterministic(DeterministicRounder),
            SchemeId::Stochastic => ScalarRounder::Stochastic(StochasticRounder::new(seed)),
            SchemeId::Dither => ScalarRounder::Dither(DitherRounder::new(n, seed)),
            zoo => ScalarRounder::Zoo(ZooRounder::new(zoo, seed)),
        }
    }

    /// Round a real to an integer level under this scheme.
    #[inline]
    pub fn round(&mut self, v: f64) -> i64 {
        match self {
            ScalarRounder::Deterministic(r) => r.round(v),
            ScalarRounder::Stochastic(r) => r.round(v),
            ScalarRounder::Dither(r) => r.round(v),
            ScalarRounder::Zoo(r) => r.round(v),
        }
    }

    /// The scheme this rounder implements.
    pub fn mode(&self) -> SchemeId {
        match self {
            ScalarRounder::Deterministic(_) => SchemeId::Deterministic,
            ScalarRounder::Stochastic(_) => SchemeId::Stochastic,
            ScalarRounder::Dither(_) => SchemeId::Dither,
            ScalarRounder::Zoo(r) => r.id,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn scheme_parsing() {
        assert_eq!("traditional".parse(), Ok(SchemeId::Deterministic));
        assert_eq!("sr".parse(), Ok(SchemeId::Stochastic));
        assert_eq!("dither".parse(), Ok(SchemeId::Dither));
        assert_eq!("srvb".parse(), Ok(SchemeId::SrVb));
        assert!("x".parse::<SchemeId>().is_err());
        assert_eq!(SchemeId::Tpdf.to_string(), "tpdf");
    }

    #[test]
    fn all_rounders_hit_adjacent_integers() {
        for scheme in SchemeId::ALL {
            let mut r = ScalarRounder::new(scheme, 16, 3);
            for i in 0..200 {
                let v = i as f64 * 0.173 - 5.0;
                let out = r.round(v);
                assert!(
                    out == v.floor() as i64 || out == v.ceil() as i64,
                    "{scheme:?} v={v} out={out}"
                );
                assert_eq!(r.mode(), scheme);
            }
        }
    }

    #[test]
    fn unbiased_modes_vs_biased_mode() {
        // At α = 0.3 deterministic rounding is biased by -0.3; the paper's
        // unbiased schemes' means converge to α. (The zoo schemes trade
        // per-sample unbiasedness for variance and are covered by their own
        // statistical tests in `zoo` and `scheme`.)
        let alpha = 0.3;
        for scheme in SchemeId::PAPER {
            let mut r = ScalarRounder::new(scheme, 32, 5);
            let mut w = Welford::new();
            for _ in 0..20_000 {
                w.push(r.round(alpha) as f64);
            }
            match scheme {
                SchemeId::Deterministic => assert_eq!(w.mean(), 0.0),
                _ => assert!((w.mean() - alpha).abs() < 0.01, "{scheme:?} {}", w.mean()),
            }
        }
    }

    #[test]
    fn zoo_rounders_count_applications() {
        let mut r = ZooRounder::new(SchemeId::Sr2, 4);
        assert_eq!(r.count(), 0);
        let _ = r.round(1.5);
        let _ = r.round(2.5);
        assert_eq!(r.count(), 2);
    }

    #[test]
    fn dither_time_average_converges_fastest() {
        // Error of the running mean after exactly one period N.
        let alpha = 0.45;
        let n = 64;
        let mut dither = ScalarRounder::new(SchemeId::Dither, n, 9);
        let dither_mean: f64 =
            (0..n).map(|_| dither.round(alpha) as f64).sum::<f64>() / n as f64;
        // Repeat stochastic over many windows to estimate its typical error.
        let mut sto_errs = Welford::new();
        for t in 0..200 {
            let mut s = ScalarRounder::new(SchemeId::Stochastic, n, 100 + t);
            let m: f64 = (0..n).map(|_| s.round(alpha) as f64).sum::<f64>() / n as f64;
            sto_errs.push((m - alpha).abs());
        }
        assert!(
            (dither_mean - alpha).abs() < sto_errs.mean(),
            "dither window err {} vs stochastic mean err {}",
            (dither_mean - alpha).abs(),
            sto_errs.mean()
        );
    }
}
