//! Rounding schemes for reduced-precision arithmetic (§II-C, §VII).
//!
//! Three ways to map a real level `α` to an integer level:
//!
//! * [`RoundingMode::Deterministic`] — `round(α)`; lowest per-application
//!   EMSE (§II-C proves it minimal) but *biased*, which degrades iterated /
//!   correlated computations and wastes quantizer levels on narrow data.
//! * [`RoundingMode::Stochastic`] — `⌊α⌋ + Bernoulli(frac)`; unbiased,
//!   `Θ(1/√N)` time-averaged error.
//! * [`RoundingMode::Dither`] — the paper's scheme: the rounded bit follows
//!   the dither-computing representation of `frac`, indexed by an
//!   application counter; unbiased with `Θ(1/N)` time-averaged error.
//!
//! [`ScalarRounder`] is the stateful uniform front-end; the stateless
//! `*_bit` functions are reused by the matmul engines and mirrored by the
//! Pallas kernel.

pub mod deterministic;
pub mod dither;
pub mod quantizer;
pub mod stochastic;

pub use deterministic::{deterministic_bit, DeterministicRounder};
pub use dither::{dither_bit, DitherRounder};
pub use quantizer::Quantizer;
pub use stochastic::{stochastic_bit, StochasticRounder};

/// Which rounding scheme to apply.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoundingMode {
    /// Traditional round-to-nearest.
    Deterministic,
    /// Stochastic rounding.
    Stochastic,
    /// Dither rounding (§VII).
    Dither,
}

impl RoundingMode {
    /// All modes in the paper's comparison order.
    pub const ALL: [RoundingMode; 3] = [
        RoundingMode::Deterministic,
        RoundingMode::Dither,
        RoundingMode::Stochastic,
    ];

    /// Display name matching the paper's figure legends.
    pub fn name(&self) -> &'static str {
        match self {
            RoundingMode::Deterministic => "deterministic",
            RoundingMode::Stochastic => "stochastic",
            RoundingMode::Dither => "dither",
        }
    }

    /// Parse from CLI spelling.
    pub fn from_str(s: &str) -> Option<RoundingMode> {
        match s {
            "deterministic" | "det" | "traditional" => Some(RoundingMode::Deterministic),
            "stochastic" | "sr" => Some(RoundingMode::Stochastic),
            "dither" => Some(RoundingMode::Dither),
            _ => None,
        }
    }
}

/// Uniform stateful scalar rounder over the three modes.
#[derive(Clone, Debug)]
pub enum ScalarRounder {
    /// Round-to-nearest (stateless).
    Deterministic(DeterministicRounder),
    /// Stochastic rounding with a counter-seeded PRNG.
    Stochastic(StochasticRounder),
    /// Dither rounding with period `n` and permutation σ.
    Dither(DitherRounder),
}

impl ScalarRounder {
    /// Build a rounder. `n` is the dither period (ignored by the others).
    pub fn new(mode: RoundingMode, n: usize, seed: u64) -> Self {
        match mode {
            RoundingMode::Deterministic => ScalarRounder::Deterministic(DeterministicRounder),
            RoundingMode::Stochastic => ScalarRounder::Stochastic(StochasticRounder::new(seed)),
            RoundingMode::Dither => ScalarRounder::Dither(DitherRounder::new(n, seed)),
        }
    }

    /// Round a real to an integer level under this scheme.
    #[inline]
    pub fn round(&mut self, v: f64) -> i64 {
        match self {
            ScalarRounder::Deterministic(r) => r.round(v),
            ScalarRounder::Stochastic(r) => r.round(v),
            ScalarRounder::Dither(r) => r.round(v),
        }
    }

    /// The mode this rounder implements.
    pub fn mode(&self) -> RoundingMode {
        match self {
            ScalarRounder::Deterministic(_) => RoundingMode::Deterministic,
            ScalarRounder::Stochastic(_) => RoundingMode::Stochastic,
            ScalarRounder::Dither(_) => RoundingMode::Dither,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Welford;

    #[test]
    fn mode_parsing() {
        assert_eq!(
            RoundingMode::from_str("traditional"),
            Some(RoundingMode::Deterministic)
        );
        assert_eq!(RoundingMode::from_str("sr"), Some(RoundingMode::Stochastic));
        assert_eq!(RoundingMode::from_str("dither"), Some(RoundingMode::Dither));
        assert_eq!(RoundingMode::from_str("x"), None);
    }

    #[test]
    fn all_rounders_hit_adjacent_integers() {
        for mode in RoundingMode::ALL {
            let mut r = ScalarRounder::new(mode, 16, 3);
            for i in 0..200 {
                let v = i as f64 * 0.173 - 5.0;
                let out = r.round(v);
                assert!(
                    out == v.floor() as i64 || out == v.ceil() as i64,
                    "{mode:?} v={v} out={out}"
                );
                assert_eq!(r.mode(), mode);
            }
        }
    }

    #[test]
    fn unbiased_modes_vs_biased_mode() {
        // At α = 0.3 deterministic rounding is biased by -0.3; the unbiased
        // schemes' means converge to α.
        let alpha = 0.3;
        for mode in RoundingMode::ALL {
            let mut r = ScalarRounder::new(mode, 32, 5);
            let mut w = Welford::new();
            for _ in 0..20_000 {
                w.push(r.round(alpha) as f64);
            }
            match mode {
                RoundingMode::Deterministic => assert_eq!(w.mean(), 0.0),
                _ => assert!((w.mean() - alpha).abs() < 0.01, "{mode:?} {}", w.mean()),
            }
        }
    }

    #[test]
    fn dither_time_average_converges_fastest() {
        // Error of the running mean after exactly one period N.
        let alpha = 0.45;
        let n = 64;
        let mut dither = ScalarRounder::new(RoundingMode::Dither, n, 9);
        let dither_mean: f64 =
            (0..n).map(|_| dither.round(alpha) as f64).sum::<f64>() / n as f64;
        // Repeat stochastic over many windows to estimate its typical error.
        let mut sto_errs = Welford::new();
        for t in 0..200 {
            let mut s = ScalarRounder::new(RoundingMode::Stochastic, n, 100 + t);
            let m: f64 = (0..n).map(|_| s.round(alpha) as f64).sum::<f64>() / n as f64;
            sto_errs.push((m - alpha).abs());
        }
        assert!(
            (dither_mean - alpha).abs() < sto_errs.mean(),
            "dither window err {} vs stochastic mean err {}",
            (dither_mean - alpha).abs(),
            sto_errs.mean()
        );
    }
}
