//! The literature zoo: stateless rounded-bit functions for the schemes
//! served beyond the paper's three-way comparison.
//!
//! Every function maps `(frac, u)` — the fractional part `frac ∈ [0, 1)`
//! and one uniform random word — to the rounded bit, so the rounded value
//! is always `⌊α⌋ + bit`. Confining each scheme to one quantizer step is a
//! deliberate serving contract: the adjacent-level property is what the
//! step-budget error bounds and the propcheck invariants rely on, so
//! schemes whose textbook form spans two steps (TPDF dither) are realized
//! as a jittered round-half-up threshold instead.
//!
//! * [`sr2_bit`] — two-candidate improved SR (Xia et al. 2020): the
//!   Bernoulli is sharpened toward the nearer candidate,
//!   `p = f²/(f² + (1−f)²)`, cutting per-application variance at the cost
//!   of a small odd-symmetric bias.
//! * [`srvb_bit`] — variance-bounded SR (El Arar et al. 2022 family):
//!   plain SR while `f(1−f)` is under the bound, blended toward
//!   round-to-nearest beyond it; the exact midpoint stays a fair coin.
//! * [`tpdf_bit`] — TPDF (triangular) dither: the rounding threshold is
//!   jittered by the mean of two uniforms.
//! * [`gauss_bit`] — Gaussian dither: the threshold is jittered by a
//!   centered Irwin–Hall(4) approximate Gaussian.

use crate::util::rng::u64_to_unit_f64;

/// Bernoulli-variance ceiling of [`srvb_bit`] (half of plain SR's
/// worst-case `1/4`).
pub const SRVB_VARIANCE_BOUND: f64 = 0.125;

/// Two-candidate improved stochastic rounding bit: `1` with probability
/// `f² / (f² + (1−f)²)` — steeper than plain SR's `f`, so draws cluster on
/// the nearer candidate.
#[inline]
pub fn sr2_bit(frac: f64, u: u64) -> bool {
    let up = frac * frac;
    let down = (1.0 - frac) * (1.0 - frac);
    // up + down ≥ 1/2 for frac ∈ [0, 1], so the ratio is always defined.
    u64_to_unit_f64(u) < up / (up + down)
}

/// Variance-bounded stochastic rounding bit: plain SR while
/// `f(1−f) ≤ `[`SRVB_VARIANCE_BOUND`], otherwise the Bernoulli parameter
/// is contracted toward the nearer integer by `λ = bound / (f(1−f))`,
/// capping the per-application variance near the bound. The exact midpoint
/// has no nearer integer and stays a fair coin.
#[inline]
pub fn srvb_bit(frac: f64, u: u64) -> bool {
    let fq = frac * (1.0 - frac);
    let p = if fq <= SRVB_VARIANCE_BOUND {
        frac
    } else {
        let lambda = SRVB_VARIANCE_BOUND / fq;
        let nearest = if frac > 0.5 {
            1.0
        } else if frac < 0.5 {
            0.0
        } else {
            0.5
        };
        lambda * frac + (1.0 - lambda) * nearest
    };
    u64_to_unit_f64(u) < p
}

/// TPDF-dithered rounding bit: `1` iff the mean of two independent
/// uniforms (a triangular variate on `[0, 1]`) falls below `frac` — i.e.
/// round-half-up with the threshold jittered by triangular noise, confined
/// to one step.
#[inline]
pub fn tpdf_bit(frac: f64, u: u64) -> bool {
    let a = (u >> 32) as f64 / 4294967296.0;
    let b = (u & 0xFFFF_FFFF) as f64 / 4294967296.0;
    0.5 * (a + b) < frac
}

/// Gaussian-dithered rounding bit: round-half-up with the threshold
/// jittered by a centered Irwin–Hall(4) variate (mean 0, sd ≈ 0.577),
/// confined to one step. Exact integers (`frac = 0`) never move.
#[inline]
pub fn gauss_bit(frac: f64, u: u64) -> bool {
    if frac <= 0.0 {
        return false;
    }
    let s = ((u >> 48) & 0xFFFF) as f64
        + ((u >> 32) & 0xFFFF) as f64
        + ((u >> 16) & 0xFFFF) as f64
        + (u & 0xFFFF) as f64;
    let g = s / 65536.0 - 2.0;
    frac + 0.5 * g >= 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rounding::stochastic::stochastic_bit;
    use crate::util::rng::counter_hash;
    use crate::util::stats::Welford;

    /// Empirical bit rate over many counter-hashed words.
    fn rate(bit: impl Fn(f64, u64) -> bool, frac: f64, seed: u64, trials: u64) -> f64 {
        let mut w = Welford::new();
        for i in 0..trials {
            w.push(f64::from(u8::from(bit(frac, counter_hash(seed, i)))));
        }
        w.mean()
    }

    #[test]
    fn sr2_matches_its_sharpened_probability() {
        for k in 1..10 {
            let f = k as f64 / 10.0;
            let up = f * f;
            let p = up / (up + (1.0 - f) * (1.0 - f));
            let r = rate(sr2_bit, f, 7, 40_000);
            assert!((r - p).abs() < 0.01, "f={f} rate={r} p={p}");
        }
    }

    #[test]
    fn sr2_variance_never_exceeds_plain_sr() {
        // p(1−p) of the sharpened Bernoulli is ≤ f(1−f) everywhere.
        for k in 0..=20 {
            let f = k as f64 / 20.0;
            let up = f * f;
            let p = up / (up + (1.0 - f) * (1.0 - f));
            assert!(
                p * (1.0 - p) <= f * (1.0 - f) + 1e-12,
                "f={f} p={p}"
            );
        }
    }

    #[test]
    fn srvb_is_plain_sr_inside_the_variance_bound() {
        // f(1−f) ≤ 1/8 ⇔ f outside (0.146.., 0.853..): the bit must equal
        // plain SR on the same random word, bit for bit.
        for &f in &[0.0, 0.05, 0.1, 0.14, 0.86, 0.9, 0.99] {
            for i in 0..5_000u64 {
                let u = counter_hash(11, i);
                assert_eq!(srvb_bit(f, u), stochastic_bit(f, u), "f={f}");
            }
        }
    }

    #[test]
    fn srvb_caps_the_bernoulli_variance() {
        // Away from the midpoint knife-edge, p(1−p) stays near the bound
        // instead of climbing to SR's 1/4.
        for k in 0..=40 {
            let f = k as f64 / 40.0;
            if (f - 0.5).abs() < 1e-9 {
                continue;
            }
            let r = rate(srvb_bit, f, 13, 40_000);
            assert!(
                r * (1.0 - r) <= 0.19 + 0.01,
                "f={f} rate={r} var={}",
                r * (1.0 - r)
            );
        }
        // The midpoint itself is a fair coin.
        let mid = rate(srvb_bit, 0.5, 13, 40_000);
        assert!((mid - 0.5).abs() < 0.01, "midpoint rate {mid}");
    }

    #[test]
    fn tpdf_tracks_the_triangular_cdf() {
        for k in 0..=10 {
            let f = k as f64 / 10.0;
            let cdf = if f <= 0.5 {
                2.0 * f * f
            } else {
                1.0 - 2.0 * (1.0 - f) * (1.0 - f)
            };
            let r = rate(tpdf_bit, f, 17, 40_000);
            assert!((r - cdf).abs() < 0.01, "f={f} rate={r} cdf={cdf}");
        }
    }

    #[test]
    fn gauss_rate_is_monotone_and_anchored() {
        let mut prev = -1.0;
        for k in 0..=10 {
            let f = k as f64 / 10.0;
            let r = rate(gauss_bit, f, 19, 40_000);
            assert!(r >= prev - 0.01, "rate must grow with frac: f={f} {r} < {prev}");
            prev = r;
        }
        assert_eq!(rate(gauss_bit, 0.0, 19, 1_000), 0.0, "integers never move");
        let mid = rate(gauss_bit, 0.5, 23, 40_000);
        assert!((mid - 0.5).abs() < 0.01, "midpoint rate {mid}");
    }

    #[test]
    fn all_zoo_bits_are_deterministic_in_their_inputs() {
        for i in 0..200u64 {
            let u = counter_hash(29, i);
            let f = (i as f64 * 0.37) % 1.0;
            assert_eq!(sr2_bit(f, u), sr2_bit(f, u));
            assert_eq!(srvb_bit(f, u), srvb_bit(f, u));
            assert_eq!(tpdf_bit(f, u), tpdf_bit(f, u));
            assert_eq!(gauss_bit(f, u), gauss_bit(f, u));
        }
    }
}
