//! Quantized (reduced-precision) MLP inference — the §VII–§VIII evaluation
//! path.
//!
//! Every matmul in the forward pass is replaced by a k-bit fixed-point
//! [`quant_matmul`] under a chosen [`SchemeId`] and [`Variant`]. This is
//! the *direct* path, which plans both operands per call; the serving stack
//! uses [`crate::nn::PreparedModel`] to plan the weight side once and only
//! pays for the activation side per request. Per the
//! paper: weights are normalized to `[-1, 1]`, the input shares the weight
//! quantizer's `[-1, 1]` range even though pixels occupy only `[0, 1]`
//! ("it did not fully utilize the full range of the quantizer" — the very
//! regime where unbiased rounding wins), and for the 3-layer network the
//! intermediate result matrices are rounded separately before each matmul,
//! with activation ranges calibrated from a float forward pass.

use crate::linalg::{quant_matmul, Matrix, QuantMatmulConfig, Variant};
use crate::nn::layer::argmax_rows;
use crate::nn::mlp::Mlp;
use crate::rounding::SchemeId;

/// Configuration for quantized inference.
#[derive(Clone, Debug)]
pub struct QuantInferenceConfig {
    /// Quantizer bit width `k`.
    pub bits: u32,
    /// Rounding scheme.
    pub mode: SchemeId,
    /// Rounding placement within each matmul.
    pub variant: Variant,
    /// Trial seed (vary to sample the accuracy distribution).
    pub seed: u64,
}

impl QuantInferenceConfig {
    /// The plan-cache fingerprint of this configuration for one model
    /// family: everything except the per-trial seed, which only drives the
    /// activation-side rounding stream of a prepared forward pass.
    pub fn plan_key(&self, model: &str) -> crate::nn::prepared::PlanKey {
        crate::nn::prepared::PlanKey {
            model: model.to_string(),
            bits: self.bits,
            scheme: self.mode,
            variant: self.variant,
        }
    }
}

/// Per-layer input ranges used by the quantizers, calibrated once on the
/// float model.
#[derive(Clone, Debug)]
pub struct ActivationRanges {
    /// `(lo, hi)` for the input of each layer.
    pub per_layer: Vec<(f64, f64)>,
}

impl ActivationRanges {
    /// Calibrate on a batch: layer 0 uses the paper's fixed `[-1, 1]`;
    /// deeper layers use the observed activation envelope with 10% headroom
    /// (the paper's "conservatively scaled to lie well within the range").
    pub fn calibrate(mlp: &Mlp, x: &Matrix) -> ActivationRanges {
        let mut per_layer = vec![(-1.0, 1.0)];
        let mut h = x.clone();
        for (i, layer) in mlp.layers.iter().enumerate() {
            h = layer.forward(&h);
            if i + 1 < mlp.layers.len() {
                let m = h.max_abs().max(1e-6) * 1.1;
                per_layer.push((-m, m));
            }
        }
        ActivationRanges { per_layer }
    }
}

/// Quantized forward pass → logits.
pub fn quantized_forward(
    mlp: &Mlp,
    x: &Matrix,
    ranges: &ActivationRanges,
    cfg: &QuantInferenceConfig,
) -> Matrix {
    assert_eq!(
        ranges.per_layer.len(),
        mlp.layers.len(),
        "one activation range per layer"
    );
    let mut h = x.clone();
    for (li, layer) in mlp.layers.iter().enumerate() {
        let w_range = layer.weight_range();
        let mm = QuantMatmulConfig {
            bits: cfg.bits,
            mode: cfg.mode,
            variant: cfg.variant,
            // Decorrelate layers and trials.
            seed: cfg.seed ^ ((li as u64 + 1) << 40),
            range_a: ranges.per_layer[li],
            range_b: (-w_range, w_range),
            n_a: None,
            n_b: None,
        };
        let mut out = quant_matmul(&h, &layer.weights, &mm);
        layer.finish(&mut out); // bias + ReLU in full precision (§VI: bias
                                // is "precoded"; the multiplier is what is
                                // reduced-precision)
        h = out;
    }
    h
}

/// Quantized predictions.
pub fn quantized_predict(
    mlp: &Mlp,
    x: &Matrix,
    ranges: &ActivationRanges,
    cfg: &QuantInferenceConfig,
) -> Vec<u8> {
    argmax_rows(&quantized_forward(mlp, x, ranges, cfg))
}

/// Quantized classification accuracy.
pub fn quantized_accuracy(
    mlp: &Mlp,
    x: &Matrix,
    labels: &[u8],
    ranges: &ActivationRanges,
    cfg: &QuantInferenceConfig,
) -> f64 {
    let preds = quantized_predict(mlp, x, ranges, cfg);
    preds.iter().zip(labels).filter(|(p, l)| p == l).count() as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    /// A tiny separable problem: class = argmax of two pixel groups.
    fn toy_problem() -> (Mlp, Matrix, Vec<u8>) {
        let mut rng = Xoshiro256pp::new(1);
        let mut mlp = Mlp::single_layer(4, 2, &mut rng);
        mlp.layers[0].weights =
            Matrix::from_vec(4, 2, vec![0.9, -0.9, 0.9, -0.9, -0.9, 0.9, -0.9, 0.9]);
        mlp.layers[0].bias = vec![0.0, 0.0];
        let mut x = Matrix::zeros(40, 4);
        let mut labels = Vec::new();
        let mut rng2 = Xoshiro256pp::new(2);
        for i in 0..40 {
            let class = (i % 2) as u8;
            for j in 0..4 {
                let group = usize::from(j >= 2);
                let base = if group == class as usize { 0.8 } else { 0.2 };
                x.set(i, j, (base + rng2.uniform(-0.1, 0.1)) as f64);
            }
            labels.push(class);
        }
        (mlp, x, labels)
    }

    #[test]
    fn high_bits_match_float_accuracy() {
        let (mlp, x, labels) = toy_problem();
        let float_acc = mlp.accuracy(&x, &labels);
        assert_eq!(float_acc, 1.0);
        let ranges = ActivationRanges::calibrate(&mlp, &x);
        for mode in SchemeId::PAPER {
            let cfg = QuantInferenceConfig {
                bits: 12,
                mode,
                variant: Variant::PerPartial,
                seed: 3,
            };
            let acc = quantized_accuracy(&mlp, &x, &labels, &ranges, &cfg);
            assert!(acc > 0.95, "{mode:?} acc={acc}");
        }
    }

    #[test]
    fn unbiased_modes_survive_low_bits() {
        // The §VII narrow-range regime: inputs occupy [0.05, 0.45] inside a
        // [-1, 1] quantizer at k=1 — deterministic rounding maps *every*
        // pixel to the same level (all information lost), while dither /
        // stochastic rounding keep the class signal in expectation.
        let (mlp, _, _) = toy_problem();
        let mut x = Matrix::zeros(40, 4);
        let mut labels = Vec::new();
        let mut rng = Xoshiro256pp::new(8);
        for i in 0..40 {
            let class = (i % 2) as u8;
            for j in 0..4 {
                let group = usize::from(j >= 2);
                let base = if group == class as usize { 0.40 } else { 0.10 };
                x.set(i, j, base + rng.uniform(-0.05, 0.05));
            }
            labels.push(class);
        }
        let ranges = ActivationRanges::calibrate(&mlp, &x);
        let acc_of = |mode: SchemeId| {
            let mut total = 0.0;
            for t in 0..10u64 {
                let cfg = QuantInferenceConfig {
                    bits: 1,
                    mode,
                    variant: Variant::PerPartial,
                    seed: 50 + t,
                };
                total += quantized_accuracy(&mlp, &x, &labels, &ranges, &cfg);
            }
            total / 10.0
        };
        let dither = acc_of(SchemeId::Dither);
        let det = acc_of(SchemeId::Deterministic);
        assert!(
            dither > det + 0.1,
            "dither {dither} should beat deterministic {det} at k=1"
        );
    }

    #[test]
    fn calibration_shapes() {
        let mut rng = Xoshiro256pp::new(4);
        let mlp = Mlp::three_layer(6, 5, 4, 3, &mut rng);
        let x = Matrix::from_fn(8, 6, |i, j| ((i + j) as f64 * 0.17).sin().abs());
        let ranges = ActivationRanges::calibrate(&mlp, &x);
        assert_eq!(ranges.per_layer.len(), 3);
        assert_eq!(ranges.per_layer[0], (-1.0, 1.0));
        for &(lo, hi) in &ranges.per_layer[1..] {
            assert!(lo < 0.0 && hi > 0.0 && hi == -lo);
        }
    }

    #[test]
    fn deterministic_quantized_forward_is_reproducible() {
        let (mlp, x, labels) = toy_problem();
        let ranges = ActivationRanges::calibrate(&mlp, &x);
        let cfg = QuantInferenceConfig {
            bits: 4,
            mode: SchemeId::Dither,
            variant: Variant::Separate,
            seed: 9,
        };
        let a = quantized_accuracy(&mlp, &x, &labels, &ranges, &cfg);
        let b = quantized_accuracy(&mlp, &x, &labels, &ranges, &cfg);
        assert_eq!(a, b);
    }
}
