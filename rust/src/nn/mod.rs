//! Neural-network inference: float reference path and the reduced-precision
//! quantized path used by the paper's §VII–§VIII experiments.

pub mod layer;
pub mod mlp;
pub mod prepared;
pub mod quantized;

pub use layer::{argmax_rows, softmax_rows, Dense};
pub use mlp::Mlp;
pub use prepared::{PlanKey, PreparedModel};
pub use quantized::{
    quantized_accuracy, quantized_forward, quantized_predict, ActivationRanges,
    QuantInferenceConfig,
};
