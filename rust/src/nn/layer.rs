//! Dense layers and activations for the evaluation networks.

use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256pp;

/// A dense (fully connected) layer `y = x·W + b` with an optional ReLU.
#[derive(Clone, Debug)]
pub struct Dense {
    /// Weight matrix, `in_dim × out_dim`.
    pub weights: Matrix,
    /// Bias vector, `out_dim`.
    pub bias: Vec<f64>,
    /// Apply ReLU after the affine map.
    pub relu: bool,
}

impl Dense {
    /// He-initialized layer.
    pub fn init(in_dim: usize, out_dim: usize, relu: bool, rng: &mut Xoshiro256pp) -> Dense {
        let std = (2.0 / in_dim as f64).sqrt();
        let weights = Matrix::from_fn(in_dim, out_dim, |_, _| rng.normal() * std);
        Dense {
            weights,
            bias: vec![0.0; out_dim],
            relu,
        }
    }

    /// Forward pass on a batch (`n × in_dim` → `n × out_dim`).
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut out = x.matmul(&self.weights);
        self.finish(&mut out);
        out
    }

    /// Add bias and apply the activation in place (shared with the
    /// quantized path, which substitutes its own matmul).
    pub fn finish(&self, out: &mut Matrix) {
        let cols = out.cols;
        for i in 0..out.rows {
            let row = out.row_mut(i);
            for j in 0..cols {
                row[j] += self.bias[j];
                if self.relu && row[j] < 0.0 {
                    row[j] = 0.0;
                }
            }
        }
    }

    /// Input dimension.
    pub fn in_dim(&self) -> usize {
        self.weights.rows
    }

    /// Output dimension.
    pub fn out_dim(&self) -> usize {
        self.weights.cols
    }

    /// Largest |weight| (used to derive quantizer ranges).
    pub fn weight_range(&self) -> f64 {
        self.weights.max_abs().max(1e-9)
    }
}

/// Row-wise softmax (numerically stabilized).
pub fn softmax_rows(m: &mut Matrix) {
    let cols = m.cols;
    for i in 0..m.rows {
        let row = m.row_mut(i);
        let max = row.iter().fold(f64::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
        let _ = cols;
    }
}

/// Argmax per row → predicted labels.
pub fn argmax_rows(m: &Matrix) -> Vec<u8> {
    (0..m.rows)
        .map(|i| {
            let row = m.row(i);
            let mut best = 0usize;
            for j in 1..row.len() {
                if row[j] > row[best] {
                    best = j;
                }
            }
            best as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shape_and_bias() {
        let mut rng = Xoshiro256pp::new(1);
        let mut layer = Dense::init(4, 3, false, &mut rng);
        layer.bias = vec![1.0, 2.0, 3.0];
        let x = Matrix::zeros(2, 4);
        let y = layer.forward(&x);
        assert_eq!((y.rows, y.cols), (2, 3));
        assert_eq!(y.row(0), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut rng = Xoshiro256pp::new(2);
        let mut layer = Dense::init(2, 2, true, &mut rng);
        layer.weights = Matrix::from_vec(2, 2, vec![1.0, -1.0, 0.0, 0.0]);
        layer.bias = vec![0.0, 0.0];
        let x = Matrix::from_vec(1, 2, vec![3.0, 0.0]);
        let y = layer.forward(&x);
        assert_eq!(y.row(0), &[3.0, 0.0]); // -3 clamped to 0
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        softmax_rows(&mut m);
        for i in 0..2 {
            let s: f64 = m.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-12);
            assert!(m.row(i).iter().all(|&v| v > 0.0));
        }
        // Monotonic in the logits.
        assert!(m.get(0, 2) > m.get(0, 1));
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let mut m = Matrix::from_vec(1, 2, vec![1000.0, 1001.0]);
        softmax_rows(&mut m);
        assert!(m.get(0, 1) > m.get(0, 0));
        assert!((m.row(0).iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn argmax_picks_largest() {
        let m = Matrix::from_vec(2, 3, vec![0.1, 0.7, 0.2, 0.9, 0.05, 0.05]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn he_init_scale() {
        let mut rng = Xoshiro256pp::new(3);
        let layer = Dense::init(1000, 10, false, &mut rng);
        let var: f64 = layer
            .weights
            .data()
            .iter()
            .map(|w| w * w)
            .sum::<f64>()
            / layer.weights.data().len() as f64;
        assert!((var - 0.002).abs() < 0.0005, "var={var}");
    }
}
