//! Multi-layer perceptron container with binary save/load.
//!
//! The evaluation networks are the paper's: a 1-layer softmax classifier
//! for the MNIST-class task and a 3-layer ReLU MLP for the Fashion-class
//! task (§VII–§VIII). Weights are produced by the pure-Rust trainer
//! ([`crate::train`]) and stored under `artifacts/weights/` so the serving
//! path and the experiments never need Python.

use crate::linalg::Matrix;
use crate::nn::layer::{argmax_rows, Dense};
use crate::util::rng::Xoshiro256pp;
use std::io::{Read, Write};

/// A stack of dense layers (softmax is applied by the loss/argmax, not
/// stored as a layer).
#[derive(Clone, Debug)]
pub struct Mlp {
    /// Layers in forward order.
    pub layers: Vec<Dense>,
}

const MAGIC: &[u8; 4] = b"DMLP";
const VERSION: u32 = 1;

impl Mlp {
    /// The paper's MNIST network: single 784→10 softmax layer.
    pub fn single_layer(in_dim: usize, classes: usize, rng: &mut Xoshiro256pp) -> Mlp {
        Mlp {
            layers: vec![Dense::init(in_dim, classes, false, rng)],
        }
    }

    /// The paper's Fashion network: 3-layer ReLU MLP.
    pub fn three_layer(
        in_dim: usize,
        hidden1: usize,
        hidden2: usize,
        classes: usize,
        rng: &mut Xoshiro256pp,
    ) -> Mlp {
        Mlp {
            layers: vec![
                Dense::init(in_dim, hidden1, true, rng),
                Dense::init(hidden1, hidden2, true, rng),
                Dense::init(hidden2, classes, false, rng),
            ],
        }
    }

    /// Full-precision forward pass → logits.
    pub fn forward(&self, x: &Matrix) -> Matrix {
        let mut h = x.clone();
        for layer in &self.layers {
            h = layer.forward(&h);
        }
        h
    }

    /// Predicted labels.
    pub fn predict(&self, x: &Matrix) -> Vec<u8> {
        argmax_rows(&self.forward(x))
    }

    /// Classification accuracy on a labeled batch.
    pub fn accuracy(&self, x: &Matrix, labels: &[u8]) -> f64 {
        let preds = self.predict(x);
        let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        correct as f64 / labels.len() as f64
    }

    /// Rescale every layer's weights into `[-1, 1]` (the paper scales the
    /// weight matrix to that range before quantization) while preserving
    /// the network's predictions.
    ///
    /// Scaling layer ℓ's weights by `s_ℓ` scales its (ReLU-homogeneous)
    /// output by the accumulated `c_ℓ = Π s_i`, so each bias must be scaled
    /// by the *accumulated* factor for the pre-activation to remain a
    /// positive multiple of the original — which keeps ReLUs and the final
    /// argmax exact.
    ///
    /// Returns the per-layer scale factors applied to the weights.
    pub fn normalize_weights(&mut self) -> Vec<f64> {
        let mut accumulated = 1.0;
        self.layers
            .iter_mut()
            .map(|layer| {
                let s = 1.0 / layer.weight_range();
                for w in layer.weights.data_mut() {
                    *w *= s;
                }
                accumulated *= s;
                for b in &mut layer.bias {
                    *b *= accumulated;
                }
                s
            })
            .collect()
    }

    /// Serialize to a writer (little-endian binary).
    pub fn save_to(&self, w: &mut impl Write) -> std::io::Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&(self.layers.len() as u32).to_le_bytes())?;
        for layer in &self.layers {
            w.write_all(&(layer.in_dim() as u32).to_le_bytes())?;
            w.write_all(&(layer.out_dim() as u32).to_le_bytes())?;
            w.write_all(&[u8::from(layer.relu)])?;
            for &v in layer.weights.data() {
                w.write_all(&v.to_le_bytes())?;
            }
            for &v in &layer.bias {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Save to a file path (creating parent directories).
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        self.save_to(&mut f)
    }

    /// Deserialize from a reader.
    pub fn load_from(r: &mut impl Read) -> std::io::Result<Mlp> {
        let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(err("bad magic"));
        }
        let mut u32buf = [0u8; 4];
        r.read_exact(&mut u32buf)?;
        if u32::from_le_bytes(u32buf) != VERSION {
            return Err(err("unsupported version"));
        }
        r.read_exact(&mut u32buf)?;
        let n_layers = u32::from_le_bytes(u32buf) as usize;
        if n_layers > 64 {
            return Err(err("implausible layer count"));
        }
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            r.read_exact(&mut u32buf)?;
            let in_dim = u32::from_le_bytes(u32buf) as usize;
            r.read_exact(&mut u32buf)?;
            let out_dim = u32::from_le_bytes(u32buf) as usize;
            let mut relu_b = [0u8; 1];
            r.read_exact(&mut relu_b)?;
            let mut f64buf = [0u8; 8];
            let mut wdata = Vec::with_capacity(in_dim * out_dim);
            for _ in 0..in_dim * out_dim {
                r.read_exact(&mut f64buf)?;
                wdata.push(f64::from_le_bytes(f64buf));
            }
            let mut bias = Vec::with_capacity(out_dim);
            for _ in 0..out_dim {
                r.read_exact(&mut f64buf)?;
                bias.push(f64::from_le_bytes(f64buf));
            }
            layers.push(Dense {
                weights: Matrix::from_vec(in_dim, out_dim, wdata),
                bias,
                relu: relu_b[0] != 0,
            });
        }
        Ok(Mlp { layers })
    }

    /// Load from a file path.
    pub fn load(path: &str) -> std::io::Result<Mlp> {
        let mut f = std::fs::File::open(path)?;
        Self::load_from(&mut f)
    }

    /// FNV-1a fingerprint over layer shapes and parameter bit patterns.
    ///
    /// Two networks share a fingerprint iff their architectures and every
    /// weight/bias bit agree — the identity the plan caches key prepared
    /// weight-side state against (so a plan can never be silently executed
    /// on a retrained or different model).
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf29ce484222325;
        const PRIME: u64 = 0x100000001b3;
        let mut h = OFFSET;
        let mut mix = |x: u64| {
            h = (h ^ x).wrapping_mul(PRIME);
        };
        mix(self.layers.len() as u64);
        for layer in &self.layers {
            mix(layer.in_dim() as u64);
            mix(layer.out_dim() as u64);
            mix(u64::from(layer.relu));
            for &w in layer.weights.data() {
                mix(w.to_bits());
            }
            for &b in &layer.bias {
                mix(b.to_bits());
            }
        }
        h
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.in_dim() * l.out_dim() + l.out_dim())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let mut rng = Xoshiro256pp::new(1);
        let mlp = Mlp::three_layer(20, 16, 8, 4, &mut rng);
        let x = Matrix::zeros(5, 20);
        let y = mlp.forward(&x);
        assert_eq!((y.rows, y.cols), (5, 4));
        assert_eq!(mlp.param_count(), 20 * 16 + 16 + 16 * 8 + 8 + 8 * 4 + 4);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Xoshiro256pp::new(2);
        let mlp = Mlp::three_layer(6, 5, 4, 3, &mut rng);
        let mut buf = Vec::new();
        mlp.save_to(&mut buf).unwrap();
        let back = Mlp::load_from(&mut &buf[..]).unwrap();
        assert_eq!(back.layers.len(), 3);
        for (a, b) in mlp.layers.iter().zip(&back.layers) {
            assert_eq!(a.weights, b.weights);
            assert_eq!(a.bias, b.bias);
            assert_eq!(a.relu, b.relu);
        }
        // Same predictions.
        let x = Matrix::from_fn(4, 6, |i, j| ((i * 7 + j) as f64).sin());
        assert_eq!(mlp.predict(&x), back.predict(&x));
    }

    #[test]
    fn load_rejects_garbage() {
        assert!(Mlp::load_from(&mut &b"XXXX"[..]).is_err());
        let mut buf = Vec::new();
        Mlp::single_layer(4, 2, &mut Xoshiro256pp::new(3))
            .save_to(&mut buf)
            .unwrap();
        buf[4] = 99; // version
        assert!(Mlp::load_from(&mut &buf[..]).is_err());
    }

    #[test]
    fn normalize_weights_bounds_range() {
        let mut rng = Xoshiro256pp::new(4);
        let mut mlp = Mlp::three_layer(10, 8, 6, 4, &mut rng);
        // Inflate one weight to force a non-trivial scale.
        mlp.layers[0].weights.set(0, 0, 7.5);
        let x = Matrix::from_fn(3, 10, |i, j| ((i + j) as f64 * 0.1).cos().abs());
        let before = mlp.layers[2].forward(
            &mlp.layers[1].forward(&mlp.layers[0].forward(&x)),
        );
        let preds_before = argmax_rows(&before);
        mlp.normalize_weights();
        for layer in &mlp.layers {
            assert!(layer.weight_range() <= 1.0 + 1e-12);
        }
        // Final-layer argmax is preserved for the single-layer case only in
        // general; for deep ReLU nets positive rescaling preserves argmax
        // per layer (ReLU is positive-homogeneous), so predictions match.
        let preds_after = mlp.predict(&x);
        assert_eq!(preds_before, preds_after);
    }

    #[test]
    fn fingerprint_tracks_parameters() {
        let mut rng = Xoshiro256pp::new(6);
        let mlp = Mlp::three_layer(6, 5, 4, 3, &mut rng);
        let same = mlp.clone();
        assert_eq!(mlp.fingerprint(), same.fingerprint());
        let mut other = mlp.clone();
        other.layers[1].weights.set(0, 0, 0.123456789);
        assert_ne!(mlp.fingerprint(), other.fingerprint());
        let mut biased = mlp.clone();
        biased.layers[2].bias[0] += 1e-9;
        assert_ne!(mlp.fingerprint(), biased.fingerprint());
    }

    #[test]
    fn accuracy_computation() {
        let mut rng = Xoshiro256pp::new(5);
        let mut mlp = Mlp::single_layer(2, 2, &mut rng);
        mlp.layers[0].weights = Matrix::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        mlp.layers[0].bias = vec![0.0, 0.0];
        let x = Matrix::from_vec(4, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 0.0, 1.0]);
        assert_eq!(mlp.accuracy(&x, &[0, 1, 0, 1]), 1.0);
        assert_eq!(mlp.accuracy(&x, &[1, 0, 0, 1]), 0.5);
    }
}
