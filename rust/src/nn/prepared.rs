//! Prepared quantized inference: the weight side of every layer's matmul
//! is planned **once** and reused across requests, so a serving call only
//! plans the activation side.
//!
//! The direct path ([`crate::nn::quantized_forward`]) rebuilds quantizers,
//! per-element rounding tables and (for the `Separate` placement) the full
//! requantized weight matrix on every call — per layer, per request. The
//! rounded values of the weight operand are request-invariant for
//! deterministic rounding (seed-free) and effectively so for dither
//! rounding (the §II-D representation is deterministic to first order), so
//! [`PreparedModel`] freezes one materialized quantized weight matrix per
//! layer for those schemes under [`Variant::Separate`], and caches the
//! seed-independent planning tables for everything else.
//!
//! Guarantees, locked by `tests/plan_execute.rs`:
//!
//! * deterministic mode is **bit-identical** to the direct path (and
//!   seed-independent);
//! * stochastic mode is bit-identical given the same per-call seed (its
//!   weight draw stays fresh per request — freezing a Bernoulli draw would
//!   silently correlate repeated requests);
//! * dither mode under `Separate` is distribution-equivalent: the frozen
//!   weight draw shifts individual logits by at most one quantizer step
//!   per contracted element, with the same mean behaviour over trials;
//! * dither mode under `InputOnce`/`PerPartial` is bit-identical given the
//!   per-call seed: those placements sweep the weight operand's dither
//!   period over a batch-sized use index, so the weight side is planned
//!   per call rather than pinned to a wrong prebuilt period.

use crate::linalg::{execute, Matrix, Operand, QuantMatmulConfig, QuantPlan, SweepAxis, Variant};
use crate::nn::mlp::Mlp;
use crate::nn::quantized::ActivationRanges;
use crate::rounding::{Quantizer, SchemeId};

/// Cache key for a prepared model: everything that determines the
/// weight-side plans of one serving configuration.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// Model family wire name.
    pub model: String,
    /// Quantizer bit width `k`.
    pub bits: u32,
    /// Rounding scheme.
    pub scheme: SchemeId,
    /// Rounding placement.
    pub variant: Variant,
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/k={}/{}/{}",
            self.model,
            self.bits,
            self.scheme,
            self.variant.name()
        )
    }
}

/// One weight-side [`QuantPlan`] per layer of an [`Mlp`], for a fixed
/// `(bits, mode, variant)` serving configuration.
pub struct PreparedModel {
    bits: u32,
    mode: SchemeId,
    variant: Variant,
    /// Weight-side plan per layer, in forward order. `None` means the
    /// layer's weight operand must be planned per call (dither under the
    /// per-partial placements, whose sweep period is the batch size and
    /// therefore unknowable at prepare time).
    plans: Vec<Option<QuantPlan>>,
    /// Fingerprint of the network the plans were built from (guards
    /// against executing plans on a different model).
    fingerprint: u64,
}

impl PreparedModel {
    /// Build the weight-side plans for every layer. `prep_seed` fixes the
    /// dither draw of frozen weight operands (deterministic mode ignores
    /// it entirely).
    ///
    /// Frozen plans use the layer's input dimension as the dither period:
    /// the rounding errors of each weight column then sweep one full §II-D
    /// sequence across exactly the elements the matmul sums, which is the
    /// stratification the paper's `Θ(1/N)` argument wants (the per-call
    /// path defaults the period to the batch size instead, because it
    /// cannot know the contraction geometry ahead of time).
    pub fn prepare(
        mlp: &Mlp,
        bits: u32,
        mode: SchemeId,
        variant: Variant,
        prep_seed: u64,
    ) -> PreparedModel {
        let plans = mlp
            .layers
            .iter()
            .enumerate()
            .map(|(li, layer)| {
                let w_range = layer.weight_range();
                let quant = Quantizer::new(bits, -w_range, w_range);
                let n = layer.in_dim();
                // Freezing is sound when the operand is quantized once per
                // call (`Separate`) and its draw is request-invariant —
                // deterministic always, dither by §II-D structure. The
                // stochastic family (plain SR and every zoo scheme) keeps a
                // fresh draw per request; the registry's `frozen_weights`
                // flag is the single source of truth.
                if variant == Variant::Separate && mode.frozen_weights() {
                    let seed = prep_seed ^ ((li as u64 + 1) << 40) ^ 0xB1B1_B1B1;
                    let plan = QuantPlan::plan_frozen(
                        &layer.weights,
                        &quant,
                        mode,
                        n,
                        SweepAxis::Rows,
                        seed,
                    );
                    Some(plan)
                } else if mode == SchemeId::Dither {
                    // InputOnce/PerPartial sweep the weight operand's
                    // dither period over its per-row use index, whose
                    // count is the batch size — unknowable here. A
                    // prebuilt period would silently change the
                    // stratification geometry, so these layers plan per
                    // call, exactly like the direct path.
                    None
                } else {
                    // Deterministic and stochastic rounding ignore the
                    // period entirely, so their tables are reusable under
                    // every placement.
                    let plan =
                        QuantPlan::plan_operand(&layer.weights, &quant, mode, n, SweepAxis::Rows);
                    Some(plan)
                }
            })
            .collect();
        PreparedModel {
            bits,
            mode,
            variant,
            plans,
            fingerprint: mlp.fingerprint(),
        }
    }

    /// Quantized forward pass → logits, planning only the activation side.
    ///
    /// `mlp` must be the network the plans were prepared from (checked via
    /// fingerprint in debug builds); `seed` drives the per-call activation
    /// rounding stream exactly like [`crate::nn::QuantInferenceConfig::seed`]
    /// drives the direct path.
    pub fn forward(&self, mlp: &Mlp, x: &Matrix, ranges: &ActivationRanges, seed: u64) -> Matrix {
        debug_assert_eq!(
            self.fingerprint,
            mlp.fingerprint(),
            "prepared plans executed against a different model"
        );
        assert_eq!(
            self.plans.len(),
            mlp.layers.len(),
            "one weight plan per layer"
        );
        assert_eq!(
            ranges.per_layer.len(),
            mlp.layers.len(),
            "one activation range per layer"
        );
        let mut h = x.clone();
        for (li, layer) in mlp.layers.iter().enumerate() {
            let w_range = layer.weight_range();
            let mm = QuantMatmulConfig {
                bits: self.bits,
                mode: self.mode,
                variant: self.variant,
                // Decorrelate layers and trials (same derivation as the
                // direct path, so unfrozen schemes stay bit-identical).
                seed: seed ^ ((li as u64 + 1) << 40),
                range_a: ranges.per_layer[li],
                range_b: (-w_range, w_range),
                n_a: None,
                n_b: None,
            };
            let weight_side = match &self.plans[li] {
                Some(plan) => Operand::Plan(plan),
                None => Operand::Raw(&layer.weights),
            };
            let mut out = execute(Operand::Raw(&h), weight_side, &mm);
            layer.finish(&mut out); // bias + ReLU in full precision (§VI)
            h = out;
        }
        h
    }

    /// Bit width of the prepared configuration.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Rounding scheme of the prepared configuration.
    pub fn mode(&self) -> SchemeId {
        self.mode
    }

    /// Rounding placement of the prepared configuration.
    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Approximate heap footprint of all layer plans (cache accounting).
    pub fn memory_bytes(&self) -> usize {
        self.plans
            .iter()
            .flatten()
            .map(QuantPlan::memory_bytes)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quantized::{quantized_forward, QuantInferenceConfig};
    use crate::util::rng::Xoshiro256pp;

    fn toy() -> (Mlp, Matrix, ActivationRanges) {
        let mut rng = Xoshiro256pp::new(11);
        let mut mlp = Mlp::three_layer(10, 8, 6, 4, &mut rng);
        mlp.normalize_weights();
        let x = Matrix::from_fn(5, 10, |i, j| (((i * 10 + j) as f64) * 0.37).sin().abs());
        let ranges = ActivationRanges::calibrate(&mlp, &x);
        (mlp, x, ranges)
    }

    #[test]
    fn deterministic_prepared_forward_is_seed_independent() {
        let (mlp, x, ranges) = toy();
        let cfg = QuantInferenceConfig {
            bits: 4,
            mode: SchemeId::Deterministic,
            variant: Variant::Separate,
            seed: 1,
        };
        let direct = quantized_forward(&mlp, &x, &ranges, &cfg);
        for prep_seed in [0u64, 7, 999] {
            let prepared = PreparedModel::prepare(
                &mlp,
                4,
                SchemeId::Deterministic,
                Variant::Separate,
                prep_seed,
            );
            for call_seed in [1u64, 2, 3000] {
                let out = prepared.forward(&mlp, &x, &ranges, call_seed);
                assert_eq!(direct, out, "prep_seed={prep_seed} call_seed={call_seed}");
            }
        }
    }

    #[test]
    fn frozen_layers_report_memory_and_config() {
        let (mlp, _x, _ranges) = toy();
        let p = PreparedModel::prepare(&mlp, 6, SchemeId::Dither, Variant::Separate, 3);
        assert_eq!(p.bits(), 6);
        assert_eq!(p.mode(), SchemeId::Dither);
        assert_eq!(p.variant(), Variant::Separate);
        assert!(p.memory_bytes() > 0);
        // Frozen dither plans drop the planning tables, so the footprint is
        // roughly the materialized weights alone — strictly smaller than a
        // stochastic preparation, which must keep per-call tables.
        let s = PreparedModel::prepare(&mlp, 6, SchemeId::Stochastic, Variant::Separate, 3);
        assert!(p.memory_bytes() < s.memory_bytes());
    }
}
