//! Streaming per-configuration error estimators: Welford cells over the
//! shadow-sampled logit errors, lock-free within a shard.
//!
//! Each serving shard owns one [`FidelityShard`]: a flat, fixed-size table
//! of Welford accumulators keyed by `(model, scheme, k)`. The label space
//! is bounded up front ([`MODEL_SLOTS`] × [`SchemeId::COUNT`] registered
//! schemes × [`MAX_K`] bit widths — the whole zoo gets measured cells, not
//! just the paper's trio), so recording is a handful of relaxed atomic
//! loads/stores with
//! no allocation and no lock — the same hot-path discipline as the
//! latency windows in `coordinator::metrics`.
//!
//! **Freshness.** Every `(model, scheme, k)` label owns [`EPOCH_SLOTS`]
//! rotating Welford cells, mirroring the epoch discipline of the
//! coordinator's recent-latency windows: the writer stamps each cell with
//! the epoch it was (re)started in, readers fold only cells whose stamp is
//! within the live window, and an aged-out cell is zeroed before its new
//! stamp is published. Epochs are supplied by the caller
//! ([`FidelityShard::advance_epoch`] — the serving metrics advance them on
//! its wall-clock cadence), so the estimator itself stays clock-free and
//! deterministic under test. A shard whose epoch is never advanced behaves
//! exactly like the pre-epoch estimator: everything lands in one cell and
//! nothing ever ages out.
//!
//! Concurrency contract: each cell has **one writer** (the shard's batch
//! worker, which is the only thread that runs the engine's shadow path)
//! and any number of readers (`stats` scrapes). The writer updates
//! mean/m2 first and publishes the new count last — and on an epoch
//! rollover zeroes the moments before publishing the new stamp — so
//! readers see either the previous consistent triple or a slightly torn
//! one — acceptable for approximate telemetry, exactly like the rotating
//! latency windows. If multiple writers ever race (standalone engines
//! driven from several threads), updates are lost but never corrupted:
//! every field is a whole atomic word.

use crate::rounding::SchemeId;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of model-family slots per shard (the zoo serves 2; the rest is
/// headroom so adding a family never needs a layout change).
pub const MODEL_SLOTS: usize = 4;

/// Highest tracked quantizer bit width (matches the servable `k` range).
pub const MAX_K: u32 = 16;

/// Rotating epoch cells per label: a measurement stays live for this many
/// epochs after the one it was recorded in, then ages out — the same
/// window depth as the coordinator's recent-latency slots, so the
/// measured-MSE and measured-latency views of a configuration go stale
/// together.
pub const EPOCH_SLOTS: usize = 6;

/// Number of registered rounding schemes (every zoo scheme gets cells).
const SCHEMES: usize = SchemeId::COUNT;

/// One Welford accumulator: count, running mean, and the sum of squared
/// deviations (`m2`), each stored as a whole atomic word (f64 bits), plus
/// the epoch stamp that scopes its lifetime.
#[derive(Debug)]
struct Cell {
    /// Epoch this cell was last (re)started in; 0 = never written.
    epoch: AtomicU64,
    n: AtomicU64,
    mean: AtomicU64,
    m2: AtomicU64,
}

impl Cell {
    fn new() -> Cell {
        Cell {
            epoch: AtomicU64::new(0),
            n: AtomicU64::new(0),
            mean: AtomicU64::new(0),
            m2: AtomicU64::new(0),
        }
    }
}

/// A snapshot of one `(model, scheme, k)` cell, mergeable across shards.
///
/// `bias` is the mean signed logit error (quantized − exact), `m2` the
/// Welford sum of squared deviations; [`FidelityEstimate::mse`] and
/// [`FidelityEstimate::variance`] derive the paper's quantities.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FidelityEstimate {
    /// Number of logit errors observed.
    pub samples: u64,
    /// Mean signed error — the bias the paper proves away for the
    /// unbiased schemes.
    pub bias: f64,
    /// Welford sum of squared deviations from the mean.
    pub m2: f64,
}

impl FidelityEstimate {
    /// Population variance of the error (0 for an empty cell).
    pub fn variance(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.m2 / self.samples as f64
        }
    }

    /// Mean squared error: `bias² + variance` (0 for an empty cell).
    pub fn mse(&self) -> f64 {
        if self.samples == 0 {
            0.0
        } else {
            self.bias * self.bias + self.variance()
        }
    }

    /// Merge another estimate (the standard parallel Welford reduction —
    /// this is how per-shard cells combine on a `stats` scrape).
    pub fn merge(&mut self, other: &FidelityEstimate) {
        if other.samples == 0 {
            return;
        }
        if self.samples == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.samples as f64;
        let n2 = other.samples as f64;
        let delta = other.bias - self.bias;
        let n = n1 + n2;
        self.bias += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.samples += other.samples;
    }
}

/// One shard's fidelity table: [`EPOCH_SLOTS`] rotating Welford cells per
/// `(model, scheme, k)`.
#[derive(Debug)]
pub struct FidelityShard {
    /// Current epoch (starts at 1 so a stamp of 0 always means "never
    /// written"); advanced monotonically by [`FidelityShard::advance_epoch`].
    epoch: AtomicU64,
    cells: Vec<Cell>,
}

impl Default for FidelityShard {
    fn default() -> Self {
        Self::new()
    }
}

impl FidelityShard {
    /// Fresh zeroed table covering the full bounded label space.
    pub fn new() -> FidelityShard {
        FidelityShard {
            epoch: AtomicU64::new(1),
            cells: (0..MODEL_SLOTS * SCHEMES * MAX_K as usize * EPOCH_SLOTS)
                .map(|_| Cell::new())
                .collect(),
        }
    }

    /// Flat index of a label's first epoch cell; `None` when the label is
    /// outside the bounded space (unknown model slot or unservable bit
    /// width).
    fn index(model: usize, mode: SchemeId, k: u32) -> Option<usize> {
        if model >= MODEL_SLOTS || !(1..=MAX_K).contains(&k) {
            return None;
        }
        let label =
            model * SCHEMES * MAX_K as usize + mode.slot() * MAX_K as usize + (k - 1) as usize;
        Some(label * EPOCH_SLOTS)
    }

    /// Advance the shard's epoch to `now_epoch` (monotonic — an older
    /// value is ignored). The serving metrics call this on their
    /// wall-clock cadence; standalone engines that never call it keep the
    /// initial epoch and age nothing out.
    pub fn advance_epoch(&self, now_epoch: u64) {
        self.epoch.fetch_max(now_epoch.max(1), Ordering::Relaxed);
    }

    /// The shard's current epoch (test/telemetry visibility).
    pub fn current_epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Record one shadow-sampled logit error (quantized − exact) for the
    /// configuration. Out-of-space labels are dropped silently (the label
    /// space is bounded by construction; this is a belt-and-braces guard).
    pub fn record(&self, model: usize, mode: SchemeId, k: u32, err: f64) {
        let Some(base) = FidelityShard::index(model, mode, k) else {
            return;
        };
        let e = self.epoch.load(Ordering::Relaxed);
        let cell = &self.cells[base + (e % EPOCH_SLOTS as u64) as usize];
        if cell.epoch.load(Ordering::Relaxed) != e {
            // The slot last served an aged-out epoch: zero the moments
            // first, publish the new stamp last, so a reader that sees the
            // new stamp also sees the reset (or later single-writer
            // updates under it) — never stale moments under a fresh stamp.
            cell.mean.store(0, Ordering::Relaxed);
            cell.m2.store(0, Ordering::Relaxed);
            cell.n.store(0, Ordering::Release);
            cell.epoch.store(e, Ordering::Release);
        }
        let n = cell.n.load(Ordering::Relaxed);
        let mean = f64::from_bits(cell.mean.load(Ordering::Relaxed));
        let m2 = f64::from_bits(cell.m2.load(Ordering::Relaxed));
        let n1 = n + 1;
        let delta = err - mean;
        let new_mean = mean + delta / n1 as f64;
        let new_m2 = m2 + delta * (err - new_mean);
        // Mean/m2 first, count last: a reader that sees the new count also
        // sees moments at least as new (single-writer publication order).
        cell.mean.store(new_mean.to_bits(), Ordering::Relaxed);
        cell.m2.store(new_m2.to_bits(), Ordering::Relaxed);
        cell.n.store(n1, Ordering::Release);
    }

    /// Snapshot one label: the parallel-Welford fold of its live epoch
    /// cells (approximate under concurrent writes; see the module docs).
    pub fn estimate(&self, model: usize, mode: SchemeId, k: u32) -> FidelityEstimate {
        let mut out = FidelityEstimate::default();
        let Some(base) = FidelityShard::index(model, mode, k) else {
            return out;
        };
        let now = self.epoch.load(Ordering::Relaxed);
        for cell in &self.cells[base..base + EPOCH_SLOTS] {
            let e = cell.epoch.load(Ordering::Acquire);
            if e == 0 || now.saturating_sub(e) >= EPOCH_SLOTS as u64 {
                continue; // never written, or aged out of the live window
            }
            let n = cell.n.load(Ordering::Acquire);
            out.merge(&FidelityEstimate {
                samples: n,
                bias: f64::from_bits(cell.mean.load(Ordering::Relaxed)),
                m2: f64::from_bits(cell.m2.load(Ordering::Relaxed)),
            });
        }
        out
    }

    /// Total live logit errors recorded across every cell.
    pub fn total_samples(&self) -> u64 {
        let now = self.epoch.load(Ordering::Relaxed);
        self.cells
            .iter()
            .filter(|c| {
                let e = c.epoch.load(Ordering::Acquire);
                e != 0 && now.saturating_sub(e) < EPOCH_SLOTS as u64
            })
            .map(|c| c.n.load(Ordering::Relaxed))
            .sum()
    }
}

/// A point-in-time snapshot of a whole fidelity table — one
/// [`FidelityEstimate`] per `(model, scheme, k)` label — mergeable across
/// shards. This is what the auto controller prices candidates against: a
/// plain value with no atomics, so a choice replayed against the same
/// table is bit-for-bit reproducible.
#[derive(Clone, Debug, PartialEq)]
pub struct EstimateTable {
    cells: Vec<FidelityEstimate>,
}

impl Default for EstimateTable {
    fn default() -> Self {
        Self::empty()
    }
}

impl EstimateTable {
    /// A table with every label empty (cold — every candidate prices at
    /// its prior).
    pub fn empty() -> EstimateTable {
        EstimateTable {
            cells: vec![FidelityEstimate::default(); MODEL_SLOTS * SCHEMES * MAX_K as usize],
        }
    }

    /// Snapshot one shard's live estimates.
    pub fn from_shard(shard: &FidelityShard) -> EstimateTable {
        let mut table = EstimateTable::empty();
        table.merge_shard(shard);
        table
    }

    /// Fold another shard's live estimates in (parallel Welford per
    /// label) — the per-process merged view is the fold over all shards.
    pub fn merge_shard(&mut self, shard: &FidelityShard) {
        for model in 0..MODEL_SLOTS {
            for mode in SchemeId::ALL {
                for k in 1..=MAX_K {
                    let i = model * SCHEMES * MAX_K as usize
                        + mode.slot() * MAX_K as usize
                        + (k - 1) as usize;
                    self.cells[i].merge(&shard.estimate(model, mode, k));
                }
            }
        }
    }

    /// The estimate for one label (empty for out-of-space labels).
    pub fn get(&self, model: usize, mode: SchemeId, k: u32) -> FidelityEstimate {
        if model >= MODEL_SLOTS || !(1..=MAX_K).contains(&k) {
            return FidelityEstimate::default();
        }
        let i =
            model * SCHEMES * MAX_K as usize + mode.slot() * MAX_K as usize + (k - 1) as usize;
        self.cells[i].clone()
    }

    /// Total samples across every label.
    pub fn total_samples(&self) -> u64 {
        self.cells.iter().map(|c| c.samples).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_cell_matches_direct_moments() {
        let shard = FidelityShard::new();
        let errs = [0.5, -0.25, 1.0, 0.0, -0.5, 0.75];
        for &e in &errs {
            shard.record(0, SchemeId::Dither, 4, e);
        }
        let est = shard.estimate(0, SchemeId::Dither, 4);
        assert_eq!(est.samples, errs.len() as u64);
        let mean: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!((est.bias - mean).abs() < 1e-12);
        let mse: f64 = errs.iter().map(|e| e * e).sum::<f64>() / errs.len() as f64;
        assert!((est.mse() - mse).abs() < 1e-12, "mse {} vs {}", est.mse(), mse);
        assert!((est.variance() - (mse - mean * mean)).abs() < 1e-12);
    }

    #[test]
    fn cells_are_keyed_independently() {
        let shard = FidelityShard::new();
        shard.record(0, SchemeId::Dither, 4, 1.0);
        shard.record(0, SchemeId::Dither, 5, -1.0);
        shard.record(0, SchemeId::Stochastic, 4, 3.0);
        shard.record(1, SchemeId::Dither, 4, 5.0);
        assert_eq!(shard.estimate(0, SchemeId::Dither, 4).bias, 1.0);
        assert_eq!(shard.estimate(0, SchemeId::Dither, 5).bias, -1.0);
        assert_eq!(shard.estimate(0, SchemeId::Stochastic, 4).bias, 3.0);
        assert_eq!(shard.estimate(1, SchemeId::Dither, 4).bias, 5.0);
        assert_eq!(shard.total_samples(), 4);
    }

    #[test]
    fn out_of_space_labels_are_dropped() {
        let shard = FidelityShard::new();
        shard.record(MODEL_SLOTS, SchemeId::Dither, 4, 1.0);
        shard.record(0, SchemeId::Dither, 0, 1.0);
        shard.record(0, SchemeId::Dither, MAX_K + 1, 1.0);
        assert_eq!(shard.total_samples(), 0);
        assert_eq!(
            shard.estimate(9, SchemeId::Dither, 99),
            FidelityEstimate::default()
        );
    }

    #[test]
    fn merge_equals_sequential_accumulation() {
        let all = FidelityShard::new();
        let a = FidelityShard::new();
        let b = FidelityShard::new();
        let errs: Vec<f64> = (0..100).map(|i| (i as f64 * 0.77).sin()).collect();
        for (i, &e) in errs.iter().enumerate() {
            all.record(0, SchemeId::Stochastic, 2, e);
            let half = if i < 37 { &a } else { &b };
            half.record(0, SchemeId::Stochastic, 2, e);
        }
        let mut merged = a.estimate(0, SchemeId::Stochastic, 2);
        merged.merge(&b.estimate(0, SchemeId::Stochastic, 2));
        let direct = all.estimate(0, SchemeId::Stochastic, 2);
        assert_eq!(merged.samples, direct.samples);
        assert!((merged.bias - direct.bias).abs() < 1e-12);
        assert!((merged.mse() - direct.mse()).abs() < 1e-12);
        // Merging an empty estimate is the identity in both directions.
        let mut lhs = direct.clone();
        lhs.merge(&FidelityEstimate::default());
        assert_eq!(lhs, direct);
        let mut empty = FidelityEstimate::default();
        empty.merge(&direct);
        assert_eq!(empty, direct);
    }

    #[test]
    fn epochs_age_out_stale_measurements() {
        let shard = FidelityShard::new();
        shard.record(0, SchemeId::Dither, 4, 2.0);
        assert_eq!(shard.estimate(0, SchemeId::Dither, 4).samples, 1);
        // Still live at the edge of the window…
        shard.advance_epoch(EPOCH_SLOTS as u64);
        assert_eq!(shard.estimate(0, SchemeId::Dither, 4).samples, 1);
        // …gone one epoch past it, for both the label and the totals.
        shard.advance_epoch(EPOCH_SLOTS as u64 + 1);
        assert_eq!(shard.estimate(0, SchemeId::Dither, 4).samples, 0);
        assert_eq!(shard.total_samples(), 0);
        // A fresh recording in the new epoch reclaims the slot: only the
        // new data folds, with no residue of the aged-out moments.
        shard.record(0, SchemeId::Dither, 4, -1.0);
        let est = shard.estimate(0, SchemeId::Dither, 4);
        assert_eq!((est.samples, est.bias), (1, -1.0));
    }

    #[test]
    fn live_epochs_fold_together_and_epoch_is_monotonic() {
        let shard = FidelityShard::new();
        let errs: Vec<f64> = (0..40).map(|i| (i as f64 * 0.31).cos()).collect();
        // Spread the recordings over 4 adjacent epochs.
        for (i, &e) in errs.iter().enumerate() {
            shard.advance_epoch(1 + (i / 10) as u64);
            shard.record(0, SchemeId::Gauss, 3, e);
        }
        // Retreating the clock is ignored (monotonic epochs).
        shard.advance_epoch(1);
        assert_eq!(shard.current_epoch(), 4);
        let est = shard.estimate(0, SchemeId::Gauss, 3);
        assert_eq!(est.samples, errs.len() as u64);
        let mean: f64 = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!((est.bias - mean).abs() < 1e-9, "{} vs {mean}", est.bias);
    }

    #[test]
    fn estimate_table_snapshots_and_merges_shards() {
        let a = FidelityShard::new();
        let b = FidelityShard::new();
        for i in 0..50 {
            let e = (i as f64 * 0.13).sin();
            if i % 2 == 0 {
                a.record(1, SchemeId::Sr2, 6, e);
            } else {
                b.record(1, SchemeId::Sr2, 6, e);
            }
        }
        let mut table = EstimateTable::from_shard(&a);
        table.merge_shard(&b);
        let mut direct = a.estimate(1, SchemeId::Sr2, 6);
        direct.merge(&b.estimate(1, SchemeId::Sr2, 6));
        assert_eq!(table.get(1, SchemeId::Sr2, 6), direct);
        assert_eq!(table.total_samples(), 50);
        // Out-of-space lookups answer empty, and an empty table is cold
        // everywhere.
        assert_eq!(table.get(MODEL_SLOTS, SchemeId::Sr2, 6).samples, 0);
        assert_eq!(EstimateTable::empty().total_samples(), 0);
    }
}
