//! Shadow-sampling policy: which requests additionally run the exact f64
//! forward pass.
//!
//! The decision for request `i` is a stateless hash test —
//! `counter_hash(SALT, i) < rate · 2⁶⁴` — over a per-engine request
//! counter. This keeps the two properties the fidelity estimators need:
//!
//! * **deterministic**: the sampled offsets are a fixed pseudo-random
//!   sequence, so a replayed workload shadows the same requests and the
//!   estimator state is reproducible in tests;
//! * **pattern-free**: whether request `i` is sampled is independent of
//!   any periodicity in the traffic. A plain stride (sample every
//!   `1/rate`-th request) can alias with periodic workloads — e.g. two
//!   clients strictly alternating schemes at rate 0.5 would shadow only
//!   one of the schemes forever, leaving the other's fidelity cell
//!   permanently cold.
//!
//! The long-run sampled fraction converges to `rate` (it is exact in
//! expectation per request, not per window).

use crate::util::rng::counter_hash;
use std::sync::atomic::{AtomicU64, Ordering};

/// Fixed hash salt for the sampling decision (locked by this module's
/// tests; changing it re-rolls which request offsets are shadowed).
const SHADOW_SALT: u64 = 0x5AD0;

/// Deterministic hash-based shadow sampler.
#[derive(Debug)]
pub struct ShadowSampler {
    rate: f64,
    /// `rate · 2⁶⁴`, the per-request acceptance threshold.
    threshold: u64,
    counter: AtomicU64,
}

impl ShadowSampler {
    /// Sampler taking the given fraction of requests (clamped to `0..=1`;
    /// NaN disables sampling).
    pub fn new(rate: f64) -> ShadowSampler {
        let rate = if rate.is_nan() { 0.0 } else { rate.clamp(0.0, 1.0) };
        ShadowSampler {
            rate,
            threshold: (rate * 18446744073709551616.0) as u64,
            counter: AtomicU64::new(0),
        }
    }

    /// Configured sampling fraction.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// True when any request can ever be sampled.
    pub fn enabled(&self) -> bool {
        self.rate > 0.0
    }

    /// Advance the request counter by one and report whether this request
    /// is shadow-sampled.
    pub fn take(&self) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        if self.rate >= 1.0 {
            return true;
        }
        let i = self.counter.fetch_add(1, Ordering::Relaxed);
        counter_hash(SHADOW_SALT, i) < self.threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count(rate: f64, n: usize) -> usize {
        let s = ShadowSampler::new(rate);
        (0..n).filter(|_| s.take()).count()
    }

    #[test]
    fn sampled_fraction_tracks_the_rate() {
        assert_eq!(count(0.0, 1000), 0);
        assert_eq!(count(1.0, 1000), 1000);
        // The hash stream is fixed, so the counts are exact constants —
        // each within a few percent of rate·n (locks SHADOW_SALT).
        assert_eq!(count(0.5, 1000), 506);
        assert_eq!(count(0.25, 1000), 241);
        assert_eq!(count(0.1, 1000), 92);
        assert_eq!(count(0.037, 10_000), 359);
    }

    #[test]
    fn rates_are_clamped() {
        assert_eq!(ShadowSampler::new(-3.0).rate(), 0.0);
        assert_eq!(ShadowSampler::new(7.0).rate(), 1.0);
        assert_eq!(ShadowSampler::new(f64::NAN).rate(), 0.0);
        assert!(!ShadowSampler::new(0.0).enabled());
        assert!(ShadowSampler::new(0.01).enabled());
    }

    #[test]
    fn sampling_does_not_alias_with_periodic_traffic() {
        // At rate 0.5, every parity class must be sampled: a strict
        // stride would hit only one of two interleaved request streams.
        let s = ShadowSampler::new(0.5);
        let pattern: Vec<bool> = (0..1000).map(|_| s.take()).collect();
        assert!(pattern.iter().step_by(2).any(|&b| b), "even offsets never sampled");
        assert!(pattern.iter().skip(1).step_by(2).any(|&b| b), "odd offsets never sampled");
        // And coverage has no pathological holes (measured max gap is 10).
        let mut gap = 0usize;
        let mut max_gap = 0usize;
        for &b in &pattern {
            if b {
                max_gap = max_gap.max(gap);
                gap = 0;
            } else {
                gap += 1;
            }
        }
        assert!(max_gap <= 16, "max un-sampled run {max_gap}");
    }
}
