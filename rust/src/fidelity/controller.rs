//! Adaptive precision controller: turn a per-request SLO into a concrete
//! `(scheme, k)` serving configuration.
//!
//! A `"scheme":"auto"` request carries a `max_mse` error budget, a
//! `max_latency_us` latency budget, or both, instead of a hand-picked
//! configuration. The controller walks the candidate grid in **measured
//! cost order**: candidates ranked by their measured recent latency (the
//! per-`(model, k)` and per-scheme serving windows, combined through
//! [`LatencyView`]), with the static cost order — lowest bit width first;
//! at equal width the paper's trio in cheap-first order (deterministic
//! needs no randomness, dither one table lookup per element, stochastic a
//! hash per element), then the literature zoo — as the cold-start
//! tiebreak. The first candidate that satisfies every budget wins, so a
//! fully cold process behaves exactly like the historic static walk and a
//! warm one serves the cheapest configuration *as measured*, not as
//! assumed. Every registered scheme is a candidate, so the whole zoo
//! competes in auto resolution.
//!
//! The MSE prediction for a candidate is the measured shadow-sampling
//! estimate once its cell has accrued [`MIN_SAMPLES`] logit errors, and
//! each scheme's own [`crate::rounding::Rounding::mse_prior`] before that
//! — `Θ(1/N²)` shapes for the deterministic/dithered schemes, `Ω(1/N)`
//! for the stochastic family, in the quantizer resolution `N = 2^k − 1`
//! (§II-C/§VII — the prior only has to rank candidates sanely until real
//! measurements take over; El Arar 2022 and Xia 2020 both show the true
//! constants are workload-dependent, which is exactly what the online
//! estimator captures).
//!
//! The choice is a pure function of `(budget, estimate table, latency
//! view)` — no randomness, no clocks — so replaying traffic against the
//! same snapshot ([`AutoSnapshot`]) reproduces every auto decision. The
//! serving stack refreshes one merged snapshot per process on a short
//! cadence (see `coordinator::shard`), published through [`AutoView`], so
//! every shard converges to the same auto view.

use crate::fidelity::estimator::{EstimateTable, FidelityShard, MAX_K, MODEL_SLOTS};
use crate::rounding::SchemeId;
use std::sync::{Arc, Mutex};

/// Shadow samples a `(model, scheme, k)` cell needs before its measured
/// MSE replaces the prior (≈ a few dozen shadowed requests at 10 logits
/// each — enough to damp single-image noise without starving cold
/// configurations of measurements for long).
pub const MIN_SAMPLES: u64 = 256;

/// Latency samples a recent window needs before its percentile counts as
/// a measurement; below this the candidate is latency-cold and keeps its
/// static-order position (a handful of requests must not reorder the
/// walk on noise).
pub const LATENCY_MIN_SAMPLES: u64 = 32;

/// Contraction length assumed by the prior (the models' 784-wide input
/// layer dominates every forward pass).
const PRIOR_CONTRACTION: f64 = 784.0;

/// In the infeasible-budget fallback, a prior-only candidate displaces a
/// measured one only when the prior is decisively better — more than this
/// factor below the measured MSE. At comparable predicted MSE the
/// measured candidate wins: priors are optimistic by construction
/// (contraction-averaged), so trusting one over a live measurement it
/// merely edges out re-serves exactly the stale-prior bug this guards.
const FALLBACK_PRIOR_MARGIN: f64 = 4.0;

/// Candidate schemes in ascending serving-cost order at a fixed `k`: the
/// paper's trio first (cheapest machinery wins budget ties exactly as
/// before the zoo existed), then the literature schemes in slot order.
const COST_ORDER: [SchemeId; SchemeId::COUNT] = [
    SchemeId::Deterministic,
    SchemeId::Dither,
    SchemeId::Stochastic,
    SchemeId::Sr2,
    SchemeId::SrVb,
    SchemeId::Tpdf,
    SchemeId::Gauss,
];

/// The controller's verdict for one auto request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoChoice {
    /// Chosen rounding scheme.
    pub scheme: SchemeId,
    /// Chosen bit width.
    pub k: u32,
    /// The MSE prediction the choice was based on.
    pub predicted_mse: f64,
    /// True when the MSE prediction came from shadow measurements rather
    /// than the prior.
    pub measured: bool,
    /// The measured recent-latency estimate the choice was priced at
    /// (`None` when the candidate was latency-cold).
    pub predicted_latency_us: Option<u64>,
    /// False when no candidate met every declared budget and this choice
    /// is the least-bad fallback — the SLO evaluator's
    /// `auto_infeasible` signal counts these.
    pub feasible: bool,
}

impl AutoChoice {
    /// True when any axis of the choice was backed by live measurements
    /// (a warm MSE cell or a warm latency window) — what the reply's
    /// `"measured"` flag echoes.
    pub fn any_measured(&self) -> bool {
        self.measured || self.predicted_latency_us.is_some()
    }
}

/// The per-request SLO an auto request carries: at least one axis must be
/// present (the protocol rejects budget-less autos).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloBudget {
    /// Error budget: highest acceptable predicted MSE. `None` means
    /// unbounded — only legal alongside a latency budget.
    pub max_mse: Option<f64>,
    /// Latency budget in microseconds against the measured recent
    /// windows. Latency-cold candidates pass optimistically (cold-start
    /// must be able to serve).
    pub max_latency_us: Option<u64>,
}

impl SloBudget {
    /// An error-only budget (the historic auto request shape).
    pub fn mse(max_mse: f64) -> SloBudget {
        SloBudget {
            max_mse: Some(max_mse),
            max_latency_us: None,
        }
    }
}

/// A snapshot of the measured recent-latency surface the controller walks:
/// one `(samples, p50_us)` pair per `(model, k)` serving window and one
/// per scheme window. Plain data — built by the coordinator's metrics
/// (`MetricsHandle::auto_snapshot`) from the raw rotating windows, merged
/// across shards at fold time, then handed to [`choose_slo`] by value so
/// the choice stays replayable.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyView {
    /// `(samples, p50_us)` per model slot × k (flat, `MODEL_SLOTS × MAX_K`).
    model_k: Vec<(u64, u64)>,
    /// `(samples, p50_us)` per registered scheme slot.
    schemes: Vec<(u64, u64)>,
}

impl Default for LatencyView {
    fn default() -> Self {
        Self::empty()
    }
}

impl LatencyView {
    /// A view with every window cold — the cold-start walk is exactly the
    /// static cost order.
    pub fn empty() -> LatencyView {
        LatencyView {
            model_k: vec![(0, 0); MODEL_SLOTS * MAX_K as usize],
            schemes: vec![(0, 0); SchemeId::COUNT],
        }
    }

    fn mk_index(model: usize, k: u32) -> Option<usize> {
        if model >= MODEL_SLOTS || !(1..=MAX_K).contains(&k) {
            return None;
        }
        Some(model * MAX_K as usize + (k - 1) as usize)
    }

    /// Set one `(model, k)` window's fold (out-of-space labels ignored).
    pub fn set_model_k(&mut self, model: usize, k: u32, samples: u64, p50_us: u64) {
        if let Some(i) = LatencyView::mk_index(model, k) {
            self.model_k[i] = (samples, p50_us);
        }
    }

    /// Set one scheme window's fold.
    pub fn set_scheme(&mut self, mode: SchemeId, samples: u64, p50_us: u64) {
        self.schemes[mode.slot()] = (samples, p50_us);
    }

    /// Measured p50 for a `(model, k)` window, `None` until it has
    /// [`LATENCY_MIN_SAMPLES`] samples.
    pub fn model_k_latency(&self, model: usize, k: u32) -> Option<u64> {
        let (n, p50) = LatencyView::mk_index(model, k).map(|i| self.model_k[i])?;
        (n >= LATENCY_MIN_SAMPLES).then_some(p50)
    }

    /// Measured p50 for a scheme window, `None` until warm.
    pub fn scheme_latency(&self, mode: SchemeId) -> Option<u64> {
        let (n, p50) = self.schemes[mode.slot()];
        (n >= LATENCY_MIN_SAMPLES).then_some(p50)
    }

    /// The composite measured-latency estimate for one candidate: the
    /// worse of its `(model, k)` window and its scheme window (either
    /// alone when only one is warm, `None` when both are cold). Taking
    /// the max is conservative: a candidate is only priced fast when
    /// nothing measured about it says slow.
    pub fn latency_estimate(&self, model: usize, mode: SchemeId, k: u32) -> Option<u64> {
        match (self.model_k_latency(model, k), self.scheme_latency(mode)) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        }
    }
}

/// The merged per-process snapshot auto resolution prices against:
/// estimates and latency folded across every shard at one instant.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AutoSnapshot {
    /// Merged `(model, scheme, k)` error estimates.
    pub estimates: EstimateTable,
    /// Merged recent-latency surface.
    pub latency: LatencyView,
}

impl AutoSnapshot {
    /// A fully cold snapshot (process start: priors and static order).
    pub fn empty() -> AutoSnapshot {
        AutoSnapshot::default()
    }
}

/// The shared, periodically refreshed [`AutoSnapshot`] all shards of one
/// process resolve against. Readers clone an `Arc` under a short lock;
/// the refresher swaps in a new snapshot wholesale, so a resolution never
/// sees a half-updated view.
#[derive(Debug)]
pub struct AutoView {
    current: Mutex<Arc<AutoSnapshot>>,
}

impl Default for AutoView {
    fn default() -> Self {
        AutoView::new(AutoSnapshot::empty())
    }
}

impl AutoView {
    /// A view seeded with `snapshot`.
    pub fn new(snapshot: AutoSnapshot) -> AutoView {
        AutoView {
            current: Mutex::new(Arc::new(snapshot)),
        }
    }

    /// The current snapshot (cheap: one `Arc` clone under the lock).
    pub fn load(&self) -> Arc<AutoSnapshot> {
        self.current
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .clone()
    }

    /// Publish a fresh snapshot.
    pub fn store(&self, snapshot: AutoSnapshot) {
        *self
            .current
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner) = Arc::new(snapshot);
    }
}

/// Prior MSE of a `(scheme, k)` candidate: per-logit error of a `q`-long
/// contraction whose factors are rounded on a step of `2/(2^k−1)`. The
/// shape comes from the scheme's own registry entry
/// ([`crate::rounding::Rounding::mse_prior`]), so newly registered schemes
/// are ranked without touching the controller.
pub fn prior_mse(mode: SchemeId, k: u32) -> f64 {
    let levels = ((1u64 << k.min(MAX_K)) - 1) as f64;
    let step = 2.0 / levels;
    crate::rounding::SchemeRegistry::global()
        .get(mode)
        .mse_prior(step, PRIOR_CONTRACTION)
}

/// Predicted MSE for one candidate against a live shard: measured
/// estimate once warm, prior until then. Returns `(mse, measured)`.
pub fn predicted_mse(
    shard: &FidelityShard,
    model: usize,
    mode: SchemeId,
    k: u32,
) -> (f64, bool) {
    let est = shard.estimate(model, mode, k);
    if est.samples >= MIN_SAMPLES {
        (est.mse(), true)
    } else {
        (prior_mse(mode, k), false)
    }
}

/// In the infeasible-budget fallback, is `c` a better least-bad answer
/// than `b`? Same measurement axis: lower predicted MSE wins (strictly —
/// ties keep the earlier, cheaper-walking candidate). Across axes the
/// measured candidate wins unless the prior undercuts it by more than
/// [`FALLBACK_PRIOR_MARGIN`].
fn fallback_better(c: &AutoChoice, b: &AutoChoice) -> bool {
    match (c.measured, b.measured) {
        (true, false) => c.predicted_mse < b.predicted_mse * FALLBACK_PRIOR_MARGIN,
        (false, true) => c.predicted_mse * FALLBACK_PRIOR_MARGIN < b.predicted_mse,
        _ => c.predicted_mse < b.predicted_mse,
    }
}

/// Resolve one auto request against a snapshot: walk the candidate grid
/// in measured-latency order (static cost order breaking cold and equal
/// ties) and pick the first candidate meeting every budget.
///
/// When no candidate meets the budgets (the error budget is tighter than
/// anything the grid can deliver, or non-finite), the most accurate
/// candidate wins — measured cells preferred over comparable priors (see
/// [`fallback_better`]), remaining ties broken toward the cheaper walk
/// position — so the result is still deterministic given the snapshot.
pub fn choose_slo(
    table: &EstimateTable,
    view: &LatencyView,
    model: usize,
    budget: SloBudget,
) -> AutoChoice {
    // The full grid with its walk key: measured latency first (cold =
    // u64::MAX, i.e. after every measured candidate), static rank second.
    let mut grid: Vec<(u64, usize, AutoChoice)> =
        Vec::with_capacity(MAX_K as usize * COST_ORDER.len());
    let mut rank = 0usize;
    for k in 1..=MAX_K {
        for &mode in &COST_ORDER {
            let est = table.get(model, mode, k);
            let (mse, measured) = if est.samples >= MIN_SAMPLES {
                (est.mse(), true)
            } else {
                (prior_mse(mode, k), false)
            };
            let latency = view.latency_estimate(model, mode, k);
            let choice = AutoChoice {
                scheme: mode,
                k,
                predicted_mse: mse,
                measured,
                predicted_latency_us: latency,
                feasible: true,
            };
            grid.push((latency.unwrap_or(u64::MAX), rank, choice));
            rank += 1;
        }
    }
    grid.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    // An absent error budget is only legal alongside a latency budget
    // (enforced at parse and resolve time); infinity is then correct.
    let mse_budget = budget.max_mse.unwrap_or(f64::INFINITY);
    let mut best: Option<AutoChoice> = None;
    for &(_, _, c) in &grid {
        let latency_ok = match (budget.max_latency_us, c.predicted_latency_us) {
            (Some(budget_us), Some(measured_us)) => measured_us <= budget_us,
            // No latency budget, or a latency-cold candidate: pass — a
            // cold start must be able to serve under any budget.
            _ => true,
        };
        if latency_ok && c.predicted_mse <= mse_budget {
            return c;
        }
        if best.as_ref().is_none_or(|b| fallback_better(&c, b)) {
            best = Some(c);
        }
    }
    let mut fallback = best.expect("the candidate grid is never empty");
    fallback.feasible = false;
    fallback
}

/// Pick the cheapest `(scheme, k)` whose predicted MSE meets `max_mse`
/// against one live shard with no latency view — the historic error-only
/// entry point ([`choose_slo`] with a cold latency surface, so the walk
/// is exactly the static cost order).
pub fn choose(shard: &FidelityShard, model: usize, max_mse: f64) -> AutoChoice {
    choose_slo(
        &EstimateTable::from_shard(shard),
        &LatencyView::empty(),
        model,
        SloBudget::mse(max_mse),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_has_the_paper_shape() {
        // Every registered scheme's prior falls with finer quantizers:
        // 1/N² shapes for the deterministic/dithered schemes, 1/N for the
        // stochastic family.
        for k in 1..MAX_K {
            for mode in SchemeId::ALL {
                assert!(prior_mse(mode, k + 1) < prior_mse(mode, k), "{mode:?} k={k}");
            }
        }
        let det_ratio = prior_mse(SchemeId::Deterministic, 4)
            / prior_mse(SchemeId::Deterministic, 5);
        let sto_ratio =
            prior_mse(SchemeId::Stochastic, 4) / prior_mse(SchemeId::Stochastic, 5);
        assert!(det_ratio > sto_ratio * 1.5, "det {det_ratio} vs sto {sto_ratio}");
        // At matched k the unbiased-but-slow stochastic prior is worst.
        assert!(prior_mse(SchemeId::Stochastic, 6) > prior_mse(SchemeId::Dither, 6));
    }

    #[test]
    fn loose_budget_picks_the_cheapest_candidate() {
        let shard = FidelityShard::new();
        let c = choose(&shard, 0, 1e12);
        assert_eq!((c.scheme, c.k), (SchemeId::Deterministic, 1));
        assert!(!c.measured);
        assert!(c.feasible);
        assert_eq!(c.predicted_latency_us, None);
    }

    #[test]
    fn tighter_budgets_buy_more_bits() {
        let shard = FidelityShard::new();
        let loose = choose(&shard, 0, 10.0);
        let tight = choose(&shard, 0, 1e-4);
        assert!(tight.k > loose.k, "tight {tight:?} vs loose {loose:?}");
        assert!(tight.predicted_mse <= 1e-4);
        // An impossible budget falls back to the most accurate candidate,
        // flagged infeasible; satisfiable budgets are flagged feasible.
        let impossible = choose(&shard, 0, 1e-12);
        assert_eq!(impossible.k, MAX_K);
        assert!(impossible.predicted_mse > 1e-12);
        assert!(!impossible.feasible);
        assert!(loose.feasible && tight.feasible);
    }

    #[test]
    fn measured_estimates_override_the_prior() {
        // The fallback-prior → measured-estimate handoff, locked: on a
        // cold estimator the cheapest prior-feasible candidate wins; once
        // shadow samples show that candidate blowing its budget while a
        // costlier one meets it, the choice must move.
        let shard = FidelityShard::new();
        let budget = prior_mse(SchemeId::Deterministic, 1) * 1.01;
        let cold = choose(&shard, 0, budget);
        assert_eq!((cold.scheme, cold.k), (SchemeId::Deterministic, 1));
        assert!(!cold.measured, "cold choice must come from the prior");
        // Measure deterministic k=1 as terrible and dither k=1 as tiny.
        for i in 0..MIN_SAMPLES {
            shard.record(0, SchemeId::Deterministic, 1, 1000.0 + (i % 3) as f64);
            let small = if i % 2 == 0 { 0.01 } else { -0.01 };
            shard.record(0, SchemeId::Dither, 1, small);
        }
        let warm = choose(&shard, 0, budget);
        assert_eq!((warm.scheme, warm.k), (SchemeId::Dither, 1), "{warm:?}");
        assert!(warm.measured, "warm choice must come from measurements");
        // Deterministic given the estimator state: same state, same choice.
        assert_eq!(warm, choose(&shard, 0, budget));
    }

    #[test]
    fn one_sample_short_of_warm_still_uses_the_prior() {
        let shard = FidelityShard::new();
        for _ in 0..MIN_SAMPLES - 1 {
            shard.record(0, SchemeId::Deterministic, 1, 1e6);
        }
        let budget = prior_mse(SchemeId::Deterministic, 1) * 1.01;
        let c = choose(&shard, 0, budget);
        assert_eq!(
            (c.scheme, c.k, c.measured),
            (SchemeId::Deterministic, 1, false)
        );
        shard.record(0, SchemeId::Deterministic, 1, 1e6);
        let c = choose(&shard, 0, budget);
        assert_ne!(
            (c.scheme, c.k),
            (SchemeId::Deterministic, 1),
            "crossing MIN_SAMPLES must flip the cell to measured"
        );
    }

    #[test]
    fn infeasible_fallback_prefers_measured_over_comparable_prior() {
        // Regression for the one-axis fallback compare: under an
        // impossible budget the old walk returned the candidate with the
        // lowest *predicted* MSE, so the grid's most optimistic cold
        // prior beat a live measurement it only marginally undercut. The
        // fixed fallback keeps the measured candidate at comparable
        // predicted MSE.
        let shard = FidelityShard::new();
        let best_prior = COST_ORDER
            .iter()
            .map(|&m| prior_mse(m, MAX_K))
            .fold(f64::INFINITY, f64::min);
        // Warm one cell to 1.5× the best prior on the grid: worse than
        // the prior on the raw axis, comparable under the margin.
        let err = (1.5 * best_prior).sqrt();
        for i in 0..MIN_SAMPLES {
            let signed = if i % 2 == 0 { err } else { -err };
            shard.record(0, SchemeId::Dither, MAX_K, signed);
        }
        let c = choose(&shard, 0, 1e-300);
        assert_eq!(
            (c.scheme, c.k, c.measured),
            (SchemeId::Dither, MAX_K, true),
            "stale-prior candidate won the fallback again: {c:?}"
        );
        assert!(!c.feasible, "fallback choices must be flagged infeasible");
        assert!((c.predicted_mse - 1.5 * best_prior).abs() < best_prior * 0.01);
    }

    #[test]
    fn latency_budget_walks_measured_candidates_first() {
        // Cold estimates, warm latency: deterministic measured slow, the
        // dither scheme window and the (model 0, k=2) window measured
        // fast. A latency-budgeted request must skip the statically
        // cheapest (deterministic) candidate for the measured-fast one.
        let table = EstimateTable::empty();
        let mut view = LatencyView::empty();
        view.set_model_k(0, 2, LATENCY_MIN_SAMPLES, 100);
        view.set_scheme(SchemeId::Dither, LATENCY_MIN_SAMPLES, 100);
        view.set_scheme(SchemeId::Deterministic, LATENCY_MIN_SAMPLES, 50_000);
        let budget = SloBudget {
            max_mse: Some(1e9),
            max_latency_us: Some(10_000),
        };
        let c = choose_slo(&table, &view, 0, budget);
        assert_eq!(c.scheme, SchemeId::Dither, "{c:?}");
        assert_eq!(c.predicted_latency_us, Some(100), "{c:?}");
        assert!(c.any_measured());
        // Below the warm threshold the same numbers change nothing: the
        // walk is static again and deterministic k=1 wins.
        let mut cold = LatencyView::empty();
        cold.set_scheme(SchemeId::Deterministic, LATENCY_MIN_SAMPLES - 1, 50_000);
        let c = choose_slo(&table, &cold, 0, budget);
        assert_eq!((c.scheme, c.k), (SchemeId::Deterministic, 1), "{c:?}");
        assert_eq!(c.predicted_latency_us, None);
    }

    #[test]
    fn latency_only_budget_serves_from_a_cold_start() {
        // max_mse absent is legal when a latency budget is present; on a
        // fully cold snapshot the walk is the static order and the
        // cheapest candidate serves (cold candidates pass the latency
        // check optimistically).
        let snap = AutoSnapshot::empty();
        let budget = SloBudget {
            max_mse: None,
            max_latency_us: Some(500),
        };
        let c = choose_slo(&snap.estimates, &snap.latency, 0, budget);
        assert_eq!((c.scheme, c.k), (SchemeId::Deterministic, 1));
        assert!(!c.any_measured());
    }

    #[test]
    fn cold_view_reduces_to_the_static_cost_walk() {
        // With an empty latency view, choose_slo over an error budget is
        // exactly the historic static walk for any budget.
        let shard = FidelityShard::new();
        for i in 0..MIN_SAMPLES {
            let signed = if i % 2 == 0 { 0.05 } else { -0.05 };
            shard.record(0, SchemeId::Tpdf, 3, signed);
        }
        let table = EstimateTable::from_shard(&shard);
        let view = LatencyView::empty();
        for budget in [1e12, 10.0, 1e-2, 1e-4, 1e-7, 1e-12] {
            let a = choose(&shard, 0, budget);
            let b = choose_slo(&table, &view, 0, SloBudget::mse(budget));
            assert_eq!(a, b, "budget {budget}");
        }
    }

    #[test]
    fn replaying_budgets_against_a_snapshot_reproduces_every_choice() {
        // The determinism contract auto resolution rests on: a choice is
        // a pure function of (budget, snapshot), so replaying a traffic
        // mix against the same snapshotted estimator + latency view
        // reproduces every decision — and rebuilding the snapshot from
        // the unchanged shard changes nothing either.
        let shard = FidelityShard::new();
        for i in 0..MIN_SAMPLES {
            let e = ((i * 37 + 11) % 100) as f64 / 500.0 - 0.1;
            shard.record(0, SchemeId::Dither, 4, e);
            shard.record(0, SchemeId::Stochastic, 2, e * 3.0);
        }
        let mut view = LatencyView::empty();
        view.set_model_k(0, 2, 64, 180);
        view.set_model_k(0, 4, 64, 420);
        view.set_scheme(SchemeId::Dither, 64, 200);
        view.set_scheme(SchemeId::Stochastic, 64, 900);
        let table = EstimateTable::from_shard(&shard);
        // A deterministic pseudo-random budget mix over both axes.
        let budgets: Vec<SloBudget> = (0..200u64)
            .map(|i| {
                let h = i.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
                SloBudget {
                    max_mse: (h % 3 != 0).then(|| 10f64.powi((h % 13) as i32 - 9)),
                    max_latency_us: (h % 3 != 1).then_some(10 + (h % 2000)),
                }
            })
            .collect();
        let first: Vec<AutoChoice> =
            budgets.iter().map(|&b| choose_slo(&table, &view, 0, b)).collect();
        let replay: Vec<AutoChoice> =
            budgets.iter().map(|&b| choose_slo(&table, &view, 0, b)).collect();
        assert_eq!(first, replay);
        let rebuilt = EstimateTable::from_shard(&shard);
        let again: Vec<AutoChoice> =
            budgets.iter().map(|&b| choose_slo(&rebuilt, &view, 0, b)).collect();
        assert_eq!(first, again);
    }
}
