//! Adaptive precision controller: turn a per-request error budget into a
//! concrete `(scheme, k)` serving configuration.
//!
//! A `"scheme":"auto"` request carries a `max_mse` budget instead of a
//! hand-picked configuration. The controller walks the candidate grid in
//! **cost order** (lowest bit width first; at equal width the cheaper
//! rounding machinery first — deterministic needs no randomness, dither
//! one table lookup per element, stochastic a hash per element) and picks
//! the first candidate whose *predicted* MSE meets the budget.
//!
//! The prediction for a candidate is the shard's measured shadow-sampling
//! estimate once it has accrued [`MIN_SAMPLES`] logit errors, and the
//! paper-shape prior before that: deterministic and dither rounding have
//! `Θ(1/N²)` MSE and stochastic rounding `Ω(1/N)` in the quantizer
//! resolution `N = 2^k − 1` (§II-C/§VII — the prior only has to rank
//! candidates sanely until real measurements take over; El Arar 2022 and
//! Xia 2020 both show the true constants are workload-dependent, which is
//! exactly what the online estimator captures).
//!
//! The choice is a pure function of `(budget, estimator state)` — no
//! randomness, no clocks — so replaying traffic against the same
//! estimator state reproduces every auto decision.

use crate::fidelity::estimator::{FidelityShard, MAX_K};
use crate::rounding::RoundingMode;

/// Shadow samples a `(model, scheme, k)` cell needs before its measured
/// MSE replaces the prior (≈ a few dozen shadowed requests at 10 logits
/// each — enough to damp single-image noise without starving cold
/// configurations of measurements for long).
pub const MIN_SAMPLES: u64 = 256;

/// Contraction length assumed by the prior (the models' 784-wide input
/// layer dominates every forward pass).
const PRIOR_CONTRACTION: f64 = 784.0;

/// Candidate schemes in ascending serving-cost order at a fixed `k`.
const COST_ORDER: [RoundingMode; 3] = [
    RoundingMode::Deterministic,
    RoundingMode::Dither,
    RoundingMode::Stochastic,
];

/// The controller's verdict for one auto request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoChoice {
    /// Chosen rounding scheme.
    pub mode: RoundingMode,
    /// Chosen bit width.
    pub k: u32,
    /// The MSE prediction the choice was based on.
    pub predicted_mse: f64,
    /// True when the prediction came from shadow measurements rather than
    /// the prior.
    pub measured: bool,
}

/// Paper-shape prior MSE of a `(scheme, k)` candidate: per-logit error of
/// a `q`-long contraction whose factors are rounded on a step of
/// `2/(2^k−1)` — `∝ step²` for the deterministic/dither schemes, `∝ step`
/// for stochastic rounding.
pub fn prior_mse(mode: RoundingMode, k: u32) -> f64 {
    let levels = ((1u64 << k.min(MAX_K)) - 1) as f64;
    let step = 2.0 / levels;
    match mode {
        RoundingMode::Stochastic => PRIOR_CONTRACTION * step / 6.0,
        _ => PRIOR_CONTRACTION * step * step / 6.0,
    }
}

/// Predicted MSE for one candidate: measured estimate once warm, prior
/// until then. Returns `(mse, measured)`.
pub fn predicted_mse(
    shard: &FidelityShard,
    model: usize,
    mode: RoundingMode,
    k: u32,
) -> (f64, bool) {
    let est = shard.estimate(model, mode, k);
    if est.samples >= MIN_SAMPLES {
        (est.mse(), true)
    } else {
        (prior_mse(mode, k), false)
    }
}

/// Pick the cheapest `(scheme, k)` whose predicted MSE meets `max_mse`.
///
/// When no candidate meets the budget (it is tighter than anything the
/// grid can deliver, or non-finite), the most accurate candidate wins —
/// ties broken toward the cheaper one, so the result is still
/// deterministic given the estimator state.
pub fn choose(shard: &FidelityShard, model: usize, max_mse: f64) -> AutoChoice {
    let mut best: Option<AutoChoice> = None;
    for k in 1..=MAX_K {
        for &mode in &COST_ORDER {
            let (mse, measured) = predicted_mse(shard, model, mode, k);
            let candidate = AutoChoice {
                mode,
                k,
                predicted_mse: mse,
                measured,
            };
            if mse <= max_mse {
                return candidate;
            }
            let better = match &best {
                None => true,
                Some(b) => mse < b.predicted_mse,
            };
            if better {
                best = Some(candidate);
            }
        }
    }
    best.expect("the candidate grid is never empty")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_has_the_paper_shape() {
        // Deterministic/dither priors fall as 1/N², stochastic as 1/N.
        for k in 1..MAX_K {
            for mode in RoundingMode::ALL {
                assert!(prior_mse(mode, k + 1) < prior_mse(mode, k), "{mode:?} k={k}");
            }
        }
        let det_ratio = prior_mse(RoundingMode::Deterministic, 4)
            / prior_mse(RoundingMode::Deterministic, 5);
        let sto_ratio =
            prior_mse(RoundingMode::Stochastic, 4) / prior_mse(RoundingMode::Stochastic, 5);
        assert!(det_ratio > sto_ratio * 1.5, "det {det_ratio} vs sto {sto_ratio}");
        // At matched k the unbiased-but-slow stochastic prior is worst.
        assert!(prior_mse(RoundingMode::Stochastic, 6) > prior_mse(RoundingMode::Dither, 6));
    }

    #[test]
    fn loose_budget_picks_the_cheapest_candidate() {
        let shard = FidelityShard::new();
        let c = choose(&shard, 0, 1e12);
        assert_eq!((c.mode, c.k), (RoundingMode::Deterministic, 1));
        assert!(!c.measured);
    }

    #[test]
    fn tighter_budgets_buy_more_bits() {
        let shard = FidelityShard::new();
        let loose = choose(&shard, 0, 10.0);
        let tight = choose(&shard, 0, 1e-4);
        assert!(tight.k > loose.k, "tight {tight:?} vs loose {loose:?}");
        assert!(tight.predicted_mse <= 1e-4);
        // An impossible budget falls back to the most accurate candidate.
        let impossible = choose(&shard, 0, 1e-12);
        assert_eq!(impossible.k, MAX_K);
        assert!(impossible.predicted_mse > 1e-12);
    }

    #[test]
    fn measured_estimates_override_the_prior() {
        // The fallback-prior → measured-estimate handoff, locked: on a
        // cold estimator the cheapest prior-feasible candidate wins; once
        // shadow samples show that candidate blowing its budget while a
        // costlier one meets it, the choice must move.
        let shard = FidelityShard::new();
        let budget = prior_mse(RoundingMode::Deterministic, 1) * 1.01;
        let cold = choose(&shard, 0, budget);
        assert_eq!((cold.mode, cold.k), (RoundingMode::Deterministic, 1));
        assert!(!cold.measured, "cold choice must come from the prior");
        // Measure deterministic k=1 as terrible and dither k=1 as tiny.
        for i in 0..MIN_SAMPLES {
            shard.record(0, RoundingMode::Deterministic, 1, 1000.0 + (i % 3) as f64);
            let small = if i % 2 == 0 { 0.01 } else { -0.01 };
            shard.record(0, RoundingMode::Dither, 1, small);
        }
        let warm = choose(&shard, 0, budget);
        assert_eq!((warm.mode, warm.k), (RoundingMode::Dither, 1), "{warm:?}");
        assert!(warm.measured, "warm choice must come from measurements");
        // Deterministic given the estimator state: same state, same choice.
        assert_eq!(warm, choose(&shard, 0, budget));
    }

    #[test]
    fn one_sample_short_of_warm_still_uses_the_prior() {
        let shard = FidelityShard::new();
        for _ in 0..MIN_SAMPLES - 1 {
            shard.record(0, RoundingMode::Deterministic, 1, 1e6);
        }
        let budget = prior_mse(RoundingMode::Deterministic, 1) * 1.01;
        let c = choose(&shard, 0, budget);
        assert_eq!((c.mode, c.k, c.measured), (RoundingMode::Deterministic, 1, false));
        shard.record(0, RoundingMode::Deterministic, 1, 1e6);
        let c = choose(&shard, 0, budget);
        assert_ne!(
            (c.mode, c.k),
            (RoundingMode::Deterministic, 1),
            "crossing MIN_SAMPLES must flip the cell to measured"
        );
    }
}
