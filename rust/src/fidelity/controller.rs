//! Adaptive precision controller: turn a per-request error budget into a
//! concrete `(scheme, k)` serving configuration.
//!
//! A `"scheme":"auto"` request carries a `max_mse` budget instead of a
//! hand-picked configuration. The controller walks the candidate grid in
//! **cost order** (lowest bit width first; at equal width the paper's
//! trio in cheap-first order — deterministic needs no randomness, dither
//! one table lookup per element, stochastic a hash per element — then the
//! literature zoo) and picks the first candidate whose *predicted* MSE
//! meets the budget. Every registered scheme is a candidate, so the whole
//! zoo competes in auto resolution.
//!
//! The prediction for a candidate is the shard's measured shadow-sampling
//! estimate once it has accrued [`MIN_SAMPLES`] logit errors, and each
//! scheme's own [`crate::rounding::Rounding::mse_prior`] before that —
//! `Θ(1/N²)` shapes for the deterministic/dithered schemes, `Ω(1/N)` for
//! the stochastic family, in the quantizer resolution `N = 2^k − 1`
//! (§II-C/§VII — the prior only has to rank candidates sanely until real
//! measurements take over; El Arar 2022 and Xia 2020 both show the true
//! constants are workload-dependent, which is exactly what the online
//! estimator captures).
//!
//! The choice is a pure function of `(budget, estimator state)` — no
//! randomness, no clocks — so replaying traffic against the same
//! estimator state reproduces every auto decision.

use crate::fidelity::estimator::{FidelityShard, MAX_K};
use crate::rounding::SchemeId;

/// Shadow samples a `(model, scheme, k)` cell needs before its measured
/// MSE replaces the prior (≈ a few dozen shadowed requests at 10 logits
/// each — enough to damp single-image noise without starving cold
/// configurations of measurements for long).
pub const MIN_SAMPLES: u64 = 256;

/// Contraction length assumed by the prior (the models' 784-wide input
/// layer dominates every forward pass).
const PRIOR_CONTRACTION: f64 = 784.0;

/// Candidate schemes in ascending serving-cost order at a fixed `k`: the
/// paper's trio first (cheapest machinery wins budget ties exactly as
/// before the zoo existed), then the literature schemes in slot order.
const COST_ORDER: [SchemeId; SchemeId::COUNT] = [
    SchemeId::Deterministic,
    SchemeId::Dither,
    SchemeId::Stochastic,
    SchemeId::Sr2,
    SchemeId::SrVb,
    SchemeId::Tpdf,
    SchemeId::Gauss,
];

/// The controller's verdict for one auto request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoChoice {
    /// Chosen rounding scheme.
    pub scheme: SchemeId,
    /// Chosen bit width.
    pub k: u32,
    /// The MSE prediction the choice was based on.
    pub predicted_mse: f64,
    /// True when the prediction came from shadow measurements rather than
    /// the prior.
    pub measured: bool,
}

/// Prior MSE of a `(scheme, k)` candidate: per-logit error of a `q`-long
/// contraction whose factors are rounded on a step of `2/(2^k−1)`. The
/// shape comes from the scheme's own registry entry
/// ([`crate::rounding::Rounding::mse_prior`]), so newly registered schemes
/// are ranked without touching the controller.
pub fn prior_mse(mode: SchemeId, k: u32) -> f64 {
    let levels = ((1u64 << k.min(MAX_K)) - 1) as f64;
    let step = 2.0 / levels;
    crate::rounding::SchemeRegistry::global()
        .get(mode)
        .mse_prior(step, PRIOR_CONTRACTION)
}

/// Predicted MSE for one candidate: measured estimate once warm, prior
/// until then. Returns `(mse, measured)`.
pub fn predicted_mse(
    shard: &FidelityShard,
    model: usize,
    mode: SchemeId,
    k: u32,
) -> (f64, bool) {
    let est = shard.estimate(model, mode, k);
    if est.samples >= MIN_SAMPLES {
        (est.mse(), true)
    } else {
        (prior_mse(mode, k), false)
    }
}

/// Pick the cheapest `(scheme, k)` whose predicted MSE meets `max_mse`.
///
/// When no candidate meets the budget (it is tighter than anything the
/// grid can deliver, or non-finite), the most accurate candidate wins —
/// ties broken toward the cheaper one, so the result is still
/// deterministic given the estimator state.
pub fn choose(shard: &FidelityShard, model: usize, max_mse: f64) -> AutoChoice {
    let mut best: Option<AutoChoice> = None;
    for k in 1..=MAX_K {
        for &mode in &COST_ORDER {
            let (mse, measured) = predicted_mse(shard, model, mode, k);
            let candidate = AutoChoice {
                scheme: mode,
                k,
                predicted_mse: mse,
                measured,
            };
            if mse <= max_mse {
                return candidate;
            }
            let better = match &best {
                None => true,
                Some(b) => mse < b.predicted_mse,
            };
            if better {
                best = Some(candidate);
            }
        }
    }
    best.expect("the candidate grid is never empty")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_has_the_paper_shape() {
        // Every registered scheme's prior falls with finer quantizers:
        // 1/N² shapes for the deterministic/dithered schemes, 1/N for the
        // stochastic family.
        for k in 1..MAX_K {
            for mode in SchemeId::ALL {
                assert!(prior_mse(mode, k + 1) < prior_mse(mode, k), "{mode:?} k={k}");
            }
        }
        let det_ratio = prior_mse(SchemeId::Deterministic, 4)
            / prior_mse(SchemeId::Deterministic, 5);
        let sto_ratio =
            prior_mse(SchemeId::Stochastic, 4) / prior_mse(SchemeId::Stochastic, 5);
        assert!(det_ratio > sto_ratio * 1.5, "det {det_ratio} vs sto {sto_ratio}");
        // At matched k the unbiased-but-slow stochastic prior is worst.
        assert!(prior_mse(SchemeId::Stochastic, 6) > prior_mse(SchemeId::Dither, 6));
    }

    #[test]
    fn loose_budget_picks_the_cheapest_candidate() {
        let shard = FidelityShard::new();
        let c = choose(&shard, 0, 1e12);
        assert_eq!((c.scheme, c.k), (SchemeId::Deterministic, 1));
        assert!(!c.measured);
    }

    #[test]
    fn tighter_budgets_buy_more_bits() {
        let shard = FidelityShard::new();
        let loose = choose(&shard, 0, 10.0);
        let tight = choose(&shard, 0, 1e-4);
        assert!(tight.k > loose.k, "tight {tight:?} vs loose {loose:?}");
        assert!(tight.predicted_mse <= 1e-4);
        // An impossible budget falls back to the most accurate candidate.
        let impossible = choose(&shard, 0, 1e-12);
        assert_eq!(impossible.k, MAX_K);
        assert!(impossible.predicted_mse > 1e-12);
    }

    #[test]
    fn measured_estimates_override_the_prior() {
        // The fallback-prior → measured-estimate handoff, locked: on a
        // cold estimator the cheapest prior-feasible candidate wins; once
        // shadow samples show that candidate blowing its budget while a
        // costlier one meets it, the choice must move.
        let shard = FidelityShard::new();
        let budget = prior_mse(SchemeId::Deterministic, 1) * 1.01;
        let cold = choose(&shard, 0, budget);
        assert_eq!((cold.scheme, cold.k), (SchemeId::Deterministic, 1));
        assert!(!cold.measured, "cold choice must come from the prior");
        // Measure deterministic k=1 as terrible and dither k=1 as tiny.
        for i in 0..MIN_SAMPLES {
            shard.record(0, SchemeId::Deterministic, 1, 1000.0 + (i % 3) as f64);
            let small = if i % 2 == 0 { 0.01 } else { -0.01 };
            shard.record(0, SchemeId::Dither, 1, small);
        }
        let warm = choose(&shard, 0, budget);
        assert_eq!((warm.scheme, warm.k), (SchemeId::Dither, 1), "{warm:?}");
        assert!(warm.measured, "warm choice must come from measurements");
        // Deterministic given the estimator state: same state, same choice.
        assert_eq!(warm, choose(&shard, 0, budget));
    }

    #[test]
    fn one_sample_short_of_warm_still_uses_the_prior() {
        let shard = FidelityShard::new();
        for _ in 0..MIN_SAMPLES - 1 {
            shard.record(0, SchemeId::Deterministic, 1, 1e6);
        }
        let budget = prior_mse(SchemeId::Deterministic, 1) * 1.01;
        let c = choose(&shard, 0, budget);
        assert_eq!(
            (c.scheme, c.k, c.measured),
            (SchemeId::Deterministic, 1, false)
        );
        shard.record(0, SchemeId::Deterministic, 1, 1e6);
        let c = choose(&shard, 0, budget);
        assert_ne!(
            (c.scheme, c.k),
            (SchemeId::Deterministic, 1),
            "crossing MIN_SAMPLES must flip the cell to measured"
        );
    }
}
