//! Online fidelity telemetry and adaptive precision control.
//!
//! The paper's core claim is statistical — deterministic rounding is
//! biased with `O(1/N²)` MSE, stochastic rounding is unbiased with
//! `Ω(1/N)` MSE, dither rounding gets both (unbiased *and* `Θ(1/N²)`) —
//! but a serving stack that merely executes the three schemes never shows
//! an operator any of it. This subsystem measures the claims in
//! production and closes the loop:
//!
//! * [`sampler`] — a deterministic-stride **shadow sampler** decides which
//!   requests also run the exact f64 forward pass next to the quantized
//!   one (`--shadow-rate`);
//! * [`estimator`] — per-shard, lock-free **streaming bias/variance/MSE
//!   estimators** (Welford cells) keyed by `(model, scheme, k)`, fed with
//!   per-logit errors by the engine's shadow path and merged across
//!   shards on every `stats` scrape;
//! * [`controller`] — the **adaptive precision controller** behind the
//!   `"scheme":"auto"` request mode: given a `max_mse` error budget, a
//!   `max_latency_us` latency budget, or both, it walks candidates in
//!   measured recent-latency order (static cost order as the cold-start
//!   tiebreak) and picks the first `(scheme, k)` meeting every budget,
//!   falling back to a paper-shape prior until enough shadow samples
//!   accrue. Estimator cells rotate over wall-clock epochs so a workload
//!   shift can't leave stale errors dominating, and every shard of one
//!   process resolves against a periodically merged [`AutoView`] snapshot.

pub mod controller;
pub mod estimator;
pub mod sampler;

pub use controller::{
    choose, choose_slo, predicted_mse, prior_mse, AutoChoice, AutoSnapshot, AutoView,
    LatencyView, SloBudget, LATENCY_MIN_SAMPLES, MIN_SAMPLES,
};
pub use estimator::{
    EstimateTable, FidelityEstimate, FidelityShard, EPOCH_SLOTS, MAX_K, MODEL_SLOTS,
};
pub use sampler::ShadowSampler;
