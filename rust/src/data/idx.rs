//! IDX-format loader (the MNIST/Fashion-MNIST container format).
//!
//! When the real datasets are available (`data/mnist/`, `data/fashion/`
//! holding the canonical `*-images-idx3-ubyte` / `*-labels-idx1-ubyte`
//! files, optionally gzipped), [`try_load_idx_pair`] loads them and the
//! experiments run on real data; otherwise the synthetic generators are
//! used. This keeps the repository runnable offline while staying faithful
//! to the paper when the data is present.

use crate::data::dataset::Dataset;
use crate::linalg::Matrix;
use std::io::Read;
use std::path::Path;

/// Magic numbers for the two IDX record types we read.
const MAGIC_IMAGES: u32 = 0x0000_0803;
const MAGIC_LABELS: u32 = 0x0000_0801;

/// Read a file, transparently gunzipping `.gz`.
fn read_maybe_gz(path: &Path) -> std::io::Result<Vec<u8>> {
    let raw = std::fs::read(path)?;
    if path.extension().map(|e| e == "gz").unwrap_or(false) {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(&raw[..]).read_to_end(&mut out)?;
        Ok(out)
    } else {
        Ok(raw)
    }
}

fn be_u32(b: &[u8], off: usize) -> Option<u32> {
    Some(u32::from_be_bytes([
        *b.get(off)?,
        *b.get(off + 1)?,
        *b.get(off + 2)?,
        *b.get(off + 3)?,
    ]))
}

/// Parse an IDX3 image file into an `n × (rows·cols)` matrix in [0,1].
pub fn parse_idx_images(bytes: &[u8]) -> Option<Matrix> {
    if be_u32(bytes, 0)? != MAGIC_IMAGES {
        return None;
    }
    let n = be_u32(bytes, 4)? as usize;
    let rows = be_u32(bytes, 8)? as usize;
    let cols = be_u32(bytes, 12)? as usize;
    let d = rows * cols;
    let pixels = bytes.get(16..16 + n * d)?;
    let data: Vec<f64> = pixels.iter().map(|&p| p as f64 / 255.0).collect();
    Some(Matrix::from_vec(n, d, data))
}

/// Parse an IDX1 label file.
pub fn parse_idx_labels(bytes: &[u8]) -> Option<Vec<u8>> {
    if be_u32(bytes, 0)? != MAGIC_LABELS {
        return None;
    }
    let n = be_u32(bytes, 4)? as usize;
    bytes.get(8..8 + n).map(|s| s.to_vec())
}

/// Find a file under `dir` whose name starts with `stem` (allowing `.gz`).
fn find_file(dir: &Path, stem: &str) -> Option<std::path::PathBuf> {
    for suffix in ["", ".gz"] {
        let p = dir.join(format!("{stem}{suffix}"));
        if p.exists() {
            return Some(p);
        }
    }
    None
}

/// Load one (images, labels) split.
fn load_split(dir: &Path, img_stem: &str, lbl_stem: &str) -> Option<Dataset> {
    let img_bytes = read_maybe_gz(&find_file(dir, img_stem)?).ok()?;
    let lbl_bytes = read_maybe_gz(&find_file(dir, lbl_stem)?).ok()?;
    let images = parse_idx_images(&img_bytes)?;
    let labels = parse_idx_labels(&lbl_bytes)?;
    if images.rows != labels.len() {
        return None;
    }
    Some(Dataset {
        images,
        labels,
        num_classes: 10,
    })
}

/// Try to load the canonical train/test IDX pairs from `dir`.
pub fn try_load_idx_pair(dir: &str) -> Option<(Dataset, Dataset)> {
    let dir = Path::new(dir);
    if !dir.is_dir() {
        return None;
    }
    let train = load_split(dir, "train-images-idx3-ubyte", "train-labels-idx1-ubyte")?;
    let test = load_split(dir, "t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte")?;
    Some((train, test))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_idx_images(n: usize, rows: usize, cols: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&MAGIC_IMAGES.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        b.extend_from_slice(&(rows as u32).to_be_bytes());
        b.extend_from_slice(&(cols as u32).to_be_bytes());
        for i in 0..n * rows * cols {
            b.push((i % 256) as u8);
        }
        b
    }

    fn fake_idx_labels(n: usize) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&MAGIC_LABELS.to_be_bytes());
        b.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            b.push((i % 10) as u8);
        }
        b
    }

    #[test]
    fn parse_roundtrip() {
        let imgs = parse_idx_images(&fake_idx_images(3, 28, 28)).unwrap();
        assert_eq!(imgs.rows, 3);
        assert_eq!(imgs.cols, 784);
        assert!((imgs.get(0, 255) - 255.0 / 255.0).abs() < 1e-12);
        let labels = parse_idx_labels(&fake_idx_labels(3)).unwrap();
        assert_eq!(labels, vec![0, 1, 2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut b = fake_idx_images(1, 2, 2);
        b[3] = 0x99;
        assert!(parse_idx_images(&b).is_none());
        assert!(parse_idx_labels(&b).is_none());
    }

    #[test]
    fn rejects_truncated() {
        let b = fake_idx_images(10, 28, 28);
        assert!(parse_idx_images(&b[..100]).is_none());
    }

    #[test]
    fn missing_dir_is_none() {
        assert!(try_load_idx_pair("/nonexistent/dir").is_none());
    }

    #[test]
    fn gz_roundtrip() {
        use std::io::Write;
        let raw = fake_idx_labels(5);
        let mut enc =
            flate2::write::GzEncoder::new(Vec::new(), flate2::Compression::fast());
        enc.write_all(&raw).unwrap();
        let gz = enc.finish().unwrap();
        let dir = std::env::temp_dir().join("dither_idx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels-test.gz");
        std::fs::write(&p, &gz).unwrap();
        let back = read_maybe_gz(&p).unwrap();
        assert_eq!(back, raw);
        let _ = std::fs::remove_file(&p);
    }
}
