//! Labeled image datasets: container, procedural generators, and the
//! real-data escape hatch (IDX files are used automatically when present).

use crate::data::idx;
use crate::data::synth_digits::render_digit;
use crate::data::synth_fashion::render_fashion;
use crate::linalg::Matrix;
use crate::util::rng::Xoshiro256pp;
use crate::util::threadpool::parallel_map;

/// A labeled image dataset; images are rows of an `n × d` matrix with pixel
/// values in [0, 1].
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `n × d` image matrix (row per sample).
    pub images: Matrix,
    /// `n` class labels.
    pub labels: Vec<u8>,
    /// Number of classes.
    pub num_classes: usize,
}

/// Which evaluation task to generate (DESIGN.md §4 substitutions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// MNIST-class: synth-digits, or real MNIST when IDX files exist.
    Digits,
    /// Fashion-class: synth-fashion, or real Fashion-MNIST when present.
    Fashion,
}

impl Task {
    /// Display name.
    pub fn name(&self) -> &'static str {
        match self {
            Task::Digits => "digits",
            Task::Fashion => "fashion",
        }
    }

    /// Directory searched for real IDX files.
    pub fn idx_dir(&self) -> &'static str {
        match self {
            Task::Digits => "data/mnist",
            Task::Fashion => "data/fashion",
        }
    }
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Generate a synthetic dataset of `n` samples with balanced classes.
    pub fn synthesize(task: Task, n: usize, seed: u64) -> Dataset {
        let indices: Vec<usize> = (0..n).collect();
        let rows = parallel_map(&indices, |_, &i| {
            let mut rng = Xoshiro256pp::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let label = (i % 10) as u8;
            let img = match task {
                Task::Digits => render_digit(label, &mut rng),
                Task::Fashion => render_fashion(label, &mut rng),
            };
            (img, label)
        });
        let d = rows.first().map(|(img, _)| img.len()).unwrap_or(784);
        let mut images = Matrix::zeros(n, d);
        let mut labels = Vec::with_capacity(n);
        for (i, (img, label)) in rows.into_iter().enumerate() {
            images.row_mut(i).copy_from_slice(&img);
            labels.push(label);
        }
        // Shuffle sample order (labels were generated round-robin).
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Xoshiro256pp::new(seed ^ 0x5117FF1E);
        rng.shuffle(&mut order);
        let mut shuffled = Matrix::zeros(n, d);
        let mut shuffled_labels = Vec::with_capacity(n);
        for (new_i, &old_i) in order.iter().enumerate() {
            shuffled.row_mut(new_i).copy_from_slice(images.row(old_i));
            shuffled_labels.push(labels[old_i]);
        }
        Dataset {
            images: shuffled,
            labels: shuffled_labels,
            num_classes: 10,
        }
    }

    /// Load train+test for a task: real IDX data when available under
    /// `data/{mnist,fashion}/`, synthetic otherwise.
    ///
    /// Returns `(train, test, source_description)`.
    pub fn load_or_synthesize(
        task: Task,
        train_n: usize,
        test_n: usize,
        seed: u64,
    ) -> (Dataset, Dataset, &'static str) {
        if let Some((train, test)) = idx::try_load_idx_pair(task.idx_dir()) {
            return (train.truncated(train_n), test.truncated(test_n), "idx");
        }
        (
            Dataset::synthesize(task, train_n, seed),
            Dataset::synthesize(task, test_n, seed ^ 0x7E57),
            "synthetic",
        )
    }

    /// First `n` samples (all of them if `n >= len`).
    pub fn truncated(&self, n: usize) -> Dataset {
        let n = n.min(self.len());
        let d = self.images.cols;
        let mut images = Matrix::zeros(n, d);
        for i in 0..n {
            images.row_mut(i).copy_from_slice(self.images.row(i));
        }
        Dataset {
            images,
            labels: self.labels[..n].to_vec(),
            num_classes: self.num_classes,
        }
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesize_shapes_and_balance() {
        let ds = Dataset::synthesize(Task::Digits, 100, 1);
        assert_eq!(ds.len(), 100);
        assert_eq!(ds.images.rows, 100);
        assert_eq!(ds.images.cols, 784);
        let h = ds.class_histogram();
        assert!(h.iter().all(|&c| c == 10), "balanced classes: {h:?}");
    }

    #[test]
    fn shuffle_mixes_labels() {
        let ds = Dataset::synthesize(Task::Digits, 50, 2);
        // Not in round-robin order after shuffling.
        let round_robin: Vec<u8> = (0..50).map(|i| (i % 10) as u8).collect();
        assert_ne!(ds.labels, round_robin);
    }

    #[test]
    fn pixel_range_valid() {
        for task in [Task::Digits, Task::Fashion] {
            let ds = Dataset::synthesize(task, 30, 3);
            assert!(ds
                .images
                .data()
                .iter()
                .all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn truncated_keeps_prefix() {
        let ds = Dataset::synthesize(Task::Fashion, 40, 4);
        let t = ds.truncated(10);
        assert_eq!(t.len(), 10);
        assert_eq!(t.labels[..], ds.labels[..10]);
        assert_eq!(t.images.row(3), ds.images.row(3));
    }

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synthesize(Task::Digits, 20, 7);
        let b = Dataset::synthesize(Task::Digits, 20, 7);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
    }
}
