//! Tiny software rasterizer for the procedural datasets.
//!
//! Draws anti-aliased strokes (polylines, ellipse arcs) and filled polygons
//! into a 28×28 grayscale canvas, with per-sample affine jitter — enough
//! expressive power to synthesize digit-like and garment-like glyph classes
//! (DESIGN.md §4 substitution).

use crate::util::rng::Xoshiro256pp;

/// Square grayscale canvas with values in [0, 1].
#[derive(Clone, Debug)]
pub struct Canvas {
    /// Side length in pixels.
    pub size: usize,
    /// Row-major pixels.
    pub pixels: Vec<f64>,
}

/// An affine transform of the unit square (jitter: shift/scale/rotate).
#[derive(Clone, Copy, Debug)]
pub struct Affine {
    /// 2×2 linear part.
    pub m: [f64; 4],
    /// Translation.
    pub t: [f64; 2],
}

impl Affine {
    /// Identity transform.
    pub fn identity() -> Self {
        Self {
            m: [1.0, 0.0, 0.0, 1.0],
            t: [0.0, 0.0],
        }
    }

    /// Random jitter: rotation ≤ `max_rot` radians, scale in
    /// `[1-s, 1+s]`, translation ≤ `max_shift` (unit coords), all about the
    /// glyph center (0.5, 0.5).
    pub fn jitter(rng: &mut Xoshiro256pp, max_rot: f64, s: f64, max_shift: f64) -> Self {
        let theta = rng.uniform(-max_rot, max_rot);
        let scale_x = rng.uniform(1.0 - s, 1.0 + s);
        let scale_y = rng.uniform(1.0 - s, 1.0 + s);
        let (sin, cos) = theta.sin_cos();
        let m = [
            cos * scale_x,
            -sin * scale_y,
            sin * scale_x,
            cos * scale_y,
        ];
        let dx = rng.uniform(-max_shift, max_shift);
        let dy = rng.uniform(-max_shift, max_shift);
        // Keep (0.5, 0.5) fixed up to the translation jitter.
        let cx = 0.5 - (m[0] * 0.5 + m[1] * 0.5);
        let cy = 0.5 - (m[2] * 0.5 + m[3] * 0.5);
        Self {
            m,
            t: [cx + dx, cy + dy],
        }
    }

    /// Apply to a unit-space point.
    #[inline]
    pub fn apply(&self, p: [f64; 2]) -> [f64; 2] {
        [
            self.m[0] * p[0] + self.m[1] * p[1] + self.t[0],
            self.m[2] * p[0] + self.m[3] * p[1] + self.t[1],
        ]
    }
}

impl Canvas {
    /// Blank canvas.
    pub fn new(size: usize) -> Self {
        Self {
            size,
            pixels: vec![0.0; size * size],
        }
    }

    /// Deposit ink at a unit-space point with a Gaussian-ish splat of the
    /// given radius (in unit coords) and intensity.
    pub fn splat(&mut self, p: [f64; 2], radius: f64, intensity: f64) {
        let n = self.size as f64;
        let px = p[0] * n;
        let py = p[1] * n;
        let r = (radius * n).max(0.4);
        let lo_x = ((px - 2.0 * r).floor().max(0.0)) as usize;
        let hi_x = ((px + 2.0 * r).ceil().min(n - 1.0)) as usize;
        let lo_y = ((py - 2.0 * r).floor().max(0.0)) as usize;
        let hi_y = ((py + 2.0 * r).ceil().min(n - 1.0)) as usize;
        for y in lo_y..=hi_y {
            for x in lo_x..=hi_x {
                let dx = x as f64 + 0.5 - px;
                let dy = y as f64 + 0.5 - py;
                let d2 = (dx * dx + dy * dy) / (r * r);
                if d2 < 4.0 {
                    let v = intensity * (-d2).exp();
                    let cell = &mut self.pixels[y * self.size + x];
                    *cell = (*cell + v).min(1.0);
                }
            }
        }
    }

    /// Stroke a polyline given in unit coordinates.
    pub fn stroke(&mut self, path: &[[f64; 2]], xf: &Affine, thickness: f64) {
        for seg in path.windows(2) {
            let a = xf.apply(seg[0]);
            let b = xf.apply(seg[1]);
            let len = ((b[0] - a[0]).powi(2) + (b[1] - a[1]).powi(2)).sqrt();
            let steps = ((len * self.size as f64 * 2.0).ceil() as usize).max(1);
            for s in 0..=steps {
                let t = s as f64 / steps as f64;
                let p = [a[0] + t * (b[0] - a[0]), a[1] + t * (b[1] - a[1])];
                self.splat(p, thickness, 0.9);
            }
        }
    }

    /// Stroke an elliptical arc centered at `c` with radii `r`, from angle
    /// `a0` to `a1` (radians).
    pub fn arc(
        &mut self,
        c: [f64; 2],
        r: [f64; 2],
        a0: f64,
        a1: f64,
        xf: &Affine,
        thickness: f64,
    ) {
        let steps = 48;
        let pts: Vec<[f64; 2]> = (0..=steps)
            .map(|s| {
                let t = a0 + (a1 - a0) * s as f64 / steps as f64;
                [c[0] + r[0] * t.cos(), c[1] + r[1] * t.sin()]
            })
            .collect();
        self.stroke(&pts, xf, thickness);
    }

    /// Fill a convex polygon (unit coords) by scanline point-in-polygon.
    pub fn fill_polygon(&mut self, poly: &[[f64; 2]], xf: &Affine, intensity: f64) {
        let pts: Vec<[f64; 2]> = poly.iter().map(|&p| xf.apply(p)).collect();
        let n = self.size as f64;
        for y in 0..self.size {
            for x in 0..self.size {
                let p = [(x as f64 + 0.5) / n, (y as f64 + 0.5) / n];
                if point_in_polygon(p, &pts) {
                    let cell = &mut self.pixels[y * self.size + x];
                    *cell = (*cell + intensity).min(1.0);
                }
            }
        }
    }

    /// Add iid uniform noise in `[0, amp]` and clamp to [0,1].
    pub fn add_noise(&mut self, amp: f64, rng: &mut Xoshiro256pp) {
        for p in &mut self.pixels {
            *p = (*p + rng.uniform(0.0, amp)).clamp(0.0, 1.0);
        }
    }

    /// Multiplicative speckle texture (for the fashion classes).
    pub fn speckle(&mut self, depth: f64, rng: &mut Xoshiro256pp) {
        for p in &mut self.pixels {
            if *p > 0.05 {
                *p = (*p * rng.uniform(1.0 - depth, 1.0)).clamp(0.0, 1.0);
            }
        }
    }

    /// One pass of 3×3 box blur.
    pub fn blur(&mut self) {
        let s = self.size;
        let src = self.pixels.clone();
        for y in 0..s {
            for x in 0..s {
                let mut sum = 0.0;
                let mut cnt = 0.0;
                for dy in -1i64..=1 {
                    for dx in -1i64..=1 {
                        let ny = y as i64 + dy;
                        let nx = x as i64 + dx;
                        if ny >= 0 && ny < s as i64 && nx >= 0 && nx < s as i64 {
                            sum += src[ny as usize * s + nx as usize];
                            cnt += 1.0;
                        }
                    }
                }
                self.pixels[y * s + x] = sum / cnt;
            }
        }
    }
}

fn point_in_polygon(p: [f64; 2], poly: &[[f64; 2]]) -> bool {
    let mut inside = false;
    let n = poly.len();
    let mut j = n - 1;
    for i in 0..n {
        let (xi, yi) = (poly[i][0], poly[i][1]);
        let (xj, yj) = (poly[j][0], poly[j][1]);
        if ((yi > p[1]) != (yj > p[1]))
            && (p[0] < (xj - xi) * (p[1] - yi) / (yj - yi) + xi)
        {
            inside = !inside;
        }
        j = i;
    }
    inside
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blank_canvas_is_zero() {
        let c = Canvas::new(28);
        assert_eq!(c.pixels.len(), 784);
        assert!(c.pixels.iter().all(|&p| p == 0.0));
    }

    #[test]
    fn stroke_deposits_ink_along_line() {
        let mut c = Canvas::new(28);
        c.stroke(
            &[[0.2, 0.5], [0.8, 0.5]],
            &Affine::identity(),
            0.03,
        );
        // Ink at the midpoint row, none in the far corner.
        let mid = c.pixels[14 * 28 + 14];
        assert!(mid > 0.3, "mid={mid}");
        assert_eq!(c.pixels[0], 0.0);
    }

    #[test]
    fn fill_polygon_covers_interior() {
        let mut c = Canvas::new(28);
        c.fill_polygon(
            &[[0.2, 0.2], [0.8, 0.2], [0.8, 0.8], [0.2, 0.8]],
            &Affine::identity(),
            0.8,
        );
        assert!(c.pixels[14 * 28 + 14] > 0.5);
        assert_eq!(c.pixels[0], 0.0);
    }

    #[test]
    fn pixels_stay_in_unit_range() {
        let mut rng = Xoshiro256pp::new(1);
        let mut c = Canvas::new(28);
        for _ in 0..5 {
            c.stroke(&[[0.1, 0.1], [0.9, 0.9]], &Affine::identity(), 0.1);
        }
        c.add_noise(0.3, &mut rng);
        c.blur();
        assert!(c.pixels.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }

    #[test]
    fn jitter_is_bounded() {
        let mut rng = Xoshiro256pp::new(2);
        for _ in 0..50 {
            let xf = Affine::jitter(&mut rng, 0.2, 0.1, 0.05);
            let p = xf.apply([0.5, 0.5]);
            // Center moves at most by the shift bound.
            assert!((p[0] - 0.5).abs() <= 0.05 + 1e-9);
            assert!((p[1] - 0.5).abs() <= 0.05 + 1e-9);
        }
    }

    #[test]
    fn arc_draws_closed_circle() {
        let mut c = Canvas::new(28);
        c.arc(
            [0.5, 0.5],
            [0.3, 0.3],
            0.0,
            std::f64::consts::TAU,
            &Affine::identity(),
            0.03,
        );
        // Ink on the circle (right edge), hole in the center.
        assert!(c.pixels[14 * 28 + 22] > 0.2);
        assert!(c.pixels[14 * 28 + 14] < 0.1);
    }
}
