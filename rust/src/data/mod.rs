//! Datasets for the evaluation workloads: procedural synth-digits /
//! synth-fashion generators (DESIGN.md §4 substitutions for MNIST /
//! Fashion-MNIST), the IDX loader for real data when present, and the
//! rasterizer substrate.

pub mod dataset;
pub mod idx;
pub mod raster;
pub mod synth_digits;
pub mod synth_fashion;

pub use dataset::{Dataset, Task};
