//! "synth-fashion": a procedural 10-class Fashion-MNIST substitute.
//!
//! Garment-like filled silhouettes with speckle texture, rendered with the
//! same rasterizer as the digits but with *higher intra-class variance and
//! more inter-class overlap* (e.g. pullover / coat / shirt share the torso
//! silhouette; sneaker / sandal / ankle-boot share the sole) so the task is
//! measurably harder — matching the paper's observation (§VIII) that the
//! beneficial-k window narrows on the harder task.

use crate::data::raster::{Affine, Canvas};
use crate::util::rng::Xoshiro256pp;

/// Class names in Fashion-MNIST order (for reports).
pub const CLASS_NAMES: [&str; 10] = [
    "tshirt", "trouser", "pullover", "dress", "coat", "sandal", "shirt", "sneaker", "bag",
    "boot",
];

/// Render one sample of fashion class `label` (0–9) into 28×28 pixels.
pub fn render_fashion(label: u8, rng: &mut Xoshiro256pp) -> Vec<f64> {
    let mut c = Canvas::new(28);
    let xf = Affine::jitter(rng, 0.12, 0.16, 0.05);
    let fill = rng.uniform(0.55, 0.95);
    let w = rng.uniform(-0.04, 0.04); // width wobble shared by torso classes
    match label {
        // t-shirt: torso + short sleeves
        0 => {
            torso(&mut c, &xf, fill, w, 0.30);
            sleeves(&mut c, &xf, fill, w, 0.42, 0.10);
        }
        // trouser: two legs
        1 => {
            c.fill_polygon(
                &[[0.34 + w, 0.18], [0.48, 0.18], [0.46, 0.84], [0.34 + w, 0.84]],
                &xf,
                fill,
            );
            c.fill_polygon(
                &[[0.52, 0.18], [0.66 - w, 0.18], [0.66 - w, 0.84], [0.54, 0.84]],
                &xf,
                fill,
            );
        }
        // pullover: torso + long sleeves (overlaps coat/shirt)
        2 => {
            torso(&mut c, &xf, fill, w, 0.30);
            sleeves(&mut c, &xf, fill, w, 0.72, 0.09);
        }
        // dress: narrow top flaring to hem
        3 => {
            c.fill_polygon(
                &[
                    [0.42 + w, 0.16],
                    [0.58 - w, 0.16],
                    [0.70, 0.84],
                    [0.30, 0.84],
                ],
                &xf,
                fill,
            );
        }
        // coat: torso + long sleeves + open front line
        4 => {
            torso(&mut c, &xf, fill, w, 0.34);
            sleeves(&mut c, &xf, fill, w, 0.74, 0.10);
            c.stroke(&[[0.5, 0.2], [0.5, 0.8]], &xf, 0.012);
        }
        // sandal: sole + straps
        5 => {
            sole(&mut c, &xf, fill);
            c.stroke(&[[0.35, 0.62], [0.52, 0.44], [0.68, 0.60]], &xf, 0.02);
        }
        // shirt: torso + medium sleeves + collar (overlaps 0/2/4)
        6 => {
            torso(&mut c, &xf, fill, w, 0.30);
            sleeves(&mut c, &xf, fill, w, 0.56, 0.09);
            c.stroke(&[[0.44, 0.18], [0.5, 0.26], [0.56, 0.18]], &xf, 0.015);
        }
        // sneaker: sole + low body
        7 => {
            sole(&mut c, &xf, fill);
            c.fill_polygon(
                &[
                    [0.28, 0.62],
                    [0.60, 0.62],
                    [0.72, 0.52],
                    [0.46, 0.44],
                    [0.30, 0.50],
                ],
                &xf,
                fill * 0.9,
            );
        }
        // bag: rectangle + handle arc
        8 => {
            c.fill_polygon(
                &[
                    [0.26, 0.42],
                    [0.74, 0.42],
                    [0.72, 0.80],
                    [0.28, 0.80],
                ],
                &xf,
                fill,
            );
            c.arc(
                [0.5, 0.40],
                [0.16, 0.14],
                std::f64::consts::PI,
                std::f64::consts::TAU,
                &xf,
                0.02,
            );
        }
        // ankle boot: sole + tall shaft
        9 => {
            sole(&mut c, &xf, fill);
            c.fill_polygon(
                &[
                    [0.40, 0.24],
                    [0.62, 0.24],
                    [0.64, 0.62],
                    [0.30, 0.62],
                ],
                &xf,
                fill * 0.95,
            );
        }
        _ => panic!("fashion label must be 0..=9, got {label}"),
    }
    c.speckle(rng.uniform(0.15, 0.45), rng);
    if rng.bernoulli(0.7) {
        c.blur();
    }
    c.add_noise(rng.uniform(0.03, 0.10), rng);
    c.pixels
}

/// Shared torso silhouette.
fn torso(c: &mut Canvas, xf: &Affine, fill: f64, w: f64, shoulder: f64) {
    c.fill_polygon(
        &[
            [shoulder + w, 0.18],
            [1.0 - shoulder - w, 0.18],
            [0.68 - w, 0.82],
            [0.32 + w, 0.82],
        ],
        xf,
        fill,
    );
}

/// Shared sleeve pair; `len` is sleeve length in unit y, `sw` the width.
fn sleeves(c: &mut Canvas, xf: &Affine, fill: f64, w: f64, len: f64, sw: f64) {
    c.fill_polygon(
        &[
            [0.30 + w, 0.18],
            [0.18, len],
            [0.18 + sw, len + 0.04],
            [0.36 + w, 0.30],
        ],
        xf,
        fill * 0.9,
    );
    c.fill_polygon(
        &[
            [0.70 - w, 0.18],
            [0.82, len],
            [0.82 - sw, len + 0.04],
            [0.64 - w, 0.30],
        ],
        xf,
        fill * 0.9,
    );
}

/// Shared shoe sole.
fn sole(c: &mut Canvas, xf: &Affine, fill: f64) {
    c.fill_polygon(
        &[
            [0.24, 0.62],
            [0.76, 0.62],
            [0.78, 0.74],
            [0.22, 0.74],
        ],
        xf,
        fill,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes_with_ink() {
        let mut rng = Xoshiro256pp::new(1);
        for label in 0..10u8 {
            let img = render_fashion(label, &mut rng);
            assert_eq!(img.len(), 784);
            let ink: f64 = img.iter().sum();
            assert!(ink > 15.0, "class {label} too faint: {ink}");
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn harder_than_digits_by_overlap() {
        // Torso classes (0, 2, 6) should be closer to each other than to
        // the trouser class — the intended confusability structure.
        let mut rng = Xoshiro256pp::new(2);
        let mean_img = |label: u8, rng: &mut Xoshiro256pp| {
            let mut acc = vec![0.0; 784];
            for _ in 0..40 {
                for (a, v) in acc.iter_mut().zip(render_fashion(label, rng)) {
                    *a += v / 40.0;
                }
            }
            acc
        };
        let d = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        let t0 = mean_img(0, &mut rng);
        let t2 = mean_img(2, &mut rng);
        let t1 = mean_img(1, &mut rng);
        assert!(d(&t0, &t2) < d(&t0, &t1), "torso classes should overlap more");
    }

    #[test]
    fn class_names_count() {
        assert_eq!(CLASS_NAMES.len(), 10);
    }
}
