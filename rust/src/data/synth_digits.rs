//! "synth-digits": a procedural 10-class MNIST substitute.
//!
//! Each class is a hand-designed glyph archetype (strokes/arcs in unit
//! coordinates) rendered at 28×28 with per-sample affine jitter, stroke
//! thickness variation, pixel noise and blur. See DESIGN.md §4 for why this
//! preserves the behaviour the paper's MNIST experiments measure.

use crate::data::raster::{Affine, Canvas};
use crate::util::rng::Xoshiro256pp;

const TAU: f64 = std::f64::consts::TAU;
const PI: f64 = std::f64::consts::PI;

/// Render one sample of digit class `label` (0–9) into 28×28 pixels.
pub fn render_digit(label: u8, rng: &mut Xoshiro256pp) -> Vec<f64> {
    let mut c = Canvas::new(28);
    let xf = Affine::jitter(rng, 0.22, 0.12, 0.06);
    let th = rng.uniform(0.022, 0.042); // stroke thickness
    match label {
        0 => {
            c.arc([0.5, 0.5], [0.22, 0.30], 0.0, TAU, &xf, th);
        }
        1 => {
            c.stroke(&[[0.42, 0.28], [0.52, 0.18], [0.52, 0.82]], &xf, th);
        }
        2 => {
            c.arc([0.5, 0.34], [0.18, 0.14], PI, TAU, &xf, th);
            c.stroke(&[[0.68, 0.36], [0.32, 0.80]], &xf, th);
            c.stroke(&[[0.32, 0.80], [0.70, 0.80]], &xf, th);
        }
        3 => {
            c.arc([0.48, 0.35], [0.17, 0.15], -0.6 * PI, 0.5 * PI, &xf, th);
            c.arc([0.48, 0.65], [0.19, 0.16], -0.5 * PI, 0.6 * PI, &xf, th);
        }
        4 => {
            c.stroke(&[[0.60, 0.18], [0.30, 0.60], [0.74, 0.60]], &xf, th);
            c.stroke(&[[0.60, 0.30], [0.60, 0.84]], &xf, th);
        }
        5 => {
            c.stroke(&[[0.68, 0.20], [0.36, 0.20], [0.34, 0.48]], &xf, th);
            c.arc([0.49, 0.63], [0.18, 0.17], -0.5 * PI, 0.7 * PI, &xf, th);
        }
        6 => {
            c.stroke(&[[0.60, 0.16], [0.40, 0.44]], &xf, th);
            c.arc([0.48, 0.64], [0.17, 0.17], 0.0, TAU, &xf, th);
        }
        7 => {
            c.stroke(&[[0.30, 0.20], [0.70, 0.20], [0.44, 0.82]], &xf, th);
        }
        8 => {
            c.arc([0.5, 0.34], [0.15, 0.13], 0.0, TAU, &xf, th);
            c.arc([0.5, 0.66], [0.18, 0.16], 0.0, TAU, &xf, th);
        }
        9 => {
            c.arc([0.52, 0.36], [0.17, 0.17], 0.0, TAU, &xf, th);
            c.stroke(&[[0.69, 0.40], [0.62, 0.84]], &xf, th);
        }
        _ => panic!("digit label must be 0..=9, got {label}"),
    }
    if rng.bernoulli(0.5) {
        c.blur();
    }
    c.add_noise(rng.uniform(0.02, 0.08), rng);
    c.pixels
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_classes_with_ink() {
        let mut rng = Xoshiro256pp::new(1);
        for label in 0..10u8 {
            let img = render_digit(label, &mut rng);
            assert_eq!(img.len(), 784);
            let ink: f64 = img.iter().sum();
            assert!(ink > 10.0, "class {label} too faint: {ink}");
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
        }
    }

    #[test]
    fn samples_vary_within_class() {
        let mut rng = Xoshiro256pp::new(2);
        let a = render_digit(3, &mut rng);
        let b = render_digit(3, &mut rng);
        let diff: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1.0, "two samples should differ, diff={diff}");
    }

    #[test]
    fn classes_are_distinguishable_on_average() {
        // Mean images of different classes should differ far more than
        // samples within a class — a sanity floor for learnability.
        let mut rng = Xoshiro256pp::new(3);
        let mean_img = |label: u8, rng: &mut Xoshiro256pp| {
            let mut acc = vec![0.0; 784];
            for _ in 0..40 {
                for (a, v) in acc.iter_mut().zip(render_digit(label, rng)) {
                    *a += v / 40.0;
                }
            }
            acc
        };
        let m0 = mean_img(0, &mut rng);
        let m1 = mean_img(1, &mut rng);
        let m7 = mean_img(7, &mut rng);
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f64>().sqrt()
        };
        assert!(dist(&m0, &m1) > 2.0);
        assert!(dist(&m1, &m7) > 2.0);
        assert!(dist(&m0, &m7) > 2.0);
    }

    #[test]
    #[should_panic(expected = "digit label")]
    fn invalid_label_panics() {
        let mut rng = Xoshiro256pp::new(4);
        let _ = render_digit(10, &mut rng);
    }
}
