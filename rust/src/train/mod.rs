//! Model training: a pure-Rust SGD trainer plus the "model zoo" helpers
//! that produce (and cache) the trained networks the experiments quantize.

pub mod sgd;
pub mod zoo;

pub use sgd::{train, EpochStats, TrainConfig};
pub use zoo::{trained_model, ModelSpec, Zoo, ZooModel};
