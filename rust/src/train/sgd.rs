//! Pure-Rust minibatch SGD with momentum for the evaluation networks.
//!
//! Softmax cross-entropy loss, exact backprop through dense + ReLU layers.
//! Small and dependency-free: its only job is to produce the trained
//! weights the §VII–§VIII experiments quantize, entirely offline.

use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::nn::layer::softmax_rows;
use crate::nn::Mlp;
use crate::util::rng::Xoshiro256pp;

/// Training hyperparameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Number of passes over the training set.
    pub epochs: usize,
    /// Minibatch size.
    pub batch_size: usize,
    /// Learning rate.
    pub lr: f64,
    /// Momentum coefficient.
    pub momentum: f64,
    /// Shuffling seed.
    pub seed: u64,
    /// Print a line per epoch when true.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            epochs: 12,
            batch_size: 64,
            lr: 0.1,
            momentum: 0.9,
            seed: 0x5EED,
            verbose: false,
        }
    }
}

/// Per-epoch training record.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean cross-entropy loss over the epoch.
    pub loss: f64,
    /// Training accuracy over the epoch.
    pub accuracy: f64,
}

/// Train `mlp` in place; returns the per-epoch loss/accuracy curve.
pub fn train(mlp: &mut Mlp, data: &Dataset, cfg: &TrainConfig) -> Vec<EpochStats> {
    let n = data.len();
    assert!(n > 0, "empty training set");
    let mut rng = Xoshiro256pp::new(cfg.seed);
    let mut order: Vec<usize> = (0..n).collect();
    // Momentum buffers per layer (weights and bias).
    let mut vel_w: Vec<Matrix> = mlp
        .layers
        .iter()
        .map(|l| Matrix::zeros(l.in_dim(), l.out_dim()))
        .collect();
    let mut vel_b: Vec<Vec<f64>> = mlp.layers.iter().map(|l| vec![0.0; l.out_dim()]).collect();
    let mut history = Vec::with_capacity(cfg.epochs);

    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0;
        let mut epoch_correct = 0usize;
        for batch in order.chunks(cfg.batch_size) {
            // Gather the minibatch.
            let d = data.images.cols;
            let mut x = Matrix::zeros(batch.len(), d);
            let mut labels = Vec::with_capacity(batch.len());
            for (bi, &idx) in batch.iter().enumerate() {
                x.row_mut(bi).copy_from_slice(data.images.row(idx));
                labels.push(data.labels[idx]);
            }
            let (loss, correct) =
                train_step(mlp, &x, &labels, cfg, &mut vel_w, &mut vel_b);
            epoch_loss += loss * batch.len() as f64;
            epoch_correct += correct;
        }
        let stats = EpochStats {
            epoch,
            loss: epoch_loss / n as f64,
            accuracy: epoch_correct as f64 / n as f64,
        };
        if cfg.verbose {
            println!(
                "epoch {:>3}  loss {:.4}  acc {:.4}",
                stats.epoch, stats.loss, stats.accuracy
            );
        }
        history.push(stats);
    }
    history
}

/// One SGD step on a minibatch; returns (mean loss, #correct).
fn train_step(
    mlp: &mut Mlp,
    x: &Matrix,
    labels: &[u8],
    cfg: &TrainConfig,
    vel_w: &mut [Matrix],
    vel_b: &mut [Vec<f64>],
) -> (f64, usize) {
    let batch = x.rows as f64;
    // Forward, keeping every layer input (pre-layer activation).
    let mut acts: Vec<Matrix> = vec![x.clone()];
    for layer in &mlp.layers {
        let next = layer.forward(acts.last().unwrap());
        acts.push(next);
    }
    // Softmax + cross-entropy on the logits.
    let mut probs = acts.last().unwrap().clone();
    softmax_rows(&mut probs);
    let mut loss = 0.0;
    let mut correct = 0usize;
    for (i, &label) in labels.iter().enumerate() {
        let p = probs.get(i, label as usize).max(1e-12);
        loss -= p.ln();
        let row = probs.row(i);
        let pred = (0..row.len()).max_by(|&a, &b| row[a].total_cmp(&row[b])).unwrap();
        if pred == label as usize {
            correct += 1;
        }
    }
    loss /= batch;

    // Backward: delta at logits = (probs - onehot) / batch.
    let mut delta = probs;
    for (i, &label) in labels.iter().enumerate() {
        let row = delta.row_mut(i);
        row[label as usize] -= 1.0;
        for v in row.iter_mut() {
            *v /= batch;
        }
    }

    for li in (0..mlp.layers.len()).rev() {
        let input = &acts[li];
        // Gradients.
        let grad_w = input.transpose().matmul(&delta);
        let mut grad_b = vec![0.0; delta.cols];
        for i in 0..delta.rows {
            for (gb, &dv) in grad_b.iter_mut().zip(delta.row(i)) {
                *gb += dv;
            }
        }
        // Propagate before updating weights (uses current weights).
        let next_delta = if li > 0 {
            let mut nd = delta.matmul(&mlp.layers[li].weights.transpose());
            // ReLU mask of the layer below's output (acts[li]).
            if mlp.layers[li - 1].relu {
                for i in 0..nd.rows {
                    let mask = acts[li].row(i);
                    let row = nd.row_mut(i);
                    for (v, &a) in row.iter_mut().zip(mask) {
                        if a <= 0.0 {
                            *v = 0.0;
                        }
                    }
                }
            }
            Some(nd)
        } else {
            None
        };
        // Momentum update.
        let layer = &mut mlp.layers[li];
        let vw = &mut vel_w[li];
        for (v, g) in vw.data_mut().iter_mut().zip(grad_w.data()) {
            *v = cfg.momentum * *v - cfg.lr * g;
        }
        for (w, v) in layer.weights.data_mut().iter_mut().zip(vw.data()) {
            *w += v;
        }
        let vb = &mut vel_b[li];
        for ((b, v), g) in layer.bias.iter_mut().zip(vb.iter_mut()).zip(&grad_b) {
            *v = cfg.momentum * *v - cfg.lr * g;
            *b += *v;
        }
        if let Some(nd) = next_delta {
            delta = nd;
        }
    }
    (loss, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Task;

    #[test]
    fn loss_decreases_on_synthetic_digits() {
        let data = Dataset::synthesize(Task::Digits, 300, 1);
        let mut rng = Xoshiro256pp::new(2);
        let mut mlp = Mlp::single_layer(784, 10, &mut rng);
        let cfg = TrainConfig {
            epochs: 5,
            batch_size: 32,
            lr: 0.1,
            momentum: 0.9,
            seed: 3,
            verbose: false,
        };
        let hist = train(&mut mlp, &data, &cfg);
        assert_eq!(hist.len(), 5);
        assert!(
            hist.last().unwrap().loss < hist[0].loss * 0.8,
            "loss should drop: {} -> {}",
            hist[0].loss,
            hist.last().unwrap().loss
        );
        assert!(hist.last().unwrap().accuracy > 0.5);
    }

    #[test]
    fn single_layer_learns_separable_toy() {
        // Two linearly separable blobs.
        let mut images = Matrix::zeros(100, 4);
        let mut labels = Vec::new();
        let mut rng = Xoshiro256pp::new(4);
        for i in 0..100 {
            let c = (i % 2) as u8;
            for j in 0..4 {
                let group = usize::from(j >= 2);
                let base = if group == c as usize { 0.9 } else { 0.1 };
                images.set(i, j, base + rng.uniform(-0.05, 0.05));
            }
            labels.push(c);
        }
        let data = Dataset {
            images,
            labels,
            num_classes: 2,
        };
        let mut mlp = Mlp::single_layer(4, 2, &mut rng);
        train(
            &mut mlp,
            &data,
            &TrainConfig {
                epochs: 20,
                batch_size: 10,
                lr: 0.5,
                momentum: 0.5,
                seed: 5,
                verbose: false,
            },
        );
        assert_eq!(mlp.accuracy(&data.images, &data.labels), 1.0);
    }

    #[test]
    fn three_layer_backprop_learns_xor() {
        // XOR requires the hidden layer: a correctness check on the ReLU
        // backprop path.
        let images = Matrix::from_vec(
            4,
            2,
            vec![0.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0],
        );
        let labels = vec![0u8, 1, 1, 0];
        let data = Dataset {
            images,
            labels,
            num_classes: 2,
        };
        let mut best_acc: f64 = 0.0;
        for seed in 0..3 {
            let mut rng = Xoshiro256pp::new(10 + seed);
            let mut mlp = Mlp::three_layer(2, 16, 8, 2, &mut rng);
            train(
                &mut mlp,
                &data,
                &TrainConfig {
                    epochs: 300,
                    batch_size: 4,
                    lr: 0.1,
                    momentum: 0.9,
                    seed,
                    verbose: false,
                },
            );
            best_acc = best_acc.max(mlp.accuracy(&data.images, &data.labels));
            if best_acc == 1.0 {
                break;
            }
        }
        assert_eq!(best_acc, 1.0, "XOR should be solvable");
    }

    #[test]
    #[should_panic(expected = "empty training set")]
    fn empty_dataset_panics() {
        let data = Dataset {
            images: Matrix::zeros(0, 4),
            labels: vec![],
            num_classes: 2,
        };
        let mut rng = Xoshiro256pp::new(1);
        let mut mlp = Mlp::single_layer(4, 2, &mut rng);
        train(&mut mlp, &data, &TrainConfig::default());
    }
}
