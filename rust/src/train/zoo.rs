//! Model zoo: the two evaluation networks, trained on demand and cached
//! under `artifacts/weights/` so experiments and the server start fast.
//!
//! [`Zoo`] is the serving-side container: both families trained/loaded
//! once, with their activation ranges pre-calibrated, shared across the
//! coordinator's worker shards behind an `Arc`.

use crate::data::{Dataset, Task};
use crate::linalg::Variant;
use crate::nn::{ActivationRanges, Mlp, PlanKey, PreparedModel};
use crate::rounding::SchemeId;
use crate::train::sgd::{train, TrainConfig};
use crate::util::rng::Xoshiro256pp;
use std::sync::Arc;

/// Which evaluation model to produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// 1-layer 784→10 softmax classifier on the digits task (§VII).
    DigitsLinear,
    /// 3-layer ReLU MLP (784→128→64→10) on the fashion task (§VIII).
    FashionMlp,
}

impl ModelSpec {
    /// Both evaluation models, in serving order.
    pub const ALL: [ModelSpec; 2] = [ModelSpec::DigitsLinear, ModelSpec::FashionMlp];

    /// Stable position of this family in [`ModelSpec::ALL`] — the model
    /// slot used by the fidelity estimators' bounded label space.
    pub fn index(&self) -> usize {
        ModelSpec::ALL
            .iter()
            .position(|s| s == self)
            .expect("every spec appears in ALL")
    }

    /// Wire/CLI name of the model family.
    pub fn name(&self) -> &'static str {
        match self {
            ModelSpec::DigitsLinear => "digits_linear",
            ModelSpec::FashionMlp => "fashion_mlp",
        }
    }

    /// Parse a wire/CLI model-family name.
    pub fn from_name(name: &str) -> Option<ModelSpec> {
        match name {
            "digits_linear" => Some(ModelSpec::DigitsLinear),
            "fashion_mlp" => Some(ModelSpec::FashionMlp),
            _ => None,
        }
    }

    /// Cache file path, keyed by the full training configuration so a
    /// cached model can never silently override a different requested
    /// `train_n`/`seed` (training is deterministic given the key, so any
    /// process that computes the same path holds bit-identical weights).
    pub fn weights_path(&self, train_n: usize, seed: u64) -> String {
        format!("artifacts/weights/{}.n{train_n}.s{seed}.bin", self.name())
    }

    /// Task the model is trained on.
    pub fn task(&self) -> Task {
        match self {
            ModelSpec::DigitsLinear => Task::Digits,
            ModelSpec::FashionMlp => Task::Fashion,
        }
    }

    /// Fresh untrained network.
    pub fn build(&self, rng: &mut Xoshiro256pp) -> Mlp {
        match self {
            ModelSpec::DigitsLinear => Mlp::single_layer(784, 10, rng),
            ModelSpec::FashionMlp => Mlp::three_layer(784, 128, 64, 10, rng),
        }
    }

    /// Training configuration used by the zoo.
    pub fn train_config(&self) -> TrainConfig {
        match self {
            ModelSpec::DigitsLinear => TrainConfig {
                epochs: 12,
                batch_size: 64,
                lr: 0.15,
                momentum: 0.9,
                seed: 0xD161,
                verbose: false,
            },
            ModelSpec::FashionMlp => TrainConfig {
                epochs: 16,
                batch_size: 64,
                lr: 0.08,
                momentum: 0.9,
                seed: 0xFA51,
                verbose: false,
            },
        }
    }
}

/// Load the cached trained model, or train it now (then cache).
///
/// The returned model has weights normalized to `[-1, 1]` (the paper's
/// precondition for the §VII quantizer). Returns `(model, test set,
/// float test accuracy)`.
pub fn trained_model(
    spec: ModelSpec,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> (Mlp, Dataset, f64) {
    let (train_set, test_set, _source) =
        Dataset::load_or_synthesize(spec.task(), train_n, test_n, seed);
    let path = spec.weights_path(train_n, seed);
    let mlp = match Mlp::load(&path) {
        Ok(m) if shapes_match(&m, spec) => m,
        _ => {
            let mut rng = Xoshiro256pp::new(seed ^ 0x200);
            let mut m = spec.build(&mut rng);
            train(&mut m, &train_set, &spec.train_config());
            m.normalize_weights();
            // Write-then-rename so concurrent readers (other shards or
            // processes warming the same cache) never see a torn file; the
            // tmp name is unique per writer.
            static WRITER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
            let unique = WRITER.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let tmp = format!("{path}.tmp.{}.{unique}", std::process::id());
            let cached = m
                .save(&tmp)
                .and_then(|()| std::fs::rename(&tmp, &path));
            if let Err(e) = cached {
                let _ = std::fs::remove_file(&tmp);
                eprintln!("warning: could not cache weights at {path}: {e}");
            }
            m
        }
    };
    let acc = mlp.accuracy(&test_set.images, &test_set.labels);
    (mlp, test_set, acc)
}

/// One model family's serving state: the trained network, its calibrated
/// activation ranges, and the float test accuracy measured at load time.
pub struct ZooModel {
    /// Which family this is.
    pub spec: ModelSpec,
    /// The trained (weight-normalized) network.
    pub mlp: Mlp,
    /// Per-layer quantizer input ranges, calibrated once on load.
    pub ranges: ActivationRanges,
    /// Float (unquantized) test accuracy at load time.
    pub float_accuracy: f64,
}

impl ZooModel {
    /// Exact (full f64) logits for a marshalled input batch — the shadow
    /// reference the fidelity estimators compare quantized logits against.
    /// This is the same float forward pass the activation ranges were
    /// calibrated on, so quantized − exact is purely the rounding error.
    pub fn exact_logits(&self, x: &crate::linalg::Matrix) -> crate::linalg::Matrix {
        self.mlp.forward(x)
    }
}

/// Both evaluation models, trained/loaded once and shared (behind an
/// `Arc`) by every serving shard.
pub struct Zoo {
    models: Vec<ZooModel>,
}

impl Zoo {
    /// Load (or train and cache) every model family. `train_n` is the
    /// training-set size for cache misses; `seed` drives data synthesis and
    /// calibration.
    pub fn load(train_n: usize, seed: u64) -> Zoo {
        let models = ModelSpec::ALL
            .iter()
            .map(|&spec| {
                let (mlp, _test, float_accuracy) =
                    trained_model(spec, train_n, (train_n / 5).max(1), seed);
                let calib = Dataset::synthesize(spec.task(), 64, seed ^ 0xCA11B);
                let ranges = ActivationRanges::calibrate(&mlp, &calib.images);
                ZooModel {
                    spec,
                    mlp,
                    ranges,
                    float_accuracy,
                }
            })
            .collect();
        Zoo { models }
    }

    /// Zoo over explicitly constructed models (custom weights served under
    /// a known family name — controlled-model tests, A/B deployments of
    /// retrained weights). Later entries for the same spec shadow earlier
    /// ones in [`Zoo::get`].
    pub fn from_models(models: Vec<ZooModel>) -> Zoo {
        Zoo { models }
    }

    /// Look up a family by wire name (`digits_linear` / `fashion_mlp`).
    pub fn get(&self, name: &str) -> Option<&ZooModel> {
        let spec = ModelSpec::from_name(name)?;
        self.models.iter().find(|m| m.spec == spec)
    }

    /// All loaded models.
    pub fn models(&self) -> &[ZooModel] {
        &self.models
    }

    /// Build prepared weight-side inference plans for every loaded model ×
    /// each `(bits, mode)` combination — zoo-level plan prewarming.
    ///
    /// Server startup runs this once before accepting traffic and installs
    /// the shared `Arc`s into every shard engine's plan cache, so the hot
    /// configurations never pay weight-side planning on the request path
    /// (and the build cost is amortized across shards instead of repeated
    /// per engine). `seed` fixes the dither draw of frozen weight plans.
    pub fn prewarm_plans(
        &self,
        bits: &[u32],
        modes: &[SchemeId],
        variant: Variant,
        seed: u64,
    ) -> Vec<(PlanKey, Arc<PreparedModel>)> {
        let mut out = Vec::with_capacity(self.models.len() * bits.len() * modes.len());
        for m in &self.models {
            for &k in bits {
                for &mode in modes {
                    let key = PlanKey {
                        model: m.spec.name().to_string(),
                        bits: k,
                        scheme: mode,
                        variant,
                    };
                    let plans = Arc::new(PreparedModel::prepare(&m.mlp, k, mode, variant, seed));
                    out.push((key, plans));
                }
            }
        }
        out
    }
}

fn shapes_match(m: &Mlp, spec: ModelSpec) -> bool {
    let dims: Vec<(usize, usize)> = m
        .layers
        .iter()
        .map(|l| (l.in_dim(), l.out_dim()))
        .collect();
    match spec {
        ModelSpec::DigitsLinear => dims == vec![(784, 10)],
        ModelSpec::FashionMlp => dims == vec![(784, 128), (128, 64), (64, 10)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build_expected_shapes() {
        let mut rng = Xoshiro256pp::new(1);
        let lin = ModelSpec::DigitsLinear.build(&mut rng);
        assert_eq!(lin.layers.len(), 1);
        assert_eq!(lin.layers[0].in_dim(), 784);
        let mlp = ModelSpec::FashionMlp.build(&mut rng);
        assert_eq!(mlp.layers.len(), 3);
        assert!(mlp.layers[0].relu && mlp.layers[1].relu && !mlp.layers[2].relu);
        assert!(shapes_match(&lin, ModelSpec::DigitsLinear));
        assert!(shapes_match(&mlp, ModelSpec::FashionMlp));
        assert!(!shapes_match(&lin, ModelSpec::FashionMlp));
    }

    #[test]
    fn paths_are_keyed_by_family_and_config() {
        assert_ne!(
            ModelSpec::DigitsLinear.weights_path(2000, 7),
            ModelSpec::FashionMlp.weights_path(2000, 7)
        );
        // Different training configurations must never share a cache file.
        assert_ne!(
            ModelSpec::DigitsLinear.weights_path(200, 7),
            ModelSpec::DigitsLinear.weights_path(2000, 7)
        );
        assert_ne!(
            ModelSpec::DigitsLinear.weights_path(2000, 7),
            ModelSpec::DigitsLinear.weights_path(2000, 8)
        );
    }

    #[test]
    fn names_roundtrip() {
        for (i, spec) in ModelSpec::ALL.into_iter().enumerate() {
            assert_eq!(ModelSpec::from_name(spec.name()), Some(spec));
            assert_eq!(spec.index(), i);
        }
        assert_eq!(ModelSpec::from_name("nope"), None);
    }

    #[test]
    fn prewarm_plans_covers_the_config_grid() {
        let zoo = Zoo::load(200, 11);
        let plans = zoo.prewarm_plans(&[2, 4], &SchemeId::PAPER, Variant::Separate, 7);
        assert_eq!(plans.len(), 2 * 2 * 3, "models × bits × schemes");
        for (key, prepared) in &plans {
            assert_eq!(key.variant, Variant::Separate);
            assert_eq!(prepared.bits(), key.bits);
            assert_eq!(prepared.mode(), key.scheme);
            assert!(prepared.memory_bytes() > 0);
        }
        // Keys are unique (one cache slot per configuration).
        for (i, (key, _)) in plans.iter().enumerate() {
            assert!(plans.iter().skip(i + 1).all(|(other, _)| other != key));
        }
    }

    #[test]
    fn zoo_serves_both_families() {
        let zoo = Zoo::load(200, 11);
        assert_eq!(zoo.models().len(), 2);
        let digits = zoo.get("digits_linear").expect("digits served");
        assert_eq!(digits.mlp.layers[0].in_dim(), 784);
        assert_eq!(digits.ranges.per_layer.len(), digits.mlp.layers.len());
        let fashion = zoo.get("fashion_mlp").expect("fashion served");
        assert_eq!(fashion.mlp.layers.len(), 3);
        assert_eq!(fashion.ranges.per_layer.len(), 3);
        assert!(zoo.get("unknown").is_none());
    }
}
