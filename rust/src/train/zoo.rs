//! Model zoo: the two evaluation networks, trained on demand and cached
//! under `artifacts/weights/` so experiments and the server start fast.

use crate::data::{Dataset, Task};
use crate::nn::Mlp;
use crate::train::sgd::{train, TrainConfig};
use crate::util::rng::Xoshiro256pp;

/// Which evaluation model to produce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelSpec {
    /// 1-layer 784→10 softmax classifier on the digits task (§VII).
    DigitsLinear,
    /// 3-layer ReLU MLP (784→128→64→10) on the fashion task (§VIII).
    FashionMlp,
}

impl ModelSpec {
    /// Cache file path.
    pub fn weights_path(&self) -> &'static str {
        match self {
            ModelSpec::DigitsLinear => "artifacts/weights/digits_linear.bin",
            ModelSpec::FashionMlp => "artifacts/weights/fashion_mlp.bin",
        }
    }

    /// Task the model is trained on.
    pub fn task(&self) -> Task {
        match self {
            ModelSpec::DigitsLinear => Task::Digits,
            ModelSpec::FashionMlp => Task::Fashion,
        }
    }

    /// Fresh untrained network.
    pub fn build(&self, rng: &mut Xoshiro256pp) -> Mlp {
        match self {
            ModelSpec::DigitsLinear => Mlp::single_layer(784, 10, rng),
            ModelSpec::FashionMlp => Mlp::three_layer(784, 128, 64, 10, rng),
        }
    }

    /// Training configuration used by the zoo.
    pub fn train_config(&self) -> TrainConfig {
        match self {
            ModelSpec::DigitsLinear => TrainConfig {
                epochs: 12,
                batch_size: 64,
                lr: 0.15,
                momentum: 0.9,
                seed: 0xD161,
                verbose: false,
            },
            ModelSpec::FashionMlp => TrainConfig {
                epochs: 16,
                batch_size: 64,
                lr: 0.08,
                momentum: 0.9,
                seed: 0xFA51,
                verbose: false,
            },
        }
    }
}

/// Load the cached trained model, or train it now (then cache).
///
/// The returned model has weights normalized to `[-1, 1]` (the paper's
/// precondition for the §VII quantizer). Returns `(model, test set,
/// float test accuracy)`.
pub fn trained_model(
    spec: ModelSpec,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> (Mlp, Dataset, f64) {
    let (train_set, test_set, _source) =
        Dataset::load_or_synthesize(spec.task(), train_n, test_n, seed);
    let path = spec.weights_path();
    let mlp = match Mlp::load(path) {
        Ok(m) if shapes_match(&m, spec) => m,
        _ => {
            let mut rng = Xoshiro256pp::new(seed ^ 0x200);
            let mut m = spec.build(&mut rng);
            train(&mut m, &train_set, &spec.train_config());
            m.normalize_weights();
            if let Err(e) = m.save(path) {
                eprintln!("warning: could not cache weights at {path}: {e}");
            }
            m
        }
    };
    let acc = mlp.accuracy(&test_set.images, &test_set.labels);
    (mlp, test_set, acc)
}

fn shapes_match(m: &Mlp, spec: ModelSpec) -> bool {
    let dims: Vec<(usize, usize)> = m
        .layers
        .iter()
        .map(|l| (l.in_dim(), l.out_dim()))
        .collect();
    match spec {
        ModelSpec::DigitsLinear => dims == vec![(784, 10)],
        ModelSpec::FashionMlp => dims == vec![(784, 128), (128, 64), (64, 10)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_build_expected_shapes() {
        let mut rng = Xoshiro256pp::new(1);
        let lin = ModelSpec::DigitsLinear.build(&mut rng);
        assert_eq!(lin.layers.len(), 1);
        assert_eq!(lin.layers[0].in_dim(), 784);
        let mlp = ModelSpec::FashionMlp.build(&mut rng);
        assert_eq!(mlp.layers.len(), 3);
        assert!(mlp.layers[0].relu && mlp.layers[1].relu && !mlp.layers[2].relu);
        assert!(shapes_match(&lin, ModelSpec::DigitsLinear));
        assert!(shapes_match(&mlp, ModelSpec::FashionMlp));
        assert!(!shapes_match(&lin, ModelSpec::FashionMlp));
    }

    #[test]
    fn paths_are_distinct() {
        assert_ne!(
            ModelSpec::DigitsLinear.weights_path(),
            ModelSpec::FashionMlp.weights_path()
        );
    }
}
