//! Per-request trace context: the 64-bit trace id, the span timeline a
//! request accumulates as it moves through the serving pipeline, and the
//! wire encoding the cluster proxy uses to propagate the context upstream.
//!
//! Ownership is the concurrency story. A [`TraceBuilder`] is created by
//! whichever tier admits the request (the backend's connection reader or
//! the proxy's dispatcher) and then *moves* with the request — into the
//! batcher's `Pending`, across the queue to the shard worker, or into the
//! proxy's pending-reply table. Exactly one thread owns it at any moment,
//! so span recording is plain `Vec` pushes against a monotonic clock: no
//! lock, no atomics, no allocation beyond the spans themselves. Only the
//! finished, immutable [`Trace`] ever crosses into shared state (the
//! bounded ring in [`crate::trace::ring`]).

use crate::util::json::Json;
use std::time::Instant;

/// One pipeline stage a span can measure. Backend stages cover the full
/// request lifecycle inside a `serve` process; the last three are stamped
/// by the cluster proxy on its own timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Reading + validating the request line (backend connection reader).
    Parse,
    /// In-flight window admission check.
    Admit,
    /// Queue wait: submit until the shard worker drained the request.
    Queue,
    /// Batch assembly: drain until the batch was sealed for execution.
    Assemble,
    /// Auto-precision resolution (`"scheme":"auto"` batches only).
    AutoResolve,
    /// Plan-cache lookup, or the plan build a miss pays for.
    Plan,
    /// The quantized forward pass (tagged with the active kernel id and
    /// the scheme via the span note).
    Kernel,
    /// Shadow sampling: the exact f64 re-run feeding fidelity estimators.
    Shadow,
    /// Response serialization.
    Serialize,
    /// Handoff to the connection writer (the reply leaves the worker).
    Flush,
    /// Proxy: consistent-hash routing decision.
    Route,
    /// Proxy: the upstream submit on the pooled pipelined connection.
    Forward,
    /// Proxy: waiting for the backend's out-of-order completion.
    UpstreamWait,
}

impl Stage {
    /// Every stage, in pipeline order (backend stages first, proxy last).
    pub const ALL: [Stage; 13] = [
        Stage::Parse,
        Stage::Admit,
        Stage::Queue,
        Stage::Assemble,
        Stage::AutoResolve,
        Stage::Plan,
        Stage::Kernel,
        Stage::Shadow,
        Stage::Serialize,
        Stage::Flush,
        Stage::Route,
        Stage::Forward,
        Stage::UpstreamWait,
    ];

    /// Number of distinct stages.
    pub const COUNT: usize = Stage::ALL.len();

    /// Stable dense index (histogram slot).
    pub fn slot(self) -> usize {
        self as usize
    }

    /// Wire / exposition name.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Admit => "admit",
            Stage::Queue => "queue",
            Stage::Assemble => "assemble",
            Stage::AutoResolve => "auto_resolve",
            Stage::Plan => "plan",
            Stage::Kernel => "kernel",
            Stage::Shadow => "shadow",
            Stage::Serialize => "serialize",
            Stage::Flush => "flush",
            Stage::Route => "route",
            Stage::Forward => "forward",
            Stage::UpstreamWait => "upstream_wait",
        }
    }

    /// Inverse of [`Stage::name`] (used when re-parsing trace dumps).
    pub fn from_name(name: &str) -> Option<Stage> {
        Stage::ALL.into_iter().find(|s| s.name() == name)
    }
}

/// One measured interval on a trace's timeline. Offsets are microseconds
/// since the trace's own monotonic origin — timelines from different
/// processes are therefore *not* directly comparable, which is why
/// cluster stitching keeps per-process span lists side by side instead of
/// interleaving them.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// Which pipeline stage this span measures.
    pub stage: Stage,
    /// Start offset in µs since the trace origin.
    pub start_us: u64,
    /// Duration in µs.
    pub dur_us: u64,
    /// Optional annotation (the kernel span carries `"<kernel>/<scheme>"`).
    pub note: Option<String>,
}

impl Span {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("stage", Json::Str(self.stage.name().to_string())),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
        ];
        if let Some(note) = &self.note {
            fields.push(("note", Json::Str(note.clone())));
        }
        Json::obj(fields)
    }

    fn from_json(json: &Json) -> Option<Span> {
        Some(Span {
            stage: Stage::from_name(json.get("stage")?.as_str()?)?,
            start_us: json.get("start_us")?.as_f64()? as u64,
            dur_us: json.get("dur_us")?.as_f64()? as u64,
            note: json.get("note").and_then(Json::as_str).map(str::to_string),
        })
    }
}

/// Wire flag bit: the request was sampled at admission (as opposed to
/// being carried only for slow-trace promotion).
pub const FLAG_SAMPLED: u8 = 1;

/// Encode a trace context for the request line: `"<16-hex-id>:<flags>"`.
/// Proto-3 proxies attach this under the `"trace"` key; older backends
/// simply ignore the unknown field.
pub fn encode_wire(id: u64, flags: u8) -> String {
    format!("{id:016x}:{flags}")
}

/// Decode a `"trace"` request field. Returns `None` for anything
/// malformed — an unparseable tag downgrades the request to untraced
/// rather than rejecting it, mirroring how pre-proto-3 backends treat the
/// whole field.
pub fn decode_wire(tag: &str) -> Option<(u64, u8)> {
    let (id_hex, flags) = tag.split_once(':')?;
    if id_hex.len() != 16 {
        return None;
    }
    let id = u64::from_str_radix(id_hex, 16).ok()?;
    let flags = flags.parse::<u8>().ok()?;
    Some((id, flags))
}

/// Batch-level stage timings the engine reports back to the shard worker
/// (plan lookup/build, kernel execute, shadow sampling). The worker fans
/// them out to every traced request in the batch — the stages are shared
/// batch work, so each member's timeline shows the same interval.
#[derive(Debug, Default)]
pub struct BatchStageTimes {
    /// Plan-cache lookup (or the build a miss paid for).
    pub plan: Option<(Instant, Instant)>,
    /// The quantized forward pass.
    pub kernel: Option<(Instant, Instant)>,
    /// The exact f64 shadow re-run (only when shadow sampling ran).
    pub shadow: Option<(Instant, Instant)>,
}

/// An in-flight trace: owned by exactly one pipeline stage at a time (see
/// the module docs), accumulating spans until the owning tier hands it to
/// [`crate::trace::Tracer::finish`].
#[derive(Debug)]
pub struct TraceBuilder {
    id: u64,
    sampled: bool,
    t0: Instant,
    request_id: u64,
    model: String,
    scheme: String,
    k: u32,
    shard: Option<usize>,
    spans: Vec<Span>,
}

impl TraceBuilder {
    /// Fresh trace rooted at "now" on this process's monotonic clock.
    /// Boxed because it rides inside queued requests — one pointer of
    /// overhead for untraced paths' data structures.
    pub fn new(id: u64, sampled: bool, request_id: u64) -> Box<TraceBuilder> {
        Box::new(TraceBuilder {
            id,
            sampled,
            t0: Instant::now(),
            request_id,
            model: String::new(),
            scheme: String::new(),
            k: 0,
            shard: None,
            spans: Vec::with_capacity(8),
        })
    }

    /// The 64-bit trace id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Whether the admission decision sampled this request (slow-only
    /// traces carry `false` until promotion at finish).
    pub fn sampled(&self) -> bool {
        self.sampled
    }

    /// The wire tag (`"<id>:<flags>"`) a proxy attaches when forwarding.
    pub fn wire_tag(&self) -> String {
        encode_wire(self.id, if self.sampled { FLAG_SAMPLED } else { 0 })
    }

    /// Record one span from explicit start/end instants (both clamped to
    /// the trace origin, so a span can never start before its trace).
    pub fn span(&mut self, stage: Stage, start: Instant, end: Instant) {
        self.span_noted(stage, start, end, None);
    }

    /// [`TraceBuilder::span`] with an annotation (kernel id, scheme, ...).
    pub fn span_noted(
        &mut self,
        stage: Stage,
        start: Instant,
        end: Instant,
        note: Option<String>,
    ) {
        let start_us = start.saturating_duration_since(self.t0).as_micros() as u64;
        let dur_us = end.saturating_duration_since(start).as_micros() as u64;
        self.spans.push(Span {
            stage,
            start_us,
            dur_us,
            note,
        });
    }

    /// Record a span that ends now.
    pub fn span_since(&mut self, stage: Stage, start: Instant) {
        self.span(stage, start, Instant::now());
    }

    /// Stamp what the request resolved to (model family, concrete scheme
    /// and bit width — for auto requests, the controller's choice).
    pub fn annotate(&mut self, model: &str, scheme: &str, k: u32) {
        self.model = model.to_string();
        self.scheme = scheme.to_string();
        self.k = k;
    }

    /// Stamp which shard served the request.
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = Some(shard);
    }

    /// Microseconds elapsed since the trace origin.
    pub fn elapsed_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Seal the builder into an immutable [`Trace`] record (called by the
    /// tracer; `slow` is the promotion verdict it computed).
    pub(crate) fn seal(self: Box<TraceBuilder>, total_us: u64, slow: bool) -> Trace {
        Trace {
            trace_id: self.id,
            request_id: self.request_id,
            model: self.model,
            scheme: self.scheme,
            k: self.k,
            shard: self.shard,
            total_us,
            sampled: self.sampled,
            slow,
            spans: self.spans,
        }
    }
}

/// A completed, immutable trace as stored in the ring buffer and emitted
/// by the `{"cmd":"trace"}` verb.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// 64-bit trace id (shared across tiers for one request).
    pub trace_id: u64,
    /// The request id at the tier that recorded this timeline (the
    /// client's id at the proxy; the possibly-rewritten upstream id on a
    /// backend).
    pub request_id: u64,
    /// Model family the request resolved to (empty if it failed early).
    pub model: String,
    /// Concrete scheme served (auto requests record the resolved choice).
    pub scheme: String,
    /// Concrete bit width served.
    pub k: u32,
    /// Serving shard, when the request reached one.
    pub shard: Option<usize>,
    /// End-to-end latency at this tier, µs.
    pub total_us: u64,
    /// Sampled at admission.
    pub sampled: bool,
    /// Promoted by the slow-trace threshold.
    pub slow: bool,
    /// The timeline (µs offsets from this tier's trace origin).
    pub spans: Vec<Span>,
}

impl Trace {
    /// JSON form (one element of the `{"cmd":"trace"}` reply's `traces`).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("trace_id", Json::Str(format!("{:016x}", self.trace_id))),
            ("id", Json::Num(self.request_id as f64)),
            ("model", Json::Str(self.model.clone())),
            ("scheme", Json::Str(self.scheme.clone())),
            ("k", Json::Num(f64::from(self.k))),
            ("total_us", Json::Num(self.total_us as f64)),
            ("sampled", Json::Bool(self.sampled)),
            ("slow", Json::Bool(self.slow)),
            (
                "spans",
                Json::Arr(self.spans.iter().map(Span::to_json).collect()),
            ),
        ];
        if let Some(shard) = self.shard {
            fields.push(("shard", Json::Num(shard as f64)));
        }
        Json::obj(fields)
    }

    /// Parse one trace back out of its JSON form (the proxy re-parses
    /// backend trace dumps to stitch cluster timelines). `None` for
    /// anything that does not look like a trace record.
    pub fn from_json(json: &Json) -> Option<Trace> {
        let spans = json
            .get("spans")?
            .as_arr()?
            .iter()
            .map(Span::from_json)
            .collect::<Option<Vec<Span>>>()?;
        Some(Trace {
            trace_id: u64::from_str_radix(json.get("trace_id")?.as_str()?, 16).ok()?,
            request_id: json.get("id")?.as_f64()? as u64,
            model: json.get("model")?.as_str()?.to_string(),
            scheme: json.get("scheme")?.as_str()?.to_string(),
            k: json.get("k")?.as_f64()? as u32,
            shard: json.get("shard").and_then(Json::as_f64).map(|s| s as usize),
            total_us: json.get("total_us")?.as_f64()? as u64,
            sampled: json.get("sampled")?.as_bool()?,
            slow: json.get("slow")?.as_bool()?,
            spans,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_roundtrip_and_slots_are_dense() {
        for (i, stage) in Stage::ALL.into_iter().enumerate() {
            assert_eq!(stage.slot(), i);
            assert_eq!(Stage::from_name(stage.name()), Some(stage));
        }
        assert_eq!(Stage::from_name("no_such_stage"), None);
    }

    #[test]
    fn wire_tag_roundtrips_and_rejects_garbage() {
        for (id, flags) in [(0u64, 0u8), (1, 1), (u64::MAX, 255), (0xDEAD_BEEF, 1)] {
            assert_eq!(decode_wire(&encode_wire(id, flags)), Some((id, flags)));
        }
        for bad in ["", "xyz", "12:1", "deadbeef:1", ":1", "0123456789abcdef:",
            "0123456789abcdef:999", "0123456789abcdeg:1", "0123456789abcdef"]
        {
            assert_eq!(decode_wire(bad), None, "{bad:?} must not decode");
        }
    }

    #[test]
    fn builder_seals_into_a_json_roundtrippable_trace() {
        let mut b = TraceBuilder::new(0xABCD, true, 42);
        let t = Instant::now();
        b.span(Stage::Parse, t, t);
        b.span_noted(Stage::Kernel, t, t, Some("wide/dither".to_string()));
        b.annotate("digits_linear", "dither", 4);
        b.set_shard(3);
        let trace = b.seal(123, false);
        assert_eq!(trace.trace_id, 0xABCD);
        assert_eq!(trace.request_id, 42);
        assert_eq!(trace.shard, Some(3));
        assert_eq!(trace.spans.len(), 2);
        assert_eq!(trace.spans[1].note.as_deref(), Some("wide/dither"));
        let parsed = Trace::from_json(&trace.to_json()).expect("roundtrip");
        assert_eq!(parsed, trace);
    }
}
