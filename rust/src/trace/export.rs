//! Prometheus text-exposition rendering: a tiny zero-dep builder for the
//! `{"cmd":"metrics"}` verb (and the raw `GET /metrics` fast path), plus
//! the well-formedness checker the smoke tests and `load_gen` assert
//! with.
//!
//! The builder emits the [text exposition format]: one `# HELP` / `# TYPE`
//! pair per metric family followed by its samples, histograms in the
//! standard `_bucket{le="..."}` / `_sum` / `_count` convention with
//! cumulative counts and a `+Inf` bucket. Both the backend server and the
//! cluster proxy render through this type, so the two tiers' surfaces
//! stay structurally identical.
//!
//! [text exposition format]: https://prometheus.io/docs/instrumenting/exposition_formats/

use crate::trace::ring::{stage_bucket_upper, StageSnapshot};
use std::fmt::Write as _;

/// Incremental Prometheus text-exposition builder.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

/// A label set: `(name, value)` pairs rendered as `{a="x",b="y"}`.
pub type Labels<'a> = &'a [(&'a str, &'a str)];

fn write_labels(out: &mut String, labels: Labels<'_>) {
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (name, value)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{name}=\"");
        for c in value.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                c => out.push(c),
            }
        }
        out.push('"');
    }
    out.push('}');
}

fn write_value(out: &mut String, value: f64) {
    if value.fract() == 0.0 && value.abs() < 1e15 {
        let _ = write!(out, "{}", value as i64);
    } else {
        let _ = write!(out, "{value}");
    }
}

impl PromText {
    /// Empty exposition.
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Open a metric family: one `# HELP` + `# TYPE` header pair.
    /// `kind` is `counter`, `gauge`, or `histogram`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    /// One sample line for the most recently opened family.
    pub fn sample(&mut self, name: &str, labels: Labels<'_>, value: f64) {
        self.out.push_str(name);
        write_labels(&mut self.out, labels);
        self.out.push(' ');
        write_value(&mut self.out, value);
        self.out.push('\n');
    }

    /// A single-sample counter or gauge family.
    pub fn scalar(&mut self, name: &str, kind: &str, help: &str, value: f64) {
        self.family(name, kind, help);
        self.sample(name, &[], value);
    }

    /// Histogram samples from a log₂ bucket slice (edges via
    /// [`crate::coordinator::metrics::bucket_upper`]): cumulative
    /// `_bucket{le=...}` lines, `+Inf`, `_sum`, `_count`. Empty buckets
    /// are skipped (the counts are cumulative, so nothing is lost) to
    /// keep the surface compact. Call [`PromText::family`] with kind
    /// `histogram` first when emitting several labeled series under one
    /// family.
    pub fn histogram_series(
        &mut self,
        name: &str,
        labels: Labels<'_>,
        buckets: &[u64],
        sum: f64,
        upper: impl Fn(usize) -> u64,
    ) {
        let bucket_name = format!("{name}_bucket");
        let les: Vec<String> = (0..buckets.len()).map(|i| upper(i).to_string()).collect();
        let mut cumulative = 0u64;
        for (i, &count) in buckets.iter().enumerate() {
            cumulative += count;
            if count == 0 {
                continue;
            }
            let mut with_le: Vec<(&str, &str)> = labels.to_vec();
            with_le.push(("le", &les[i]));
            self.sample(&bucket_name, &with_le, cumulative as f64);
        }
        let mut with_inf: Vec<(&str, &str)> = labels.to_vec();
        with_inf.push(("le", "+Inf"));
        self.sample(&bucket_name, &with_inf, cumulative as f64);
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, cumulative as f64);
    }

    /// The per-stage span-duration histogram family every tier exposes
    /// (`dither_stage_duration_us{stage="..."}`).
    pub fn stage_histograms(&mut self, snapshots: &[StageSnapshot]) {
        if snapshots.is_empty() {
            return;
        }
        self.family(
            "dither_stage_duration_us",
            "histogram",
            "Per-stage span durations from the request tracer",
        );
        for snap in snapshots {
            self.histogram_series(
                "dither_stage_duration_us",
                &[("stage", snap.stage.name())],
                &snap.buckets,
                snap.sum_us as f64,
                stage_bucket_upper,
            );
        }
    }

    /// The finished exposition text.
    pub fn finish(self) -> String {
        self.out
    }
}

/// Structural well-formedness check over an exposition text: every line
/// is a comment or a `name{labels} value` sample with a parseable value
/// and balanced label quoting, and every sample's family was declared by
/// a preceding `# TYPE` line. Returns the first offending line.
pub fn check_exposition(text: &str) -> Result<(), String> {
    let mut typed: Vec<String> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split_whitespace();
            let name = parts.next().unwrap_or("");
            let kind = parts.next().unwrap_or("");
            if name.is_empty() || !matches!(kind, "counter" | "gauge" | "histogram") {
                return Err(format!("bad TYPE line: {line}"));
            }
            typed.push(name.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // Sample line: name[{labels}] value
        let (series, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("no value separator: {line}"))?;
        value
            .parse::<f64>()
            .map_err(|_| format!("unparseable value: {line}"))?;
        let name = match series.split_once('{') {
            Some((name, labels)) => {
                if !labels.ends_with('}') || labels.matches('"').count() % 2 != 0 {
                    return Err(format!("unbalanced labels: {line}"));
                }
                name
            }
            None => series,
        };
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|base| typed.iter().any(|t| t == base))
            .unwrap_or(name);
        if !typed.iter().any(|t| t == base) {
            return Err(format!("sample without TYPE declaration: {line}"));
        }
    }
    if typed.is_empty() {
        return Err("no metric families".to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::ring::{TraceConfig, Tracer};
    use crate::trace::Stage;
    use std::time::Instant;

    #[test]
    fn scalars_and_labels_render_and_validate() {
        let mut p = PromText::new();
        p.scalar("dither_requests_total", "counter", "Requests served", 42.0);
        p.family("dither_fidelity_mse", "gauge", "Measured MSE");
        p.sample(
            "dither_fidelity_mse",
            &[("model", "digits_linear"), ("scheme", "dither"), ("k", "4")],
            0.125,
        );
        let text = p.finish();
        assert!(text.contains("# TYPE dither_requests_total counter"));
        assert!(text.contains("dither_requests_total 42\n"));
        assert!(text.contains(
            "dither_fidelity_mse{model=\"digits_linear\",scheme=\"dither\",k=\"4\"} 0.125"
        ));
        check_exposition(&text).expect("well-formed");
    }

    #[test]
    fn histogram_emits_cumulative_buckets_inf_sum_count() {
        let mut p = PromText::new();
        p.family("dither_latency_us", "histogram", "Request latency");
        let mut buckets = vec![0u64; 8];
        buckets[2] = 3;
        buckets[5] = 1;
        p.histogram_series(
            "dither_latency_us",
            &[],
            &buckets,
            99.0,
            crate::coordinator::metrics::bucket_upper,
        );
        let text = p.finish();
        assert!(text.contains("dither_latency_us_bucket{le=\"3\"} 3"), "{text}");
        assert!(text.contains("dither_latency_us_bucket{le=\"31\"} 4"), "{text}");
        assert!(text.contains("dither_latency_us_bucket{le=\"+Inf\"} 4"), "{text}");
        assert!(text.contains("dither_latency_us_sum 99"), "{text}");
        assert!(text.contains("dither_latency_us_count 4"), "{text}");
        check_exposition(&text).expect("well-formed");
    }

    #[test]
    fn stage_family_renders_from_a_live_tracer() {
        let t = Tracer::new(TraceConfig {
            rate: 1.0,
            slow_us: 0,
            buffer: 4,
        });
        let mut b = t.begin(1).unwrap();
        let now = Instant::now();
        b.span(Stage::Kernel, now, now);
        t.finish(b);
        let mut p = PromText::new();
        p.stage_histograms(&t.stage_snapshots());
        let text = p.finish();
        assert!(
            text.contains("dither_stage_duration_us_bucket{stage=\"kernel\",le=\"+Inf\"} 1"),
            "{text}"
        );
        check_exposition(&text).expect("well-formed");
    }

    #[test]
    fn checker_rejects_malformed_text() {
        assert!(check_exposition("").is_err(), "empty text has no families");
        assert!(check_exposition("orphan_sample 1\n").is_err());
        assert!(
            check_exposition("# TYPE x counter\nx notanumber\n").is_err(),
            "value must parse"
        );
        assert!(
            check_exposition("# TYPE x counter\nx{a=\"b} 1\n").is_err(),
            "unbalanced quotes"
        );
        assert!(check_exposition("# TYPE x wrongkind\nx 1\n").is_err());
        assert!(check_exposition("# TYPE x counter\nx{a=\"b\"} 1\n").is_ok());
    }
}
