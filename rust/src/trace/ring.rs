//! The per-process tracer: the sampling decision, the per-stage duration
//! histograms behind the `metrics` exposition, and the bounded ring
//! buffer of completed traces the `{"cmd":"trace"}` verb queries.
//!
//! Sampling is the same stateless hash test the shadow sampler uses —
//! `counter_hash(SALT, n) < rate · 2⁶⁴` over an admission counter — so
//! which requests are traced is deterministic for a replayed workload and
//! free of aliasing with periodic traffic. Requests that miss the sample
//! are still carried when a slow-trace threshold is configured: their
//! spans are recorded speculatively and committed only if the finished
//! request exceeded `--trace-slow-us` (always-on promotion for outliers).
//!
//! The ring is a bounded `VecDeque` behind a mutex. Only *committed*
//! traces and `trace` queries ever touch it — span recording itself is
//! lock-free by ownership (see [`crate::trace::context`]) — so at the
//! default 1% sample rate the lock is taken about once per hundred
//! requests, far off the hot path. Per-stage histograms are relaxed
//! atomics, same discipline as [`crate::coordinator::metrics`].

use crate::coordinator::metrics::{bucket_index, bucket_upper, BUCKETS};
use crate::trace::context::{Trace, TraceBuilder};
use crate::trace::Stage;
use crate::util::rng::counter_hash;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Fixed salt for the trace-sampling decision (a different stream from
/// the shadow sampler's, so tracing and shadowing pick independent
/// request subsets at equal rates).
const TRACE_SALT: u64 = 0x7_7ACE;

/// Fixed salt for deriving trace ids from the admission counter.
const ID_SALT: u64 = 0x1D_5EED;

/// Tracing configuration (the `--trace-rate` / `--trace-slow-us` /
/// `--trace-buffer` flags).
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Fraction of admitted requests sampled for tracing (clamped to
    /// `0..=1`; NaN disables sampling).
    pub rate: f64,
    /// Slow-trace promotion threshold in µs (0 disables promotion).
    pub slow_us: u64,
    /// Ring-buffer capacity in completed traces (0 keeps nothing).
    pub buffer: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 0.0,
            slow_us: 0,
            buffer: 256,
        }
    }
}

/// One stage's duration histogram: log₂ buckets plus sum/count, updated
/// with relaxed atomics by whichever thread finishes a trace.
struct StageHist {
    count: AtomicU64,
    sum_us: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl StageHist {
    fn new() -> StageHist {
        StageHist {
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record(&self, dur_us: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(dur_us, Ordering::Relaxed);
        self.buckets[bucket_index(dur_us)].fetch_add(1, Ordering::Relaxed);
    }
}

/// A snapshot of one stage's duration histogram.
#[derive(Clone, Debug)]
pub struct StageSnapshot {
    /// The stage.
    pub stage: Stage,
    /// Spans recorded.
    pub count: u64,
    /// Total duration across those spans, µs.
    pub sum_us: u64,
    /// log₂ duration buckets (edges via
    /// [`crate::coordinator::metrics::bucket_upper`]).
    pub buckets: Vec<u64>,
}

/// The per-process tracer: sampling, stage histograms, and the ring.
pub struct Tracer {
    cfg: TraceConfig,
    /// `rate · 2⁶⁴`, the admission acceptance threshold.
    threshold: u64,
    counter: AtomicU64,
    begun: AtomicU64,
    committed: AtomicU64,
    slow_promoted: AtomicU64,
    evicted: AtomicU64,
    stages: Vec<StageHist>,
    ring: Mutex<VecDeque<Trace>>,
}

impl Tracer {
    /// Tracer from a configuration (rates clamped like the shadow
    /// sampler's).
    pub fn new(cfg: TraceConfig) -> Tracer {
        let rate = if cfg.rate.is_nan() {
            0.0
        } else {
            cfg.rate.clamp(0.0, 1.0)
        };
        let cfg = TraceConfig { rate, ..cfg };
        Tracer {
            threshold: (rate * 18446744073709551616.0) as u64,
            counter: AtomicU64::new(0),
            begun: AtomicU64::new(0),
            committed: AtomicU64::new(0),
            slow_promoted: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            stages: (0..Stage::COUNT).map(|_| StageHist::new()).collect(),
            ring: Mutex::new(VecDeque::new()),
            cfg,
        }
    }

    /// The active configuration (post-clamping).
    pub fn config(&self) -> &TraceConfig {
        &self.cfg
    }

    /// True when any request can ever produce a trace.
    pub fn enabled(&self) -> bool {
        self.cfg.buffer > 0 && (self.cfg.rate > 0.0 || self.cfg.slow_us > 0)
    }

    /// Admission decision for a locally originated request: `None` means
    /// the request carries no trace at all (the common case at low
    /// rates); `Some` is a live builder — sampled, or speculative when
    /// only the slow threshold can commit it.
    pub fn begin(&self, request_id: u64) -> Option<Box<TraceBuilder>> {
        if !self.enabled() {
            return None;
        }
        let n = self.counter.fetch_add(1, Ordering::Relaxed);
        let sampled = self.cfg.rate >= 1.0
            || (self.cfg.rate > 0.0 && counter_hash(TRACE_SALT, n) < self.threshold);
        if !sampled && self.cfg.slow_us == 0 {
            return None;
        }
        self.begun.fetch_add(1, Ordering::Relaxed);
        Some(TraceBuilder::new(
            counter_hash(ID_SALT ^ std::process::id() as u64, n),
            sampled,
            request_id,
        ))
    }

    /// Adopt a trace context propagated from an upstream tier (the proxy's
    /// `"trace":"<id:flags>"` request field). The upstream sampling
    /// decision is honored regardless of this process's own rate, so a
    /// cluster traces coherently end to end; an unsampled tag still gets
    /// a speculative builder when slow promotion is on.
    pub fn adopt(&self, request_id: u64, id: u64, flags: u8) -> Option<Box<TraceBuilder>> {
        if self.cfg.buffer == 0 {
            return None;
        }
        let sampled = flags & crate::trace::context::FLAG_SAMPLED != 0;
        if !sampled && self.cfg.slow_us == 0 {
            return None;
        }
        self.begun.fetch_add(1, Ordering::Relaxed);
        Some(TraceBuilder::new(id, sampled, request_id))
    }

    /// Finish a trace: feed every span into the per-stage histograms,
    /// decide slow promotion, and commit sampled/promoted timelines to
    /// the ring (evicting the oldest past capacity).
    pub fn finish(&self, builder: Box<TraceBuilder>) {
        let total_us = builder.elapsed_us();
        let slow = self.cfg.slow_us > 0 && total_us >= self.cfg.slow_us;
        let commit = builder.sampled() || slow;
        let trace = builder.seal(total_us, slow);
        for span in &trace.spans {
            self.stages[span.stage.slot()].record(span.dur_us);
        }
        if !commit {
            return;
        }
        if slow {
            self.slow_promoted.fetch_add(1, Ordering::Relaxed);
        }
        self.committed.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(trace);
        while ring.len() > self.cfg.buffer {
            ring.pop_front();
            self.evicted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Query the ring, newest first. `model`/`scheme` filter exactly on
    /// the recorded labels; `min_us` keeps traces at least that slow;
    /// `limit` caps the result (0 means no cap).
    pub fn query(
        &self,
        min_us: u64,
        model: Option<&str>,
        scheme: Option<&str>,
        limit: usize,
    ) -> Vec<Trace> {
        let ring = self.ring.lock().unwrap();
        let mut out = Vec::new();
        for trace in ring.iter().rev() {
            if trace.total_us < min_us {
                continue;
            }
            if model.is_some_and(|m| trace.model != m) {
                continue;
            }
            if scheme.is_some_and(|s| trace.scheme != s) {
                continue;
            }
            out.push(trace.clone());
            if limit > 0 && out.len() >= limit {
                break;
            }
        }
        out
    }

    /// Completed traces currently resident in the ring.
    pub fn resident(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Builders handed out (sampled + speculative).
    pub fn begun(&self) -> u64 {
        self.begun.load(Ordering::Relaxed)
    }

    /// Traces committed to the ring over the process lifetime.
    pub fn committed(&self) -> u64 {
        self.committed.load(Ordering::Relaxed)
    }

    /// Traces committed only because they crossed the slow threshold.
    pub fn slow_promoted(&self) -> u64 {
        self.slow_promoted.load(Ordering::Relaxed)
    }

    /// Traces evicted from the full ring.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Snapshot every stage histogram that has recorded at least one span.
    pub fn stage_snapshots(&self) -> Vec<StageSnapshot> {
        Stage::ALL
            .into_iter()
            .filter_map(|stage| {
                let hist = &self.stages[stage.slot()];
                let count = hist.count.load(Ordering::Relaxed);
                if count == 0 {
                    return None;
                }
                Some(StageSnapshot {
                    stage,
                    count,
                    sum_us: hist.sum_us.load(Ordering::Relaxed),
                    buckets: hist
                        .buckets
                        .iter()
                        .map(|b| b.load(Ordering::Relaxed))
                        .collect(),
                })
            })
            .collect()
    }
}

/// Upper edge of a stage-histogram bucket — re-exported next to
/// [`StageSnapshot`] so exposition code does not need the metrics module.
pub fn stage_bucket_upper(index: usize) -> u64 {
    bucket_upper(index)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn tracer(rate: f64, slow_us: u64, buffer: usize) -> Tracer {
        Tracer::new(TraceConfig {
            rate,
            slow_us,
            buffer,
        })
    }

    fn finish_one(t: &Tracer, request_id: u64) -> bool {
        match t.begin(request_id) {
            Some(mut b) => {
                let now = Instant::now();
                b.span(Stage::Parse, now, now);
                b.annotate("digits_linear", "dither", 4);
                t.finish(b);
                true
            }
            None => false,
        }
    }

    #[test]
    fn rate_zero_without_slow_threshold_traces_nothing() {
        let t = tracer(0.0, 0, 64);
        assert!(!t.enabled());
        for i in 0..100 {
            assert!(!finish_one(&t, i));
        }
        assert_eq!((t.begun(), t.committed(), t.resident()), (0, 0, 0));
    }

    #[test]
    fn rate_one_traces_everything_and_ring_is_bounded() {
        let t = tracer(1.0, 0, 8);
        for i in 0..20 {
            assert!(finish_one(&t, i));
        }
        assert_eq!(t.committed(), 20);
        assert_eq!(t.resident(), 8, "ring bounded at --trace-buffer");
        assert_eq!(t.evicted(), 12);
        // Newest first, and the oldest 12 were evicted.
        let traces = t.query(0, None, None, 0);
        assert_eq!(traces.len(), 8);
        assert_eq!(traces[0].request_id, 19);
        assert_eq!(traces[7].request_id, 12);
    }

    #[test]
    fn sampling_fraction_tracks_rate_deterministically() {
        let t = tracer(0.25, 0, 100_000);
        let n = 1000;
        let hits = (0..n).filter(|&i| finish_one(&t, i)).count();
        // The hash stream is fixed: the count is an exact constant near
        // rate·n (locks TRACE_SALT).
        assert!(
            (200..=300).contains(&hits),
            "sampled {hits}/{n} at rate 0.25"
        );
        let again = tracer(0.25, 0, 100_000);
        let hits2 = (0..n).filter(|&i| finish_one(&again, i)).count();
        assert_eq!(hits, hits2, "sampling must be deterministic");
    }

    #[test]
    fn slow_promotion_commits_unsampled_outliers() {
        let t = tracer(0.0, 1, 64); // every >=1µs request promotes
        assert!(t.enabled());
        let mut b = t.begin(5).expect("speculative builder at rate 0");
        assert!(!b.sampled());
        let start = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(2));
        b.span(Stage::Kernel, start, Instant::now());
        t.finish(b);
        assert_eq!(t.committed(), 1);
        assert_eq!(t.slow_promoted(), 1);
        let traces = t.query(0, None, None, 0);
        assert!(traces[0].slow && !traces[0].sampled);
        // A fast request at the same settings records histograms but does
        // not commit.
        let fast = tracer(0.0, u64::MAX, 64);
        let mut b = fast.begin(6).expect("speculative builder");
        let now = Instant::now();
        b.span(Stage::Parse, now, now);
        fast.finish(b);
        assert_eq!(fast.committed(), 0);
        assert_eq!(fast.stage_snapshots().len(), 1, "histograms still fed");
    }

    #[test]
    fn query_filters_compose() {
        let t = tracer(1.0, 0, 64);
        for (i, (model, scheme)) in [
            ("digits_linear", "dither"),
            ("digits_linear", "sr2"),
            ("fashion_mlp", "dither"),
        ]
        .iter()
        .enumerate()
        {
            let mut b = t.begin(i as u64).unwrap();
            b.annotate(model, scheme, 4);
            t.finish(b);
        }
        assert_eq!(t.query(0, Some("digits_linear"), None, 0).len(), 2);
        assert_eq!(t.query(0, None, Some("dither"), 0).len(), 2);
        assert_eq!(t.query(0, Some("fashion_mlp"), Some("dither"), 0).len(), 1);
        assert_eq!(t.query(0, Some("no_such"), None, 0).len(), 0);
        assert_eq!(t.query(u64::MAX, None, None, 0).len(), 0, "min_us filters");
        assert_eq!(t.query(0, None, None, 1).len(), 1, "limit caps");
    }

    #[test]
    fn adopt_honors_upstream_sampling_over_local_rate() {
        let t = tracer(0.0, 0, 64);
        // Locally disabled, but an upstream-sampled tag must still trace.
        let b = t.adopt(9, 0xFEED, crate::trace::context::FLAG_SAMPLED);
        let b = b.expect("upstream-sampled context adopted");
        assert_eq!(b.id(), 0xFEED);
        assert!(b.sampled());
        t.finish(b);
        assert_eq!(t.committed(), 1);
        // An unsampled tag with no slow threshold is dropped.
        assert!(t.adopt(9, 0xFEED, 0).is_none());
        // buffer 0 disables adoption entirely.
        let off = tracer(1.0, 1000, 0);
        assert!(off.adopt(9, 1, 1).is_none());
        assert!(!off.enabled());
    }

    #[test]
    fn stage_histograms_accumulate_durations() {
        let t = tracer(1.0, 0, 4);
        let mut b = t.begin(1).unwrap();
        let start = Instant::now();
        std::thread::sleep(std::time::Duration::from_millis(1));
        b.span(Stage::Queue, start, Instant::now());
        b.span(Stage::Kernel, start, Instant::now());
        t.finish(b);
        let snaps = t.stage_snapshots();
        assert_eq!(snaps.len(), 2);
        for snap in snaps {
            assert_eq!(snap.count, 1);
            assert!(snap.sum_us >= 1000, "{:?} sum {}", snap.stage, snap.sum_us);
            assert_eq!(snap.buckets.iter().sum::<u64>(), 1);
        }
        assert_eq!(stage_bucket_upper(0), 0);
    }
}
