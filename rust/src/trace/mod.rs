//! End-to-end request tracing: span timelines through proxy → shard →
//! kernel, a slow-trace ring buffer, and the Prometheus exposition
//! surface.
//!
//! Aggregate telemetry (`stats` counters, latency histograms, fidelity
//! cells) says *that* p99 moved; this subsystem says *why one request was
//! slow*. Each admitted request gets a trace context — a 64-bit trace id
//! plus a deterministic `counter_hash`-based sampling decision at
//! `--trace-rate`, with always-on promotion for requests exceeding
//! `--trace-slow-us` — and accumulates spans as it moves through the
//! pipeline: parse, window admit, queue wait, batch assembly, auto
//! resolution, plan-cache lookup/build, kernel execute (tagged with the
//! active kernel id and scheme), shadow sampling, serialization, and the
//! writer handoff. The cluster proxy stamps its own route / forward /
//! upstream-wait spans and propagates the context upstream in the request
//! line (`"trace":"<id:flags>"`, proto 3 — older backends ignore the
//! field), so a cluster-level `{"cmd":"trace"}` query stitches
//! cross-process timelines under one trace id.
//!
//! * [`context`] — the trace id, the span vocabulary ([`Stage`]), the
//!   wire tag, and the ownership-based lock-free recording story;
//! * [`ring`] — the per-process [`Tracer`]: sampling, per-stage duration
//!   histograms, and the bounded ring buffer behind `{"cmd":"trace"}`;
//! * [`export`] — the zero-dep Prometheus text-exposition builder behind
//!   `{"cmd":"metrics"}` on both tiers, plus the well-formedness checker
//!   the smoke tests scrape with.

pub mod context;
pub mod export;
pub mod ring;

pub use context::{
    decode_wire, encode_wire, BatchStageTimes, Span, Stage, Trace, TraceBuilder, FLAG_SAMPLED,
};
pub use export::{check_exposition, PromText};
pub use ring::{stage_bucket_upper, StageSnapshot, TraceConfig, Tracer};
