//! `dither` — CLI for the dither-computing framework.
//!
//! Subcommands:
//!
//! * `experiment <id>` — regenerate a paper table/figure (fig1..fig16,
//!   table1, all). `--paper-scale` switches to the paper's full settings.
//! * `train` — train and cache the evaluation models.
//! * `serve` — run the sharded batching inference server.
//! * `proxy` — run the cluster front tier: a consistent-hash proxy over N
//!   backend `serve` processes with health checks and merged stats.
//! * `infer` — one-shot inference through the native engine (smoke path).
//! * `info` — show runtime platform, model zoo and artifact manifest.
//!
//! Run `dither help` for flag details.

use dither::coordinator::{serve, ServerConfig};
use dither::data::{Dataset, Task};
use dither::err;
use dither::experiments::{run_experiment, ExperimentArgs, EXPERIMENT_IDS};
use dither::rounding::SchemeId;
use dither::train::{trained_model, ModelSpec};
use dither::util::cli::Args;
use dither::util::error::Result;

const HELP: &str = "\
dither — hybrid deterministic-stochastic computing framework (ARITH'21 repro)

USAGE:
    dither <command> [flags]

COMMANDS:
    experiment <id>   regenerate a paper result: fig1..fig6, table1, fig8,
                      fig9..fig16, or 'all'
    train             train + cache the evaluation models (model zoo)
    serve             run the sharded inference server (TCP, newline JSON)
    proxy             run the cluster front tier: consistent-hash routing
                      over N backend serve processes (same wire protocol)
    infer             single quantized inference through the native engine
    info              show runtime platform + model zoo + artifacts
    help              this text

GLOBAL FLAGS:
    --kernel NAME     compute kernel: auto | scalar | wide (auto). Selected
                      once at startup; 'auto' picks the widest kernel the
                      CPU supports. The DITHER_KERNEL environment variable
                      overrides this flag (same spellings), so a deploy can
                      force a kernel without editing service scripts. All
                      kernels produce bit-identical deterministic replies.

EXPERIMENT FLAGS (defaults in parentheses):
    --pairs N         operand pairs for fig1-6/table1 (200)
    --trials N        trials per pair (200)
    --ns a,b,c        N sweep (4..1024 powers of 2)
    --ks a,b,c        k sweep for fig8-16 (1..8)
    --matmul-pairs N  matrix pairs for fig8 (20)
    --dim N           matrix dimension for fig8 (100)
    --nn-trials N     trials per (mode,k) for fig9-16 (10)
    --train-n N       training-set size (3000)
    --test-n N        test-set size (500)
    --seed S          master seed
    --out DIR         JSON record directory (results)
    --paper-scale     use the paper's full-scale settings (slow)

SERVE FLAGS:
    --addr HOST:PORT  listen address (127.0.0.1:7878)
    --shards N        serving shards (0 = one per core, capped at 16;
                      explicit values clamped to 1..=64)
    --max-batch N     dynamic batch cap per shard (32)
    --max-wait-us N   batch linger (2000)
    --queue-cap N     bounded per-shard queue depth (256)
    --train-n N       model-zoo training-set size (2000)
    --prewarm-bits L  comma-separated k list whose weight plans are built
                      before traffic (2,4,8; 'none' disables)
    --shadow-rate F   fraction of requests re-run through the exact f64
                      forward pass to feed stats.fidelity (0.02; 0 = off)
    --plan-cache-mb N per-shard plan-cache byte budget in MiB (64; 0
                      disables plan caching and serves the plan-per-call
                      baseline)
    --max-inflight N  per-connection pipelined in-flight window (64);
                      requests beyond it get an immediate 'overloaded'
                      reply carrying their id
    --reply-timeout-ms N  watchdog deadline for an accepted request (120000;
                      0 disables): a reply still outstanding past it is
                      answered 'timeout' and releases its window slot
    --trace-rate F    fraction of admitted requests that record a full
                      span timeline (0; deterministic counter-hash
                      sampling, so replays sample identically)
    --trace-slow-us N promote any request at least this slow (µs) into
                      the trace ring, sampled or not (0 = off)
    --trace-buffer N  completed-trace ring capacity, queryable via
                      {\"cmd\":\"trace\"} (256; 0 disables tracing)
    --slo-p99-us N    latency SLO budget in µs for burn-rate alerting
                      (0 = no latency alert)
    --slo-error-rate F  error-rate SLO threshold, errors+timeouts per
                      request (0 = no error alert)
    --slo-mse-factor F  measured-MSE alert envelope as a multiple of the
                      analytic prior per (model, scheme, k) (8; 0 = off)
    --slo-eval-ms N   SLO evaluator tick in ms (1000; 0 disables the
                      evaluator thread). Alerts stream to watchers and
                      export as dither_alert_active gauges.

PROXY FLAGS:
    --addr HOST:PORT  listen address (127.0.0.1:7900)
    --backends LIST   comma-separated backend serve addresses (required),
                      e.g. 127.0.0.1:7878,127.0.0.1:7879
    --replicas N      virtual nodes per backend on the hash ring (64)
    --backend-inflight N  per-backend pipelined window cap (64); the
                      backend's advertised max_inflight may lower it
    --probe-ms N      health-probe interval in ms (500)
    --probe-timeout-ms N  probe/connect/handshake timeout in ms (2000)
    --max-backoff-ms N    probe backoff ceiling for dead backends (8000)
    --trace-rate F    proxy-side trace sampling (0); sampled requests
                      propagate their context to the serving backend and
                      {\"cmd\":\"trace\"} returns stitched cross-process
                      timelines
    --trace-slow-us N promote any request at least this slow (µs) (0)
    --trace-buffer N  proxy trace-ring capacity (256; 0 disables)

Both serve and proxy answer {\"cmd\":\"metrics\"} (and a raw
'GET /metrics' line) with a Prometheus text exposition, and stream
structured ops events to {\"cmd\":\"watch\"} subscribers (the proxy
stitches every backend's stream into its cluster-wide journal).

INFER FLAGS:
    --model NAME      digits_linear | fashion_mlp (digits_linear)
    --k N             bit width (4)
    --scheme M        any registered scheme — deterministic | stochastic |
                      dither | sr2 | srvb | tpdf | gauss — or auto (dither)
    --max-mse E       error budget for --scheme auto (1.0): the cheapest
                      (scheme, k) whose prior MSE meets E is chosen
";

fn main() -> Result<()> {
    let args = Args::from_env();
    select_kernel(&args);
    match args.command.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("serve") => cmd_serve(&args),
        Some("proxy") => cmd_proxy(&args),
        Some("infer") => cmd_infer(&args),
        Some("info") => cmd_info(&args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown command {other:?}\n");
            print!("{HELP}");
            std::process::exit(2);
        }
    }
}

/// Pin the process-wide compute kernel before any subcommand touches the
/// numeric paths. `DITHER_KERNEL` wins over `--kernel` (both accept
/// auto|scalar|wide); with neither set, the lazy default in
/// [`dither::kernels::active_id`] auto-detects at first use. A bad
/// spelling exits with usage status 2 instead of panicking mid-serve.
fn select_kernel(args: &Args) {
    let (source, spec) = match std::env::var("DITHER_KERNEL") {
        Ok(env) => ("DITHER_KERNEL", env),
        Err(_) => match args.get("kernel") {
            Some(flag) => ("--kernel", flag.to_string()),
            None => return,
        },
    };
    match dither::kernels::resolve(&spec) {
        Ok(id) => dither::kernels::select(id),
        Err(e) => {
            eprintln!("{source}: {e}");
            std::process::exit(2);
        }
    }
}

fn experiment_args(args: &Args) -> ExperimentArgs {
    let base = if args.flag("paper-scale") {
        ExperimentArgs::paper_scale()
    } else {
        ExperimentArgs::default()
    };
    ExperimentArgs {
        pairs: args.parse_or("pairs", base.pairs),
        trials: args.parse_or("trials", base.trials),
        ns: args.parse_list_or("ns", base.ns.clone()),
        ks: args.parse_list_or("ks", base.ks.clone()),
        matmul_pairs: args.parse_or("matmul-pairs", base.matmul_pairs),
        dim: args.parse_or("dim", base.dim),
        nn_trials: args.parse_or("nn-trials", base.nn_trials),
        train_n: args.parse_or("train-n", base.train_n),
        test_n: args.parse_or("test-n", base.test_n),
        seed: args.parse_or("seed", base.seed),
        out_dir: args.str_or("out", &base.out_dir),
    }
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    if id != "all" && !EXPERIMENT_IDS.contains(&id) {
        eprintln!(
            "unknown experiment {id:?}; available: all, {}",
            EXPERIMENT_IDS.join(", ")
        );
        std::process::exit(2);
    }
    run_experiment(id, &experiment_args(args))
}

fn cmd_train(args: &Args) -> Result<()> {
    let train_n = args.parse_or("train-n", 3000usize);
    let test_n = args.parse_or("test-n", 500usize);
    let seed = args.parse_or("seed", 7u64);
    for spec in [ModelSpec::DigitsLinear, ModelSpec::FashionMlp] {
        let path = spec.weights_path(train_n, seed);
        if args.flag("retrain") {
            let _ = std::fs::remove_file(&path);
        }
        let (mlp, _test, acc) = trained_model(spec, train_n, test_n, seed);
        println!(
            "{:?}: {} params, float test accuracy {:.4} -> {}",
            spec,
            mlp.param_count(),
            acc,
            path
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // Comma-separated bit widths to prewarm ("none" disables). Widths
    // outside the servable 1..=16 range are dropped rather than letting a
    // typo panic the quantizer at startup.
    let prewarm = args.str_or("prewarm-bits", "2,4,8");
    let prewarm_bits: Vec<u32> = if prewarm == "none" {
        Vec::new()
    } else {
        prewarm
            .split(',')
            .filter_map(|s| s.trim().parse().ok())
            .filter(|k| (1..=16).contains(k))
            .collect()
    };
    let cfg = ServerConfig {
        addr: args.str_or("addr", "127.0.0.1:7878"),
        shards: args.parse_or("shards", 0usize),
        max_batch: args.parse_or("max-batch", 32usize),
        max_wait_us: args.parse_or("max-wait-us", 2000u64),
        queue_cap: args.parse_or("queue-cap", 256usize),
        train_n: args.parse_or("train-n", 2000usize),
        seed: args.parse_or("seed", 7u64),
        prewarm_bits,
        shadow_rate: args.parse_or("shadow-rate", 0.02f64),
        plan_cache_mb: args.parse_or("plan-cache-mb", 64usize),
        max_inflight: args.parse_or("max-inflight", 64usize),
        reply_timeout_ms: args.parse_or("reply-timeout-ms", 120_000u64),
        trace_rate: args.parse_or("trace-rate", 0.0f64),
        trace_slow_us: args.parse_or("trace-slow-us", 0u64),
        trace_buffer: args.parse_or("trace-buffer", 256usize),
        slo_p99_us: args.parse_or("slo-p99-us", 0u64),
        slo_error_rate: args.parse_or("slo-error-rate", 0.0f64),
        slo_mse_factor: args.parse_or("slo-mse-factor", 8.0f64),
        slo_eval_ms: args.parse_or("slo-eval-ms", 1_000u64),
    };
    serve(&cfg)
}

fn cmd_proxy(args: &Args) -> Result<()> {
    use dither::cluster::{run_proxy, ProxyConfig, DEFAULT_REPLICAS};
    let backends: Vec<String> = args.parse_list_or("backends", Vec::new());
    if backends.is_empty() {
        return Err(err!(
            "proxy requires --backends host:port[,host:port...] (see `dither help`)"
        ));
    }
    let cfg = ProxyConfig {
        addr: args.str_or("addr", "127.0.0.1:7900"),
        backends,
        replicas: args.parse_or("replicas", DEFAULT_REPLICAS),
        backend_inflight: args.parse_or("backend-inflight", 64usize),
        probe_interval_ms: args.parse_or("probe-ms", 500u64),
        probe_timeout_ms: args.parse_or("probe-timeout-ms", 2_000u64),
        max_backoff_ms: args.parse_or("max-backoff-ms", 8_000u64),
        trace_rate: args.parse_or("trace-rate", 0.0f64),
        trace_slow_us: args.parse_or("trace-slow-us", 0u64),
        trace_buffer: args.parse_or("trace-buffer", 256usize),
    };
    run_proxy(&cfg)
}

fn cmd_infer(args: &Args) -> Result<()> {
    use dither::coordinator::Engine;
    let model = args.str_or("model", "digits_linear");
    let mode_str = args.str_or("scheme", &args.str_or("mode", "dither"));
    let (k, mode) = if mode_str == "auto" {
        use dither::fidelity::{choose, FidelityShard};
        // One-shot auto precision: a fresh estimator has no measurements,
        // so the choice comes from the paper-shape prior (the serving
        // path hands the controller live shadow estimates instead).
        let budget = args.parse_or("max-mse", 1.0f64);
        let spec = ModelSpec::from_name(&model)
            .ok_or_else(|| err!("unknown model family {model:?}"))?;
        let choice = choose(&FidelityShard::new(), spec.index(), budget);
        println!(
            "auto: chose scheme={} k={} for max_mse={budget} (predicted mse {:.3e}, {})",
            choice.scheme,
            choice.k,
            choice.predicted_mse,
            if choice.measured { "measured" } else { "prior" }
        );
        (choice.k, choice.scheme)
    } else {
        let mode: SchemeId = mode_str.parse().map_err(|e| err!("invalid --scheme: {e}"))?;
        (args.parse_or("k", 4u32), mode)
    };
    let seed = args.parse_or("seed", 7u64);
    let engine = Engine::new(args.parse_or("train-n", 2000usize), seed);
    // One synthetic test image per class, report predictions.
    let task = if model == "fashion_mlp" {
        Task::Fashion
    } else {
        Task::Digits
    };
    let ds = Dataset::synthesize(task, 10, seed ^ 0x1E57);
    let pixels: Vec<&[f64]> = (0..ds.len()).map(|i| ds.images.row(i)).collect();
    let t = std::time::Instant::now();
    let outputs = engine.infer_batch(&model, k, mode, &pixels)?;
    let elapsed = t.elapsed();
    let mut correct = 0;
    for (i, out) in outputs.iter().enumerate() {
        let label = ds.labels[i];
        if out.pred == label {
            correct += 1;
        }
        println!("sample {i}: label={label} pred={}", out.pred);
    }
    println!(
        "\n{}/{} correct | model={model} k={k} scheme={mode} | {:.1} ms total",
        correct,
        outputs.len(),
        elapsed.as_secs_f64() * 1e3
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    use dither::nn::Mlp;
    use dither::runtime::Runtime;
    let artifacts = args.str_or("artifacts", "artifacts");
    let rt = Runtime::native(&artifacts)?;
    println!("platform: {}", rt.platform());
    println!("kernel: {}", dither::kernels::active_id().name());
    println!("artifacts dir: {artifacts}");
    // Read-only: report cached zoo weights without training on a miss.
    let train_n = args.parse_or("train-n", 2000usize);
    let seed = args.parse_or("seed", 7u64);
    println!("\nmodel zoo (train_n={train_n}, seed={seed}):");
    for spec in [ModelSpec::DigitsLinear, ModelSpec::FashionMlp] {
        let path = spec.weights_path(train_n, seed);
        match Mlp::load(&path) {
            Ok(mlp) => println!(
                "  {:<14} {:>7} params  cached at {path}",
                spec.name(),
                mlp.param_count()
            ),
            Err(_) => println!(
                "  {:<14} not cached (run `dither train` or `dither serve`)",
                spec.name()
            ),
        }
    }
    match rt.manifest() {
        Some(manifest) => {
            println!("\nAOT artifacts (dither N = {}):", manifest.dither_n);
            println!("{:<28} {:>6}  inputs", "artifact", "batch");
            for a in &manifest.artifacts {
                println!("{:<28} {:>6}  {}", a.name, a.batch, a.inputs.join(" "));
            }
        }
        None => println!("\nno AOT artifacts (run `make artifacts` for the Python pipeline)"),
    }
    Ok(())
}
