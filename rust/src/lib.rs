//! # dither — a hybrid deterministic–stochastic computing framework
//!
//! Production-grade reproduction of C. W. Wu, *"Dither computing: a hybrid
//! deterministic-stochastic computing framework"* (ARITH 2021).
//!
//! The library implements, from the bit level up:
//!
//! * [`bitstream`] — the three pulse-sequence computing schemes (stochastic,
//!   deterministic variant, dither) with AND-multiplication and MUX
//!   scaled-addition, plus the bias/variance/EMSE analysis harness.
//! * [`rounding`] — k-bit quantization with deterministic, stochastic and
//!   dither rounding (§VII).
//! * [`linalg`] — fixed-point matrix multiplication engines with the three
//!   rounding-placement strategies of §VII–§VIII.
//! * [`kernels`] — the word/lane-parallel kernel layer: every hot inner
//!   loop (bitstream word ops, the matmul microkernel, per-row rounding)
//!   behind a trait with runtime-dispatched `scalar`/`wide` variants.
//! * [`nn`] — dense network inference with quantized matmuls, and
//!   [`train`] — a pure-Rust SGD trainer producing the evaluation models.
//! * [`data`] — synthetic MNIST-class / Fashion-class datasets (procedural;
//!   see DESIGN.md §4 for the substitution rationale) and an IDX loader.
//! * [`coordinator`] — the sharded batching inference server: K worker
//!   shards with bounded queues, hash-routed connections, per-request
//!   rounding-scheme selection and lock-free per-shard metrics.
//! * [`cluster`] — the multi-node front tier: a consistent-hash proxy
//!   (virtual nodes, health checks, pipelined upstream connections) over
//!   N backend server processes, with cluster-wide `stats` merging.
//! * [`fidelity`] — online fidelity telemetry: shadow sampling against the
//!   exact f64 forward pass, streaming bias/MSE estimators per
//!   `(model, scheme, k)`, and the `"scheme":"auto"` precision controller.
//! * [`trace`] — end-to-end request tracing: sampled span timelines
//!   through proxy → shard → kernel, a slow-trace ring buffer behind
//!   `{"cmd":"trace"}`, and the Prometheus text exposition behind
//!   `{"cmd":"metrics"}`.
//! * [`obs`] — the live ops plane: a bounded structured event journal,
//!   push-based `{"cmd":"watch"}` subscriptions (protocol v4), and the
//!   dual-window SLO burn-rate evaluator behind `dither_alert_active`.
//! * [`runtime`] — execution-environment descriptor + the AOT artifact
//!   manifest emitted by the Python pipeline.
//! * [`experiments`] — regenerators for every figure and table in the paper.
//! * [`util`] — infrastructure substrates (PRNG, stats, JSON, CLI, errors,
//!   thread pools, bench harness, property testing) built in-tree because
//!   the offline environment provides no third-party equivalents — the
//!   crate has zero external dependencies.
//!
//! ## Quickstart
//!
//! ```
//! use dither::bitstream::{Op, Scheme, EvalConfig, evaluate};
//!
//! let cfg = EvalConfig { pairs: 50, trials: 50, seed: 7 };
//! let pairs = cfg.draw_pairs();
//! let d = evaluate(Scheme::Dither, Op::Multiply, 64, &pairs, &cfg);
//! let s = evaluate(Scheme::Stochastic, Op::Multiply, 64, &pairs, &cfg);
//! assert!(d.emse < s.emse); // dither: O(1/N²) vs stochastic Ω(1/N)
//! ```

#![warn(missing_docs)]

pub mod bitstream;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fidelity;
pub mod kernels;
pub mod linalg;
pub mod nn;
pub mod obs;
pub mod rounding;
pub mod runtime;
pub mod trace;
pub mod train;
pub mod util;
