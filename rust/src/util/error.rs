//! In-tree error type: the crate's only error plumbing (no `anyhow` in the
//! offline crate set).
//!
//! [`Error`] is a message plus an optional boxed source, [`Result`] is the
//! crate-wide alias, and the [`Context`] extension trait adds the
//! `.context(..)` / `.with_context(..)` helpers the call sites were written
//! against. The [`bail!`](crate::bail) macro early-returns a formatted
//! error.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message with an optional chained source.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a plain message.
    pub fn msg(msg: impl Into<String>) -> Error {
        Error {
            msg: msg.into(),
            source: None,
        }
    }

    /// Error wrapping a source with a context message.
    pub fn wrap(
        msg: impl Into<String>,
        source: impl std::error::Error + Send + Sync + 'static,
    ) -> Error {
        Error {
            msg: msg.into(),
            source: Some(Box::new(source)),
        }
    }

    /// Add an outer context message, keeping `self` as the source.
    pub fn context(self, msg: impl Into<String>) -> Error {
        Error {
            msg: msg.into(),
            source: Some(Box::new(self)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src: Option<&(dyn std::error::Error + 'static)> =
            self.source.as_deref().map(|s| s as _);
        while let Some(s) = src {
            // A nested crate Error prints only its own message here — its
            // Display would re-render the rest of the chain, duplicating
            // every tail segment.
            if let Some(e) = s.downcast_ref::<Error>() {
                write!(f, ": {}", e.msg)?;
                src = e.source.as_deref().map(|s| s as _);
            } else {
                write!(f, ": {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug (what `main -> Result` prints) shows the full chain too.
        fmt::Display::fmt(self, f)
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source
            .as_deref()
            .map(|s| s as &(dyn std::error::Error + 'static))
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::wrap("io error", e)
    }
}

impl From<crate::util::json::JsonError> for Error {
    fn from(e: crate::util::json::JsonError) -> Error {
        Error::wrap("json error", e)
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring the `anyhow` surface the call sites use.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a static context message.
    fn context(self, msg: impl Into<String>) -> Result<T>;
    /// Wrap the error (or `None`) with a lazily-built context message.
    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E> Context<T> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::wrap(msg, e))
    }

    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::wrap(f(), e))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Return early with a formatted [`Error`] (the `anyhow::bail!` shape).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

/// Build a formatted [`Error`] value (the `anyhow::anyhow!` shape).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_missing() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_chains_sources() {
        let e = Error::wrap("reading manifest", io_missing());
        let s = e.to_string();
        assert!(s.starts_with("reading manifest"), "{s}");
        assert!(s.contains("no such file"), "{s}");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_missing());
        let e = r.context("opening weights").unwrap_err();
        assert!(e.to_string().contains("opening weights"));
        assert!(e.to_string().contains("no such file"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing field {}", "k")).unwrap_err();
        assert_eq!(e.to_string(), "missing field k");
        let some: Option<u32> = Some(7);
        assert_eq!(some.context("unused").unwrap(), 7);
    }

    #[test]
    fn from_io_and_string() {
        fn io_path() -> Result<()> {
            Err(io_missing())?
        }
        assert!(io_path().unwrap_err().to_string().contains("no such file"));
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn bail_and_err_macros() {
        fn f(x: u32) -> Result<u32> {
            if x > 10 {
                bail!("x too large: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(11).unwrap_err().to_string(), "x too large: 11");
        let e = err!("k={} out of range", 99);
        assert_eq!(e.to_string(), "k=99 out of range");
    }

    #[test]
    fn error_context_method_nests() {
        let inner = Error::msg("inner");
        let outer = inner.context("outer");
        assert_eq!(outer.to_string(), "outer: inner");
        assert!(std::error::Error::source(&outer).is_some());
    }

    #[test]
    fn nested_chain_prints_each_segment_once() {
        let e = Error::wrap("outer", Error::wrap("inner", io_missing()));
        assert_eq!(e.to_string(), "outer: inner: no such file");
        let deeper = e.context("outermost");
        assert_eq!(deeper.to_string(), "outermost: outer: inner: no such file");
    }
}
