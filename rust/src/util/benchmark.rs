//! Micro/meso benchmark harness (no `criterion` in the offline crate set).
//!
//! `cargo bench` targets in `rust/benches/` use [`Bench`] with
//! `harness = false`. The harness does warmup, adaptive iteration-count
//! calibration, wall-clock sampling, and reports median / mean / p95 plus an
//! optional throughput line. Results can be dumped as JSON for the perf log.

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::Instant;

/// One benchmark's measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark id, e.g. `bitstream/encode_dither/N=1024`.
    pub name: String,
    /// Seconds per iteration, summarized over samples.
    pub per_iter: Summary,
    /// Items processed per iteration (for throughput), if declared.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    /// Items/second based on median time (None without a throughput decl).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.per_iter.median)
    }

    /// Render as a JSON object (used by `EXPERIMENTS.md §Perf` tooling).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("median_s", Json::Num(self.per_iter.median)),
            ("mean_s", Json::Num(self.per_iter.mean)),
            ("p95_s", Json::Num(self.per_iter.p95)),
            ("samples", Json::Num(self.per_iter.count as f64)),
        ];
        if let Some(tp) = self.throughput() {
            pairs.push(("items_per_s", Json::Num(tp)));
        }
        Json::obj(pairs)
    }
}

/// Benchmark runner configuration + collected results.
pub struct Bench {
    /// Target time per measured sample batch.
    pub sample_target_s: f64,
    /// Number of samples per benchmark.
    pub samples: usize,
    /// Warmup duration.
    pub warmup_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    /// Harness with defaults tuned for sub-second benches.
    /// `DITHER_BENCH_FAST=1` shrinks everything for smoke runs.
    pub fn new() -> Self {
        let fast = std::env::var("DITHER_BENCH_FAST").is_ok();
        Self {
            sample_target_s: if fast { 0.01 } else { 0.05 },
            samples: if fast { 5 } else { 15 },
            warmup_s: if fast { 0.02 } else { 0.2 },
            results: Vec::new(),
        }
    }

    /// Run one benchmark. `f` is the operation under test; its return value
    /// is black-boxed to keep the optimizer honest.
    pub fn bench<R, F: FnMut() -> R>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_with_items(name, None, f)
    }

    /// Run one benchmark declaring `items` processed per call (throughput).
    pub fn bench_items<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items: f64,
        f: F,
    ) -> &BenchResult {
        self.bench_with_items(name, Some(items), f)
    }

    fn bench_with_items<R, F: FnMut() -> R>(
        &mut self,
        name: &str,
        items: Option<f64>,
        mut f: F,
    ) -> &BenchResult {
        // Warmup + calibration: find iters/sample such that one sample batch
        // takes ~sample_target_s.
        let warmup_start = Instant::now();
        let mut iters_per_sample = 1u64;
        let mut one = 0.0;
        while warmup_start.elapsed().as_secs_f64() < self.warmup_s {
            let t = Instant::now();
            black_box(f());
            one = t.elapsed().as_secs_f64().max(1e-9);
        }
        if one > 0.0 {
            iters_per_sample = ((self.sample_target_s / one).ceil() as u64).clamp(1, 1_000_000);
        }

        let mut per_iter = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters_per_sample {
                black_box(f());
            }
            per_iter.push(t.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            per_iter: Summary::of(&per_iter),
            items_per_iter: items,
        };
        print_result(&result);
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Dump all results as a JSON array string.
    pub fn to_json(&self) -> String {
        Json::Arr(self.results.iter().map(|r| r.to_json()).collect()).to_string()
    }

    /// Write results JSON to `path` (creating parent dirs).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json())
    }
}

fn print_result(r: &BenchResult) {
    let med = format_time(r.per_iter.median);
    let p95 = format_time(r.per_iter.p95);
    match r.throughput() {
        Some(tp) => println!(
            "{:<56} median {:>10}  p95 {:>10}  {:>12}/s",
            r.name,
            med,
            p95,
            format_count(tp)
        ),
        None => println!("{:<56} median {:>10}  p95 {:>10}", r.name, med, p95),
    }
}

/// Human-readable seconds.
pub fn format_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Human-readable count (K/M/G).
pub fn format_count(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} K", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Opaque value sink — prevents the optimizer from deleting the benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_numbers() {
        std::env::set_var("DITHER_BENCH_FAST", "1");
        let mut b = Bench::new();
        let r = b
            .bench_items("test/sum", 1000.0, || (0..1000u64).sum::<u64>())
            .clone();
        assert!(r.per_iter.median > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn json_output_parses() {
        std::env::set_var("DITHER_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.bench("a", || 1 + 1);
        b.bench_items("b", 5.0, || 2 + 2);
        let parsed = crate::util::json::Json::parse(&b.to_json()).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[1].get("items_per_s").is_some());
    }

    #[test]
    fn time_formatting() {
        assert!(format_time(2.5e-9).contains("ns"));
        assert!(format_time(2.5e-5).contains("µs"));
        assert!(format_time(2.5e-2).contains("ms"));
        assert!(format_time(2.5).contains(" s"));
        assert_eq!(format_count(1.5e9), "1.50 G");
        assert_eq!(format_count(2.0e3), "2.00 K");
    }
}
