//! Infrastructure substrates: PRNG, statistics, JSON, CLI parsing, error
//! plumbing, thread pools, the bench harness and the property-testing
//! mini-framework.
//!
//! These exist as first-class modules because the offline environment
//! provides no `rand`, `serde`, `clap`, `rayon`, `criterion`, `proptest`
//! or `anyhow`; see DESIGN.md §2 (S2, S18–S23).

pub mod benchmark;
pub mod cli;
pub mod error;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod threadpool;
