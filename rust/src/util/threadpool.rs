//! Thread-parallelism substrates (no `rayon` in the offline crate set).
//!
//! Two kinds of parallelism live here:
//!
//! * **Scoped data-parallelism** — the experiment harness is
//!   embarrassingly parallel over (x, y) pairs and over trials;
//!   [`parallel_map`] and [`parallel_chunks`] split such work over
//!   `std::thread::scope` workers. Chunking is static — every work item in
//!   our use sites costs roughly the same, so static partitioning is
//!   within a few percent of work stealing at a fraction of the
//!   complexity.
//! * **Long-lived named workers** — [`WorkerPool`] owns detached service
//!   threads (the coordinator's serving shards) and joins them on
//!   shutdown.

/// Number of worker threads to use: `DITHER_THREADS` env var if set,
/// otherwise available parallelism (min 1).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("DITHER_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` in parallel, preserving order.
///
/// `f` receives `(index, &item)`. Falls back to a sequential loop for small
/// inputs or single-thread configurations.
pub fn parallel_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = num_threads().min(items.len().max(1));
    if threads <= 1 || items.len() < 2 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = items.len().div_ceil(threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let f = &f;
    std::thread::scope(|scope| {
        for (slot_chunk, (base, item_chunk)) in out
            .chunks_mut(chunk)
            .zip(items.chunks(chunk).enumerate().map(|(ci, c)| (ci * chunk, c)))
        {
            scope.spawn(move || {
                for (off, (slot, item)) in slot_chunk.iter_mut().zip(item_chunk).enumerate() {
                    *slot = Some(f(base + off, item));
                }
            });
        }
    });
    out.into_iter().map(|r| r.expect("worker filled slot")).collect()
}

/// Run `f` once per worker over a contiguous index range split into
/// `num_threads()` chunks; `f(range)` returns a partial result, and the
/// partials are returned in chunk order (for merging, e.g. Welford::merge).
pub fn parallel_chunks<R, F>(len: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    let threads = num_threads().min(len.max(1));
    if threads <= 1 {
        return vec![f(0..len)];
    }
    let chunk = len.div_ceil(threads);
    let ranges: Vec<_> = (0..threads)
        .map(|t| (t * chunk).min(len)..((t + 1) * chunk).min(len))
        .filter(|r| !r.is_empty())
        .collect();
    let f = &f;
    std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .into_iter()
            .map(|r| scope.spawn(move || f(r)))
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    })
}

/// A set of long-lived named worker threads, joined on shutdown.
///
/// Unlike the scoped helpers above, these workers outlive the spawning
/// scope (serving shards run until the server shuts down), so the pool
/// owns their join handles and [`WorkerPool::join_all`] is the explicit
/// rendezvous point.
#[derive(Default)]
pub struct WorkerPool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Empty pool.
    pub fn new() -> WorkerPool {
        WorkerPool::default()
    }

    /// Spawn one named worker running `f` to completion.
    pub fn spawn(&mut self, name: impl Into<String>, f: impl FnOnce() + Send + 'static) {
        let handle = std::thread::Builder::new()
            .name(name.into())
            .spawn(f)
            .expect("spawning worker thread");
        self.handles.push(handle);
    }

    /// Number of workers spawned so far (joined or not).
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// True when no workers have been spawned.
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Join every worker; returns how many panicked (panics are contained,
    /// not propagated, so one crashed shard cannot take down shutdown).
    pub fn join_all(&mut self) -> usize {
        let mut panicked = 0;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        panicked
    }

    /// Join (only) workers that have already finished, dropping their
    /// handles; returns how many panicked. For long-lived owners that keep
    /// spawning — e.g. the accept loop's per-connection threads — so the
    /// handle list does not grow with every worker ever spawned.
    pub fn reap_finished(&mut self) -> usize {
        let mut panicked = 0;
        let mut i = 0;
        while i < self.handles.len() {
            if self.handles[i].is_finished() {
                if self.handles.swap_remove(i).join().is_err() {
                    panicked += 1;
                }
            } else {
                i += 1;
            }
        }
        panicked
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let _ = self.join_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, |i, &x| x * 2 + i as u64);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, items[i] * 2 + i as u64);
        }
    }

    #[test]
    fn map_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(parallel_map(&empty, |_, &x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn chunks_cover_range_exactly() {
        let partials = parallel_chunks(10_001, |r| r.len());
        assert_eq!(partials.iter().sum::<usize>(), 10_001);
    }

    #[test]
    fn chunks_zero_len() {
        let partials = parallel_chunks(0, |r| r.len());
        assert_eq!(partials.iter().sum::<usize>(), 0);
    }

    #[test]
    fn chunk_sums_match_sequential() {
        let partial: Vec<u64> =
            parallel_chunks(5000, |r| r.map(|i| i as u64).sum::<u64>());
        let total: u64 = partial.iter().sum();
        assert_eq!(total, (0..5000u64).sum::<u64>());
    }

    #[test]
    fn worker_pool_runs_and_joins() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let counter = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new();
        assert!(pool.is_empty());
        for i in 0..4 {
            let c = counter.clone();
            pool.spawn(format!("worker-{i}"), move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(pool.len(), 4);
        assert_eq!(pool.join_all(), 0);
        assert_eq!(counter.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn worker_pool_contains_panics() {
        let mut pool = WorkerPool::new();
        pool.spawn("panicker", || panic!("worker crashed"));
        pool.spawn("ok", || {});
        assert_eq!(pool.join_all(), 1);
    }

    #[test]
    fn worker_pool_reaps_finished_workers() {
        use std::sync::mpsc::channel;
        let mut pool = WorkerPool::new();
        let (tx, rx) = channel::<()>();
        pool.spawn("blocked", move || {
            let _ = rx.recv(); // alive until tx drops
        });
        pool.spawn("quick", || {});
        // Wait for the quick worker to finish, then reap: exactly one
        // handle goes away, the blocked one stays.
        for _ in 0..200 {
            pool.reap_finished();
            if pool.len() == 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(pool.len(), 1);
        drop(tx);
        assert_eq!(pool.join_all(), 0);
        assert!(pool.is_empty());
    }
}
