//! Minimal JSON parser/emitter.
//!
//! The offline crate set has no `serde`/`serde_json`, and the coordinator's
//! wire protocol plus the experiment result files need structured data, so
//! this module implements the small JSON subset we use: objects, arrays,
//! strings (with escapes), f64 numbers, booleans and null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object with sorted keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array of numbers.
    pub fn nums(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Get object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if numeric.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize if numeric and integral.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As str if string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool if boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice if array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Array of f64s (None if any element is non-numeric).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.is_finite() {
                    if x.fract() == 0.0 && x.abs() < 1e15 {
                        write!(f, "{}", *x as i64)
                    } else {
                        write!(f, "{x}")
                    }
                } else {
                    // JSON has no Inf/NaN; emit null like most encoders.
                    write!(f, "null")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Human-readable description.
    pub msg: String,
    /// Byte offset in the input.
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err(self.err("unterminated escape"));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs are not needed by our protocol;
                            // map lone surrogates to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    self.pos = start + len;
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("dither".into())),
            ("n", Json::Num(128.0)),
            ("ok", Json::Bool(true)),
            ("xs", Json::nums(&[1.0, 2.5, -3.0])),
            ("nested", Json::obj(vec![("z", Json::Null)])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5e1 , { \"b\" : null } ] } ").unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(25.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
        assert_eq!(
            Json::parse(r#""A\t""#).unwrap().as_str(),
            Some("A\t")
        );
    }

    #[test]
    fn emit_escapes_roundtrip() {
        let j = Json::Str("line1\nline2\t\"q\" \\ \u{1}".into());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e-3").unwrap().as_f64(), Some(0.001));
    }

    #[test]
    fn unicode_strings() {
        let j = Json::parse("\"héllo ✓\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ✓"));
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }
}
