//! Tiny command-line argument parser (no `clap` in the offline crate set).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` style used by the `dither` binary and the examples.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, positional args, and `--key value` opts.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First non-flag token (e.g. `experiment`).
    pub command: Option<String>,
    /// Remaining non-flag tokens.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options; boolean flags map to "true".
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an explicit token list (first token must NOT be argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.options
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else {
                    // `--flag value` unless the next token is another flag.
                    let is_value_next = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_value_next {
                        args.options
                            .insert(stripped.to_string(), it.next().unwrap());
                    } else {
                        args.options.insert(stripped.to_string(), "true".to_string());
                    }
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// Raw option lookup.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Boolean flag: present (and not "false") → true.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some(v) if v != "false")
    }

    /// String option with default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Parse an option as T, with default. Panics with a clear message on a
    /// malformed value (CLI surface; fail fast is the right behaviour).
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        match self.get(key) {
            None => default,
            Some(v) => v
                .parse::<T>()
                .unwrap_or_else(|_| panic!("invalid value for --{key}: {v:?}")),
        }
    }

    /// Comma-separated list of T (e.g. `--ns 4,8,16`). Default on absence.
    pub fn parse_list_or<T: std::str::FromStr>(&self, key: &str, default: Vec<T>) -> Vec<T> {
        match self.get(key) {
            None => default,
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse::<T>()
                        .unwrap_or_else(|_| panic!("invalid list item for --{key}: {s:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("experiment fig1 extra");
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig1", "extra"]);
    }

    #[test]
    fn flag_styles() {
        let a = parse("serve --port 9000 --threads=4 --verbose");
        assert_eq!(a.parse_or("port", 0u16), 9000);
        assert_eq!(a.parse_or("threads", 1usize), 4);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("run --fast --n 8");
        assert!(a.flag("fast"));
        assert_eq!(a.parse_or("n", 0u32), 8);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.parse_or("n", 128usize), 128);
        assert_eq!(a.str_or("mode", "dither"), "dither");
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --ns 4,8,16");
        assert_eq!(a.parse_list_or("ns", vec![0usize]), vec![4, 8, 16]);
        assert_eq!(a.parse_list_or("ks", vec![1u32, 2]), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn malformed_value_panics() {
        let a = parse("x --n abc");
        let _ = a.parse_or("n", 0usize);
    }
}
