//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this module provides the
//! PRNG substrate used everywhere in the library:
//!
//! * [`SplitMix64`] — tiny, fast seeder / stream deriver.
//! * [`Xoshiro256pp`] — the workhorse generator (xoshiro256++ by Blackman &
//!   Vigna), with `jump()` for creating independent parallel streams.
//! * [`counter_hash`] — a stateless counter-based hash (SplitMix64 finalizer)
//!   used to mirror the in-kernel PRNG of the Pallas layer, so Rust-side
//!   reference computations can reproduce kernel randomness bit-for-bit.
//!
//! All generators are deterministic from their seed; every experiment in this
//! repository is reproducible given its `--seed` argument.

/// SplitMix64: a 64-bit generator with a single u64 of state.
///
/// Primarily used to seed [`Xoshiro256pp`] and to derive independent
/// sub-seeds from a master seed (one stream per thread / per matrix element).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Stateless mix of a counter and seed into a u64 (SplitMix64 finalizer).
///
/// `counter_hash(seed, i)` is the canonical per-index random word used by
/// dither/stochastic rounding so that index `i` always sees the same bit
/// stream for a given seed — matching the counter-based PRNG in the Pallas
/// kernel (`python/compile/kernels/prng.py`).
#[inline]
pub fn counter_hash(seed: u64, counter: u64) -> u64 {
    let mut z = seed ^ counter.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Convert a u64 to a uniform f64 in [0, 1) using the top 53 bits.
#[inline]
pub fn u64_to_unit_f64(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// xoshiro256++ — fast, high-quality 256-bit-state generator.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        u64_to_unit_f64(self.next_u64())
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased enough
    /// for simulation use; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (used by the NN weight initializer).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0) by nudging u1 away from zero.
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Jump ahead 2^128 steps: gives an independent stream for parallel use.
    pub fn jump(&mut self) -> Self {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let snapshot = self.clone();
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
        snapshot
    }

    /// Derive `n` independent generators (for per-thread streams).
    pub fn split(&mut self, n: usize) -> Vec<Self> {
        (0..n).map(|_| self.jump()).collect()
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 (matches the published algorithm).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(g.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn xoshiro_uniform_mean() {
        let mut g = Xoshiro256pp::new(7);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| g.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn xoshiro_unit_range() {
        let mut g = Xoshiro256pp::new(3);
        for _ in 0..10_000 {
            let x = g.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn bernoulli_frequency() {
        let mut g = Xoshiro256pp::new(11);
        let p = 0.3;
        let n = 200_000;
        let k = (0..n).filter(|_| g.bernoulli(p)).count();
        let freq = k as f64 / n as f64;
        assert!((freq - p).abs() < 0.01, "freq={freq}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut g = Xoshiro256pp::new(5);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = g.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn jump_streams_diverge() {
        let mut g = Xoshiro256pp::new(9);
        let mut a = g.jump();
        let mut b = g.jump();
        let overlaps = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(overlaps, 0);
    }

    #[test]
    fn counter_hash_stateless_and_distinct() {
        assert_eq!(counter_hash(1, 2), counter_hash(1, 2));
        assert_ne!(counter_hash(1, 2), counter_hash(1, 3));
        assert_ne!(counter_hash(1, 2), counter_hash(2, 2));
    }

    #[test]
    fn normal_moments() {
        let mut g = Xoshiro256pp::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| g.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = Xoshiro256pp::new(17);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
