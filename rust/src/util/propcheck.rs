//! Mini property-based-testing framework (no `proptest` in the offline set).
//!
//! [`check`] drives a property over many random cases generated from a
//! deterministic PRNG, and on failure performs simple shrinking by retrying
//! the property on "smaller" versions of the failing case supplied by the
//! generator's [`Gen::shrink`]. Used by the `property_*.rs` integration tests
//! on the coordinator-invariant and encoding-invariant properties.

use crate::util::rng::Xoshiro256pp;

/// A random-case generator with optional shrinking.
pub trait Gen {
    /// The generated case type.
    type Item: std::fmt::Debug + Clone;
    /// Produce one random case.
    fn gen(&self, rng: &mut Xoshiro256pp) -> Self::Item;
    /// Candidate smaller cases (best-effort; empty = no shrinking).
    fn shrink(&self, _item: &Self::Item) -> Vec<Self::Item> {
        Vec::new()
    }
}

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Seed for the case stream.
    pub seed: u64,
    /// Max shrink iterations after a failure.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            cases: 256,
            seed: 0xD17E8_C0FFEE,
            max_shrink: 200,
        }
    }
}

/// Check `prop` over random cases from `gen`; panics with the (shrunken)
/// counterexample on failure.
pub fn check<G: Gen>(gen: &G, prop: impl Fn(&G::Item) -> bool) {
    check_with(Config::default(), gen, prop)
}

/// [`check`] with explicit configuration.
pub fn check_with<G: Gen>(cfg: Config, gen: &G, prop: impl Fn(&G::Item) -> bool) {
    let mut rng = Xoshiro256pp::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen.gen(&mut rng);
        if prop(&case) {
            continue;
        }
        // Shrink: repeatedly take the first failing smaller candidate.
        let mut worst = case;
        let mut budget = cfg.max_shrink;
        'outer: while budget > 0 {
            for cand in gen.shrink(&worst) {
                budget -= 1;
                if !prop(&cand) {
                    worst = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed at case {case_idx} (seed {:#x})\ncounterexample: {worst:#?}",
            cfg.seed
        );
    }
}

/// Generator for f64 uniform in [lo, hi); shrinks toward lo and midpoints.
pub struct UnitF64 {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl UnitF64 {
    /// The unit interval [0,1).
    pub fn unit() -> Self {
        Self { lo: 0.0, hi: 1.0 }
    }
}

impl Gen for UnitF64 {
    type Item = f64;
    fn gen(&self, rng: &mut Xoshiro256pp) -> f64 {
        rng.uniform(self.lo, self.hi)
    }
    fn shrink(&self, &x: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if x != self.lo {
            out.push(self.lo);
            out.push(self.lo + (x - self.lo) / 2.0);
        }
        out
    }
}

/// Generator for usize in [lo, hi]; shrinks toward lo by halving.
pub struct RangeUsize {
    /// Inclusive lower bound.
    pub lo: usize,
    /// Inclusive upper bound.
    pub hi: usize,
}

impl Gen for RangeUsize {
    type Item = usize;
    fn gen(&self, rng: &mut Xoshiro256pp) -> usize {
        self.lo + rng.below((self.hi - self.lo + 1) as u64) as usize
    }
    fn shrink(&self, &n: &usize) -> Vec<usize> {
        // Graded candidates from far (lo) to near (n-1): the check loop takes
        // the first *failing* candidate, so this bisects toward the boundary.
        let mut out = Vec::new();
        if n > self.lo {
            out.push(self.lo);
            let mut delta = (n - self.lo) / 2;
            while delta > 0 {
                out.push(n - delta);
                delta /= 2;
            }
        }
        out.dedup();
        out
    }
}

/// Pair two generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Item = (A::Item, B::Item);
    fn gen(&self, rng: &mut Xoshiro256pp) -> Self::Item {
        (self.0.gen(rng), self.1.gen(rng))
    }
    fn shrink(&self, (a, b): &Self::Item) -> Vec<Self::Item> {
        let mut out: Vec<Self::Item> = self
            .0
            .shrink(a)
            .into_iter()
            .map(|a2| (a2, b.clone()))
            .collect();
        out.extend(self.1.shrink(b).into_iter().map(|b2| (a.clone(), b2)));
        out
    }
}

/// Vector of cases from an element generator, length in [min_len, max_len].
pub struct VecOf<G> {
    /// Element generator.
    pub elem: G,
    /// Minimum length.
    pub min_len: usize,
    /// Maximum length.
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Item = Vec<G::Item>;
    fn gen(&self, rng: &mut Xoshiro256pp) -> Self::Item {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| self.elem.gen(rng)).collect()
    }
    fn shrink(&self, item: &Self::Item) -> Vec<Self::Item> {
        let mut out = Vec::new();
        if item.len() > self.min_len {
            // Drop the second half, drop one element.
            out.push(item[..self.min_len.max(item.len() / 2)].to_vec());
            let mut one_less = item.clone();
            one_less.pop();
            out.push(one_less);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(&UnitF64::unit(), |&x| (0.0..1.0).contains(&x));
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(&RangeUsize { lo: 0, hi: 1000 }, |&n| n < 500);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Shrinking should land at or near the boundary 500.
        assert!(msg.contains("counterexample"), "{msg}");
        let ce: usize = msg
            .rsplit("counterexample:")
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!((500..=750).contains(&ce), "shrunk to {ce}");
    }

    #[test]
    fn pair_and_vec_generators() {
        check(
            &Pair(UnitF64::unit(), RangeUsize { lo: 1, hi: 64 }),
            |&(x, n)| x < 1.0 && (1..=64).contains(&n),
        );
        check(
            &VecOf {
                elem: UnitF64::unit(),
                min_len: 0,
                max_len: 16,
            },
            |v| v.len() <= 16,
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let g = UnitF64::unit();
        let mut r1 = Xoshiro256pp::new(1);
        let mut r2 = Xoshiro256pp::new(1);
        for _ in 0..10 {
            assert_eq!(g.gen(&mut r1), g.gen(&mut r2));
        }
    }
}
