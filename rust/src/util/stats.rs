//! Streaming and batch statistics used by the analysis harness and benches.
//!
//! [`Welford`] accumulates mean/variance in one numerically-stable pass;
//! [`Summary`] captures the order statistics the bench harness reports; and
//! [`linear_regression`] / [`loglog_slope`] back the Table-I asymptotic-slope
//! estimates.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one observation.
    #[inline]
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 for empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (0 for n < 2).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Population variance (0 for n < 1).
    pub fn variance_population(&self) -> f64 {
        if self.n < 1 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.stddev() / (self.n as f64).sqrt()
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.n as f64;
        let n2 = other.n as f64;
        let delta = other.mean - self.mean;
        let n = n1 + n2;
        self.mean += delta * n2 / n;
        self.m2 += other.m2 + delta * delta * n1 * n2 / n;
        self.n += other.n;
    }
}

/// Batch summary: mean, stddev and selected percentiles.
#[derive(Clone, Debug)]
pub struct Summary {
    /// Number of samples.
    pub count: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation.
    pub stddev: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Summarize a sample set. Returns a zeroed summary for empty input.
    pub fn of(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self {
                count: 0,
                mean: 0.0,
                stddev: 0.0,
                min: 0.0,
                median: 0.0,
                p95: 0.0,
                max: 0.0,
            };
        }
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut w = Welford::new();
        for &x in samples {
            w.push(x);
        }
        Self {
            count: samples.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[sorted.len() - 1],
        }
    }
}

/// Percentile of an ascending-sorted slice via linear interpolation.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Ordinary least-squares fit y = a + b·x. Returns (intercept a, slope b).
pub fn linear_regression(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "regression needs >= 2 points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Slope of log(y) vs log(x): the empirical asymptotic order.
///
/// Points with non-positive y are skipped (a sample bias estimate can be
/// exactly zero); returns `None` when fewer than 2 usable points remain.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> Option<f64> {
    let pts: (Vec<f64>, Vec<f64>) = xs
        .iter()
        .zip(ys)
        .filter(|(&x, &y)| x > 0.0 && y > 0.0)
        .map(|(&x, &y)| (x.ln(), y.ln()))
        .unzip();
    if pts.0.len() < 2 {
        return None;
    }
    Some(linear_regression(&pts.0, &pts.1).1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let mean = 4.0;
        let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / 4.0;
        assert!((w.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut all = Welford::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Welford::new();
        let mut b = Welford::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.median - 50.5).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 0.1);
    }

    #[test]
    fn regression_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 - 2.0 * x).collect();
        let (a, b) = linear_regression(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b + 2.0).abs() < 1e-9);
    }

    #[test]
    fn loglog_slope_of_inverse_square() {
        let xs: Vec<f64> = [4.0, 8.0, 16.0, 32.0, 64.0].to_vec();
        let ys: Vec<f64> = xs.iter().map(|x| 5.0 / (x * x)).collect();
        let slope = loglog_slope(&xs, &ys).unwrap();
        assert!((slope + 2.0).abs() < 1e-9, "slope={slope}");
    }

    #[test]
    fn loglog_slope_skips_zeros() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys = [0.0, 1.0, 0.5, 0.25];
        let slope = loglog_slope(&xs, &ys).unwrap();
        assert!((slope + 1.0).abs() < 1e-9);
    }
}
