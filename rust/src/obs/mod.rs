//! Live ops plane: the structured event journal, push-based watch
//! subscriptions, and SLO burn-rate alerting.
//!
//! Traces (PR 8) answer *why was this request slow* and the fidelity
//! controller (PR 9) *schedules* precision against declared budgets;
//! this subsystem is the third leg — it *tells an operator when the
//! paper's Θ(1/N²)-vs-Θ(1/N) economics stop holding in production*,
//! without anyone polling.
//!
//! * [`journal`] — the bounded per-process [`Journal`] of structured
//!   [`Event`]s, the [`Subscription`] fan-out behind the `{"cmd":"watch"}`
//!   verb (protocol v4), the active-alert set, and the
//!   `dither_alert_active` / `dither_build_info` Prometheus families;
//! * [`slo`] — the dual-window [`SloEvaluator`]: lifetime-counter deltas
//!   and the fidelity snapshot folded into burn-rate alerts (p99 vs
//!   budget, error rate vs threshold, measured MSE vs the scheme's prior
//!   envelope) plus delta-derived journal events.
//!
//! Both tiers own one journal each. The backend's evaluator thread
//! publishes into its local journal; the cluster proxy subscribes to
//! every healthy backend's journal over the wire and stitches the
//! streams (tagged with the originating backend) into its own, so a
//! single cluster-level watch observes the whole fleet.

pub mod journal;
pub mod slo;

pub use journal::{
    append_build_info, format_event_line, parse_event_line, Event, EventKind, Journal, Severity,
    Subscription, DEFAULT_JOURNAL_CAP, DEFAULT_SUB_QUEUE,
};
pub use slo::{
    MseCell, SloEvaluator, SloPolicy, SloSample, FAST_TICKS, MSE_MIN_SAMPLES, OVERLOAD_CLEAR_TICKS,
    PLAN_EVICT_STORM, SLOW_TICKS,
};
