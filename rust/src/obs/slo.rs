//! SLO burn-rate evaluation: fold lifetime counters, latency histograms,
//! and the fidelity controller's measured-MSE snapshot into journal
//! events and active alerts.
//!
//! The evaluator runs on a slow tick (default 1 s, `--slo-eval-ms`) far
//! off the request hot path. Each tick it takes one **cumulative**
//! [`SloSample`], differences it against the previous tick, and keeps the
//! per-tick deltas in a bounded window. Alerts use the classic
//! dual-window burn-rate shape: a *fast* window (last [`FAST_TICKS`]
//! ticks) and a *slow* window (last [`SLOW_TICKS`]) must **both** breach
//! for an alert to fire — a single hiccup inside an otherwise healthy
//! slow window stays quiet — and a firing alert clears as soon as the
//! fast window is clean again, so recovery is observed promptly.
//!
//! Three alert families, each disabled when its budget is zero:
//!
//! * `latency_p99` — p99 recomputed from the windowed log₂ histogram
//!   deltas vs the declared `--slo-p99-us` budget;
//! * `error_rate` — (errors + timeouts) / requests vs `--slo-error-rate`;
//! * `mse` — per `(model, scheme, k)` cell with enough shadow samples:
//!   measured MSE vs `--slo-mse-factor ×` the scheme's dither-prior
//!   envelope (the Θ(1/N²) economics of the paper; a cell drifting past
//!   the envelope means the deterministic-stochastic tradeoff stopped
//!   paying for itself).
//!
//! The same tick also converts counter deltas into discrete journal
//! events (overload onset/clear with hysteresis, watchdog timeouts,
//! slow-trace promotions, plan-cache eviction storms, infeasible auto
//! resolutions) so the hot path never publishes for these itself.

use crate::coordinator::metrics::percentile_from_buckets;
use crate::obs::journal::{EventKind, Journal, Severity};
use std::collections::{BTreeMap, VecDeque};

/// Fast burn-rate window, in evaluator ticks.
pub const FAST_TICKS: usize = 5;

/// Slow burn-rate window, in evaluator ticks.
pub const SLOW_TICKS: usize = 30;

/// Consecutive reject-free ticks before overload is declared cleared.
pub const OVERLOAD_CLEAR_TICKS: u32 = 3;

/// Plan-cache evictions inside one tick that count as a storm.
pub const PLAN_EVICT_STORM: u64 = 16;

/// Shadow samples a fidelity cell needs before its MSE is alertable
/// (mirrors the controller's trust threshold).
pub const MSE_MIN_SAMPLES: u64 = 256;

/// Consecutive breaching ticks before an `mse` alert fires (and clean
/// ticks before it clears) — shadow sampling is noisy at the margin.
pub const MSE_STREAK: u32 = 2;

/// Declared service-level objectives. A zero field disables that alert
/// family; [`SloPolicy::disabled`] disables the evaluator entirely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// p99 latency budget in microseconds (0 = off).
    pub p99_us: u64,
    /// Highest acceptable (errors + timeouts) / requests (0.0 = off).
    pub error_rate: f64,
    /// Measured-MSE alarm threshold as a multiple of the scheme's prior
    /// envelope (0.0 = off).
    pub mse_factor: f64,
    /// Evaluator tick interval in milliseconds (0 = evaluator off).
    pub eval_ms: u64,
}

impl SloPolicy {
    /// Everything off — no evaluator thread is spawned.
    pub fn disabled() -> SloPolicy {
        SloPolicy {
            p99_us: 0,
            error_rate: 0.0,
            mse_factor: 0.0,
            eval_ms: 0,
        }
    }

    /// Should an evaluator run at all?
    pub fn enabled(&self) -> bool {
        self.eval_ms > 0
            && (self.p99_us > 0 || self.error_rate > 0.0 || self.mse_factor > 0.0)
    }
}

impl Default for SloPolicy {
    fn default() -> Self {
        SloPolicy::disabled()
    }
}

/// One tick's **cumulative** lifetime counters; the evaluator does the
/// differencing. Collected from `MetricsHandle`, the tracer, and the
/// per-shard plan caches.
#[derive(Clone, Debug, Default)]
pub struct SloSample {
    /// Requests completed.
    pub requests: u64,
    /// Server-side errors.
    pub errors: u64,
    /// Requests bounced with `overloaded`.
    pub rejected: u64,
    /// Watchdog-expired requests.
    pub timeouts: u64,
    /// Tracer slow-promotions.
    pub slow_promoted: u64,
    /// Plan-cache evictions.
    pub plan_evictions: u64,
    /// Budget-infeasible auto resolutions.
    pub auto_infeasible: u64,
    /// Lifetime log₂ latency histogram (length [`crate::coordinator::BUCKETS`]).
    pub latency_buckets: Vec<u64>,
}

/// One measured-MSE cell from the fidelity snapshot, with its prior
/// envelope already attached by the caller (keeps this module decoupled
/// from the controller's types).
#[derive(Clone, Debug, PartialEq)]
pub struct MseCell {
    /// Model family wire name.
    pub model: String,
    /// Rounding scheme wire name.
    pub scheme: String,
    /// Bit width.
    pub k: u32,
    /// Measured shadow MSE.
    pub mse: f64,
    /// Shadow samples behind the estimate.
    pub samples: u64,
    /// Prior MSE envelope for this (scheme, k).
    pub prior: f64,
}

/// Per-tick deltas derived from consecutive [`SloSample`]s.
#[derive(Clone, Debug, Default)]
struct Delta {
    requests: u64,
    errors: u64,
    rejected: u64,
    timeouts: u64,
    latency_buckets: Vec<u64>,
}

/// The dual-window burn-rate evaluator. Pure state machine: feed it one
/// cumulative sample per tick via [`SloEvaluator::observe`] and it
/// publishes events / flips alerts on the journal it is handed — no
/// threads, no clocks, so tests drive it tick by tick.
#[derive(Debug)]
pub struct SloEvaluator {
    policy: SloPolicy,
    last: Option<SloSample>,
    window: VecDeque<Delta>,
    latency_active: bool,
    error_active: bool,
    overload: bool,
    overload_clean: u32,
    mse_streaks: BTreeMap<(String, String, u32), u32>,
    mse_active: BTreeMap<(String, String, u32), bool>,
}

impl SloEvaluator {
    /// Evaluator for `policy`.
    pub fn new(policy: SloPolicy) -> SloEvaluator {
        SloEvaluator {
            policy,
            last: None,
            window: VecDeque::new(),
            latency_active: false,
            error_active: false,
            overload: false,
            overload_clean: 0,
            mse_streaks: BTreeMap::new(),
            mse_active: BTreeMap::new(),
        }
    }

    /// The policy this evaluator enforces.
    pub fn policy(&self) -> &SloPolicy {
        &self.policy
    }

    /// Fold one tick: difference `sample` against the previous tick,
    /// emit delta-derived events, and re-evaluate every alert family.
    /// The first call only establishes the baseline.
    pub fn observe(&mut self, sample: SloSample, cells: &[MseCell], journal: &Journal) {
        let Some(prev) = self.last.take() else {
            self.last = Some(sample);
            return;
        };
        let delta = Delta {
            requests: sample.requests.saturating_sub(prev.requests),
            errors: sample.errors.saturating_sub(prev.errors),
            rejected: sample.rejected.saturating_sub(prev.rejected),
            timeouts: sample.timeouts.saturating_sub(prev.timeouts),
            latency_buckets: sample
                .latency_buckets
                .iter()
                .zip(prev.latency_buckets.iter().chain(std::iter::repeat(&0)))
                .map(|(c, p)| c.saturating_sub(*p))
                .collect(),
        };
        self.delta_events(&sample, &prev, &delta, journal);
        self.last = Some(sample);
        self.window.push_back(delta);
        while self.window.len() > SLOW_TICKS {
            self.window.pop_front();
        }
        self.latency_alert(journal);
        self.error_alert(journal);
        self.mse_alerts(cells, journal);
    }

    /// Discrete events from this tick's counter movement.
    fn delta_events(&mut self, cur: &SloSample, prev: &SloSample, delta: &Delta, journal: &Journal) {
        if delta.rejected > 0 {
            self.overload_clean = 0;
            if !self.overload {
                self.overload = true;
                journal.publish(
                    Severity::Warn,
                    EventKind::OverloadOnset,
                    &[("rejected", &delta.rejected.to_string())],
                );
            }
        } else if self.overload {
            self.overload_clean += 1;
            if self.overload_clean >= OVERLOAD_CLEAR_TICKS {
                self.overload = false;
                self.overload_clean = 0;
                journal.publish(Severity::Info, EventKind::OverloadClear, &[]);
            }
        }
        if delta.timeouts > 0 {
            journal.publish(
                Severity::Error,
                EventKind::WatchdogTimeout,
                &[("count", &delta.timeouts.to_string())],
            );
        }
        let promoted = cur.slow_promoted.saturating_sub(prev.slow_promoted);
        if promoted > 0 {
            journal.publish(
                Severity::Info,
                EventKind::SlowPromotion,
                &[("count", &promoted.to_string())],
            );
        }
        let evictions = cur.plan_evictions.saturating_sub(prev.plan_evictions);
        if evictions >= PLAN_EVICT_STORM {
            journal.publish(
                Severity::Warn,
                EventKind::PlanEvictStorm,
                &[("evictions", &evictions.to_string())],
            );
        }
        let infeasible = cur.auto_infeasible.saturating_sub(prev.auto_infeasible);
        if infeasible > 0 {
            journal.publish(
                Severity::Warn,
                EventKind::AutoInfeasible,
                &[("count", &infeasible.to_string())],
            );
        }
    }

    /// Summed bucket deltas plus request/error totals over the last
    /// `ticks` window entries.
    fn window_totals(&self, ticks: usize) -> (Vec<u64>, u64, u64) {
        let mut buckets: Vec<u64> = Vec::new();
        let (mut requests, mut errors) = (0u64, 0u64);
        for d in self.window.iter().rev().take(ticks) {
            requests += d.requests;
            errors += d.errors + d.timeouts;
            if buckets.len() < d.latency_buckets.len() {
                buckets.resize(d.latency_buckets.len(), 0);
            }
            for (acc, v) in buckets.iter_mut().zip(d.latency_buckets.iter()) {
                *acc += v;
            }
        }
        (buckets, requests, errors)
    }

    fn latency_alert(&mut self, journal: &Journal) {
        if self.policy.p99_us == 0 {
            return;
        }
        let breach = |ticks: usize| {
            let (buckets, _, _) = self.window_totals(ticks);
            buckets.iter().sum::<u64>() > 0
                && percentile_from_buckets(&buckets, 0.99) > self.policy.p99_us as f64
        };
        let fast = breach(FAST_TICKS);
        let active = if self.latency_active { fast } else { fast && breach(SLOW_TICKS) };
        if active != self.latency_active {
            self.latency_active = active;
            journal.set_alert(
                "latency_p99",
                &[("budget_us", &self.policy.p99_us.to_string())],
                active,
            );
        }
    }

    fn error_alert(&mut self, journal: &Journal) {
        if self.policy.error_rate <= 0.0 {
            return;
        }
        let breach = |ticks: usize| {
            let (_, requests, errors) = self.window_totals(ticks);
            requests > 0 && errors as f64 / requests as f64 > self.policy.error_rate
        };
        let fast = breach(FAST_TICKS);
        let active = if self.error_active { fast } else { fast && breach(SLOW_TICKS) };
        if active != self.error_active {
            self.error_active = active;
            journal.set_alert(
                "error_rate",
                &[("threshold", &format!("{}", self.policy.error_rate))],
                active,
            );
        }
    }

    fn mse_alerts(&mut self, cells: &[MseCell], journal: &Journal) {
        if self.policy.mse_factor <= 0.0 {
            return;
        }
        for cell in cells {
            if cell.samples < MSE_MIN_SAMPLES || cell.prior <= 0.0 {
                continue;
            }
            let key = (cell.model.clone(), cell.scheme.clone(), cell.k);
            let breach = cell.mse > self.policy.mse_factor * cell.prior;
            let streak = self.mse_streaks.entry(key.clone()).or_insert(0);
            let active = self.mse_active.entry(key.clone()).or_insert(false);
            // One streak counter serves both directions: consecutive
            // breaching ticks arm the alert, consecutive clean ticks
            // disarm it.
            if breach != *active {
                *streak += 1;
            } else {
                *streak = 0;
            }
            if *streak >= MSE_STREAK {
                *streak = 0;
                *active = breach;
                let k = cell.k.to_string();
                journal.set_alert(
                    "mse",
                    &[
                        ("model", &cell.model),
                        ("scheme", &cell.scheme),
                        ("k", &k),
                    ],
                    breach,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> SloPolicy {
        SloPolicy {
            p99_us: 1_000,
            error_rate: 0.1,
            mse_factor: 8.0,
            eval_ms: 100,
        }
    }

    /// Cumulative sample where every one of `n` requests landed in the
    /// histogram bucket holding `latency_us`.
    fn sample(requests: u64, errors: u64, latency_us: u64) -> SloSample {
        let mut buckets = vec![0u64; crate::coordinator::BUCKETS];
        let idx = (64 - latency_us.max(1).leading_zeros() as usize).saturating_sub(1);
        buckets[idx.min(crate::coordinator::BUCKETS - 1)] = requests;
        SloSample {
            requests,
            errors,
            latency_buckets: buckets,
            ..SloSample::default()
        }
    }

    fn alert_names(j: &Journal) -> Vec<String> {
        j.active_alerts()
            .iter()
            .map(|a| a["alert"].clone())
            .collect()
    }

    #[test]
    fn disabled_policy_reports_disabled() {
        assert!(!SloPolicy::disabled().enabled());
        assert!(policy().enabled());
        assert!(!SloPolicy { eval_ms: 0, ..policy() }.enabled());
    }

    #[test]
    fn latency_alert_fires_on_sustained_breach_and_clears() {
        let j = Journal::new(64);
        let mut e = SloEvaluator::new(policy());
        // Baseline, then slow traffic: every tick's p99 lands way past
        // the 1 ms budget.
        let mut total = 0u64;
        e.observe(sample(total, 0, 50_000), &[], &j);
        for _ in 0..3 {
            total += 100;
            e.observe(sample(total, 0, 50_000), &[], &j);
        }
        assert_eq!(alert_names(&j), vec!["latency_p99"]);
        // Traffic stops: fast window drains to zero counts → clear.
        for _ in 0..FAST_TICKS + 1 {
            e.observe(sample(total, 0, 50_000), &[], &j);
        }
        assert!(alert_names(&j).is_empty(), "{:?}", j.recent(16));
        let kinds: Vec<EventKind> = j.recent(16).iter().map(|ev| ev.kind).collect();
        assert!(kinds.contains(&EventKind::AlertFired));
        assert!(kinds.contains(&EventKind::AlertCleared));
    }

    #[test]
    fn fast_latency_is_quiet_within_budget() {
        let j = Journal::new(64);
        let mut e = SloEvaluator::new(policy());
        let mut total = 0u64;
        for _ in 0..10 {
            total += 100;
            e.observe(sample(total, 0, 100), &[], &j);
        }
        assert!(alert_names(&j).is_empty());
    }

    #[test]
    fn error_rate_alert_uses_dual_window() {
        let j = Journal::new(64);
        let mut e = SloEvaluator::new(policy());
        let (mut reqs, mut errs) = (0u64, 0u64);
        e.observe(sample(reqs, errs, 100), &[], &j);
        for _ in 0..4 {
            reqs += 100;
            errs += 50; // 50% error rate, budget is 10%
            e.observe(sample(reqs, errs, 100), &[], &j);
        }
        assert!(alert_names(&j).contains(&"error_rate".to_string()));
        // Healthy traffic pushes the fast-window rate back under budget.
        for _ in 0..FAST_TICKS + 1 {
            reqs += 1_000;
            e.observe(sample(reqs, errs, 100), &[], &j);
        }
        assert!(!alert_names(&j).contains(&"error_rate".to_string()));
    }

    #[test]
    fn mse_alert_needs_samples_and_a_streak() {
        let j = Journal::new(64);
        let mut e = SloEvaluator::new(policy());
        let hot = |samples: u64| MseCell {
            model: "digits_linear".to_string(),
            scheme: "dither".to_string(),
            k: 4,
            mse: 100.0,
            samples,
            prior: 1.0,
        };
        let mut reqs = 0u64;
        e.observe(sample(reqs, 0, 100), &[hot(1)], &j);
        for _ in 0..4 {
            reqs += 10;
            e.observe(sample(reqs, 0, 100), &[hot(1)], &j);
        }
        assert!(alert_names(&j).is_empty(), "undersampled cell never alerts");
        for _ in 0..MSE_STREAK {
            reqs += 10;
            e.observe(sample(reqs, 0, 100), &[hot(10_000)], &j);
        }
        assert_eq!(alert_names(&j), vec!["mse"]);
        // Back inside the envelope for the clear streak.
        let cool = MseCell { mse: 0.5, ..hot(10_000) };
        for _ in 0..MSE_STREAK {
            reqs += 10;
            e.observe(sample(reqs, 0, 100), &[cool.clone()], &j);
        }
        assert!(alert_names(&j).is_empty());
    }

    #[test]
    fn delta_counters_become_events_with_overload_hysteresis() {
        let j = Journal::new(64);
        let mut e = SloEvaluator::new(policy());
        let mut s = sample(10, 0, 100);
        e.observe(s.clone(), &[], &j);
        s.requests += 10;
        s.rejected = 5;
        s.timeouts = 1;
        s.slow_promoted = 2;
        s.plan_evictions = PLAN_EVICT_STORM;
        s.auto_infeasible = 3;
        e.observe(s.clone(), &[], &j);
        let kinds: Vec<EventKind> = j.recent(16).iter().map(|ev| ev.kind).collect();
        for want in [
            EventKind::OverloadOnset,
            EventKind::WatchdogTimeout,
            EventKind::SlowPromotion,
            EventKind::PlanEvictStorm,
            EventKind::AutoInfeasible,
        ] {
            assert!(kinds.contains(&want), "missing {want:?} in {kinds:?}");
        }
        // No further rejects: clear only after the hysteresis streak.
        for _ in 0..OVERLOAD_CLEAR_TICKS {
            s.requests += 10;
            e.observe(s.clone(), &[], &j);
        }
        let kinds: Vec<EventKind> = j.recent(4).iter().map(|ev| ev.kind).collect();
        assert_eq!(kinds[0], EventKind::OverloadClear, "{kinds:?}");
        let onsets = j
            .recent(64)
            .iter()
            .filter(|ev| ev.kind == EventKind::OverloadOnset)
            .count();
        assert_eq!(onsets, 1, "hysteresis: one onset for one episode");
    }
}
