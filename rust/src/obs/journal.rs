//! Bounded per-process event journal with push-based watch subscriptions.
//!
//! The journal is the ops-plane sibling of [`crate::trace::Tracer`]: a
//! bounded ring of structured [`Event`]s (overload onset/clear, backend
//! mark-down/up, watchdog timeouts, slow-trace promotions, plan-cache
//! eviction storms, auto-resolution infeasibility, scheme switches, SLO
//! alert transitions) each carrying a per-process monotonic timestamp, a
//! [`Severity`], and a small label map. Publication is cheap — one short
//! ring lock plus a fan-out over registered [`Subscription`]s — and the
//! journal never blocks the publisher: subscriber queues are bounded and
//! drop-oldest, counting what they shed.
//!
//! Subscriptions back the `{"cmd":"watch"}` protocol verb (proto v4):
//! each live watch holds one [`Subscription`] whose queued lines the
//! owning connection's reader loop pumps into the shared writer channel.
//! Delivery is therefore stream-only — a subscriber sees events published
//! *after* it registered, never a replay — which is what makes cluster
//! re-subscription after a backend bounce duplicate-free by construction.
//!
//! The journal also owns the process's **active-alert set**: the SLO
//! evaluator flips alerts through [`Journal::set_alert`], which publishes
//! [`EventKind::AlertFired`] / [`EventKind::AlertCleared`] transitions and
//! feeds the `dither_alert_active` gauge family rendered by
//! [`Journal::append_prometheus`].

use crate::trace::PromText;
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Default journal ring capacity (events retained for inspection).
pub const DEFAULT_JOURNAL_CAP: usize = 1024;

/// Default per-subscriber queue bound (lines pending delivery).
pub const DEFAULT_SUB_QUEUE: usize = 256;

/// Event severity, ordered `Info < Warn < Error` so a subscription's
/// minimum-severity filter is a plain comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Routine lifecycle signal (process start, overload cleared, ...).
    Info,
    /// Degradation worth an operator's glance (overload onset, alert).
    Warn,
    /// Losing work or failing a declared objective (watchdog timeout).
    Error,
}

impl Severity {
    /// Wire name (`info` / `warn` / `error`).
    pub fn wire_name(&self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }

    /// Parse a wire name back to a severity.
    pub fn from_wire(name: &str) -> Option<Severity> {
        match name {
            "info" => Some(Severity::Info),
            "warn" => Some(Severity::Warn),
            "error" => Some(Severity::Error),
            _ => None,
        }
    }
}

/// What happened. The set is closed on purpose: every kind is a signal
/// an operator can subscribe to by name, and the wire names are part of
/// protocol v4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Process came up (labels: kernel, schemes).
    ProcessStart,
    /// Queue backpressure started rejecting work (labels: rejected).
    OverloadOnset,
    /// Backpressure drained — no rejects for several evaluator ticks.
    OverloadClear,
    /// Cluster health monitor marked a backend down (labels: backend).
    BackendDown,
    /// Cluster health monitor probed a backend back up (labels: backend).
    BackendUp,
    /// Reply watchdog expired in-flight requests (labels: count).
    WatchdogTimeout,
    /// Tracer promoted slow requests past the sampling gate (labels: count).
    SlowPromotion,
    /// Plan cache churned hard inside one window (labels: evictions).
    PlanEvictStorm,
    /// Auto resolution could not satisfy a declared budget (labels: count).
    AutoInfeasible,
    /// Auto resolution moved a model to a new (scheme, k) operating point.
    SchemeSwitch,
    /// An SLO burn-rate alert started firing (labels: alert + context).
    AlertFired,
    /// A previously firing SLO alert stopped (labels: alert + context).
    AlertCleared,
}

impl EventKind {
    /// Every kind, in wire order (drives filters and property tests).
    pub const ALL: [EventKind; 12] = [
        EventKind::ProcessStart,
        EventKind::OverloadOnset,
        EventKind::OverloadClear,
        EventKind::BackendDown,
        EventKind::BackendUp,
        EventKind::WatchdogTimeout,
        EventKind::SlowPromotion,
        EventKind::PlanEvictStorm,
        EventKind::AutoInfeasible,
        EventKind::SchemeSwitch,
        EventKind::AlertFired,
        EventKind::AlertCleared,
    ];

    /// Wire name of this kind.
    pub fn wire_name(&self) -> &'static str {
        match self {
            EventKind::ProcessStart => "process_start",
            EventKind::OverloadOnset => "overload_onset",
            EventKind::OverloadClear => "overload_clear",
            EventKind::BackendDown => "backend_down",
            EventKind::BackendUp => "backend_up",
            EventKind::WatchdogTimeout => "watchdog_timeout",
            EventKind::SlowPromotion => "slow_promotion",
            EventKind::PlanEvictStorm => "plan_evict_storm",
            EventKind::AutoInfeasible => "auto_infeasible",
            EventKind::SchemeSwitch => "scheme_switch",
            EventKind::AlertFired => "alert_fired",
            EventKind::AlertCleared => "alert_cleared",
        }
    }

    /// Parse a wire name back to a kind.
    pub fn from_wire(name: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.wire_name() == name)
    }
}

/// One journal entry. `seq` is a per-process dense sequence number (a
/// subscriber observing a gap knows exactly how many events it missed)
/// and `t_us` is microseconds since the journal was created — monotonic
/// within a process, never wall-clock.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    /// Dense per-journal sequence number, starting at 1.
    pub seq: u64,
    /// Microseconds since journal creation (monotonic clock).
    pub t_us: u64,
    /// Severity class.
    pub severity: Severity,
    /// What happened.
    pub kind: EventKind,
    /// Key/value context labels (model, backend, alert name, counts...).
    pub labels: BTreeMap<String, String>,
}

impl Event {
    /// Wire shape: `{"seq":N,"t_us":N,"severity":"...","kind":"...",
    /// "labels":{...}}`.
    pub fn to_json(&self) -> Json {
        let labels: BTreeMap<String, Json> = self
            .labels
            .iter()
            .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
            .collect();
        Json::obj(vec![
            ("seq", Json::Num(self.seq as f64)),
            ("t_us", Json::Num(self.t_us as f64)),
            ("severity", Json::Str(self.severity.wire_name().to_string())),
            ("kind", Json::Str(self.kind.wire_name().to_string())),
            ("labels", Json::Obj(labels)),
        ])
    }

    /// Parse the wire shape back. Unknown severities/kinds reject the
    /// whole event (a v4 peer never emits them).
    pub fn from_json(v: &Json) -> Option<Event> {
        let seq = v.get("seq").and_then(Json::as_f64)? as u64;
        let t_us = v.get("t_us").and_then(Json::as_f64)? as u64;
        let severity = Severity::from_wire(v.get("severity").and_then(Json::as_str)?)?;
        let kind = EventKind::from_wire(v.get("kind").and_then(Json::as_str)?)?;
        let mut labels = BTreeMap::new();
        if let Some(Json::Obj(m)) = v.get("labels") {
            for (k, val) in m {
                labels.insert(k.clone(), val.as_str()?.to_string());
            }
        }
        Some(Event {
            seq,
            t_us,
            severity,
            kind,
            labels,
        })
    }
}

/// One live watch: a bounded queue of pre-formatted event lines plus the
/// filters that decide which published events it receives. Created by
/// [`Journal::subscribe`]; the owning connection pumps [`Subscription::pop`]
/// into its writer and tears down with [`Journal::unsubscribe`].
#[derive(Debug)]
pub struct Subscription {
    id: u64,
    min_severity: Severity,
    /// Empty = all kinds.
    kinds: Vec<EventKind>,
    cap: usize,
    queue: Mutex<VecDeque<String>>,
    dropped: AtomicU64,
}

impl Subscription {
    /// Subscription id — the `"watch"` tag on every delivered line.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Does `event` pass this subscription's filters?
    pub fn matches(&self, event: &Event) -> bool {
        event.severity >= self.min_severity
            && (self.kinds.is_empty() || self.kinds.contains(&event.kind))
    }

    /// Queue one formatted line, shedding the oldest if full.
    fn offer(&self, line: String) {
        let mut q = self.queue.lock().unwrap();
        if q.len() >= self.cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(line);
    }

    /// Take the oldest pending line, if any.
    pub fn pop(&self) -> Option<String> {
        self.queue.lock().unwrap().pop_front()
    }

    /// Put a line back at the *front* of the queue — the pump does this
    /// when the shared writer channel is momentarily full, so delivery
    /// order is preserved across backoff.
    pub fn requeue_front(&self, line: String) {
        self.queue.lock().unwrap().push_front(line);
    }

    /// Lines shed by the bounded queue since subscription.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Lines currently queued.
    pub fn pending(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

/// Format one delivered watch line: `{"watch":<sub id>,"event":{...}}`.
/// Cluster-stitched deliveries carry their backend in the event labels.
pub fn format_event_line(sub_id: u64, event: &Event) -> String {
    Json::obj(vec![
        ("watch", Json::Num(sub_id as f64)),
        ("event", event.to_json()),
    ])
    .to_string()
}

/// Parse a delivered watch line back into `(subscription id, event)`.
/// Returns `None` for any other line (replies interleave on the wire).
pub fn parse_event_line(line: &str) -> Option<(u64, Event)> {
    let v = Json::parse(line.trim()).ok()?;
    let sub = v.get("watch").and_then(Json::as_f64)? as u64;
    let event = Event::from_json(v.get("event")?)?;
    Some((sub, event))
}

/// The per-process event journal: bounded ring + subscriber fan-out +
/// active-alert set. Shared as `Arc<Journal>` between the publishing
/// sides (evaluator thread, batcher workers, health monitor) and the
/// serving sides (watch connections, `stats`, Prometheus).
#[derive(Debug)]
pub struct Journal {
    origin: Instant,
    cap: usize,
    next_seq: AtomicU64,
    published: AtomicU64,
    evicted: AtomicU64,
    dropped: AtomicU64,
    next_sub: AtomicU64,
    ring: Mutex<VecDeque<Event>>,
    watchers: Mutex<Vec<Arc<Subscription>>>,
    alerts: Mutex<BTreeMap<String, BTreeMap<String, String>>>,
}

impl Default for Journal {
    fn default() -> Self {
        Journal::new(DEFAULT_JOURNAL_CAP)
    }
}

impl Journal {
    /// A journal retaining at most `cap` events.
    pub fn new(cap: usize) -> Journal {
        Journal {
            origin: Instant::now(),
            cap: cap.max(1),
            next_seq: AtomicU64::new(1),
            published: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            next_sub: AtomicU64::new(1),
            ring: Mutex::new(VecDeque::new()),
            watchers: Mutex::new(Vec::new()),
            alerts: Mutex::new(BTreeMap::new()),
        }
    }

    /// Publish an event built from borrowed label pairs.
    pub fn publish(&self, severity: Severity, kind: EventKind, labels: &[(&str, &str)]) -> u64 {
        self.publish_owned(
            severity,
            kind,
            labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        )
    }

    /// Publish an event with an owned label map (the proxy's stitcher
    /// re-publishes parsed backend events through this). Returns the
    /// assigned sequence number.
    pub fn publish_owned(
        &self,
        severity: Severity,
        kind: EventKind,
        labels: BTreeMap<String, String>,
    ) -> u64 {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let event = Event {
            seq,
            t_us: self.origin.elapsed().as_micros() as u64,
            severity,
            kind,
            labels,
        };
        {
            let mut ring = self.ring.lock().unwrap();
            ring.push_back(event.clone());
            while ring.len() > self.cap {
                ring.pop_front();
                self.evicted.fetch_add(1, Ordering::Relaxed);
            }
        }
        self.published.fetch_add(1, Ordering::Relaxed);
        let watchers = self.watchers.lock().unwrap();
        for sub in watchers.iter() {
            if sub.matches(&event) {
                let before = sub.dropped();
                sub.offer(format_event_line(sub.id, &event));
                self.dropped
                    .fetch_add(sub.dropped() - before, Ordering::Relaxed);
            }
        }
        seq
    }

    /// Register a watch. `kinds` empty means all kinds; `cap` 0 takes
    /// [`DEFAULT_SUB_QUEUE`]. Delivery starts with the next published
    /// event — no replay.
    pub fn subscribe(
        &self,
        min_severity: Severity,
        kinds: Vec<EventKind>,
        cap: usize,
    ) -> Arc<Subscription> {
        let sub = Arc::new(Subscription {
            id: self.next_sub.fetch_add(1, Ordering::Relaxed),
            min_severity,
            kinds,
            cap: if cap == 0 { DEFAULT_SUB_QUEUE } else { cap },
            queue: Mutex::new(VecDeque::new()),
            dropped: AtomicU64::new(0),
        });
        self.watchers.lock().unwrap().push(Arc::clone(&sub));
        sub
    }

    /// Remove a watch by id. Idempotent; returns whether it was live.
    pub fn unsubscribe(&self, id: u64) -> bool {
        let mut watchers = self.watchers.lock().unwrap();
        let before = watchers.len();
        watchers.retain(|s| s.id != id);
        watchers.len() != before
    }

    /// Flip an alert's active state. A `false → true` transition
    /// publishes [`EventKind::AlertFired`] (severity warn) and a
    /// `true → false` transition [`EventKind::AlertCleared`] (info);
    /// anything else is a no-op. `name` plus `labels` identify the alert
    /// instance (e.g. `mse` + model/scheme/k). Returns whether the state
    /// transitioned.
    pub fn set_alert(&self, name: &str, labels: &[(&str, &str)], active: bool) -> bool {
        let mut owned: BTreeMap<String, String> = labels
            .iter()
            .map(|(k, v)| (k.to_string(), v.to_string()))
            .collect();
        owned.insert("alert".to_string(), name.to_string());
        let key = owned
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(",");
        let transitioned = {
            let mut alerts = self.alerts.lock().unwrap();
            if active {
                alerts.insert(key, owned.clone()).is_none()
            } else {
                alerts.remove(&key).is_some()
            }
        };
        if transitioned {
            let (sev, kind) = if active {
                (Severity::Warn, EventKind::AlertFired)
            } else {
                (Severity::Info, EventKind::AlertCleared)
            };
            self.publish_owned(sev, kind, owned);
        }
        transitioned
    }

    /// Currently firing alerts, as their full label maps (each includes
    /// its `alert` name label).
    pub fn active_alerts(&self) -> Vec<BTreeMap<String, String>> {
        self.alerts.lock().unwrap().values().cloned().collect()
    }

    /// Newest `limit` retained events, newest first.
    pub fn recent(&self, limit: usize) -> Vec<Event> {
        let ring = self.ring.lock().unwrap();
        ring.iter().rev().take(limit).cloned().collect()
    }

    /// Total events published.
    pub fn published(&self) -> u64 {
        self.published.load(Ordering::Relaxed)
    }

    /// Events evicted from the bounded ring.
    pub fn evicted(&self) -> u64 {
        self.evicted.load(Ordering::Relaxed)
    }

    /// Lines shed across all subscriber queues.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Live subscription count.
    pub fn subscribers(&self) -> usize {
        self.watchers.lock().unwrap().len()
    }

    /// Render the journal's Prometheus families: event counters, watch
    /// gauges, and one `dither_alert_active` sample per firing alert.
    pub fn append_prometheus(&self, p: &mut PromText) {
        p.scalar(
            "dither_events_total",
            "counter",
            "Structured ops events published to the journal",
            self.published() as f64,
        );
        p.scalar(
            "dither_events_dropped_total",
            "counter",
            "Watch lines shed by bounded subscriber queues",
            self.dropped() as f64,
        );
        p.scalar(
            "dither_watch_subscribers",
            "gauge",
            "Live watch subscriptions",
            self.subscribers() as f64,
        );
        p.family(
            "dither_alert_active",
            "gauge",
            "SLO burn-rate alerts currently firing (1 per active alert)",
        );
        for labels in self.active_alerts() {
            let pairs: Vec<(&str, &str)> = labels
                .iter()
                .map(|(k, v)| (k.as_str(), v.as_str()))
                .collect();
            p.sample("dither_alert_active", &pairs, 1.0);
        }
    }
}

/// Render the `dither_build_info` gauge (value 1, identity as labels)
/// plus nothing else — both tiers call this next to their uptime gauge.
pub fn append_build_info(p: &mut PromText, proto: &str, kernel: &str, schemes: &str) {
    p.family(
        "dither_build_info",
        "gauge",
        "Build identity: crate version, protocol, kernel, scheme registry",
    );
    p.sample(
        "dither_build_info",
        &[
            ("version", env!("CARGO_PKG_VERSION")),
            ("proto", proto),
            ("kernel", kernel),
            ("schemes", schemes),
        ],
        1.0,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::check_exposition;

    fn ev(j: &Journal, sev: Severity, kind: EventKind) -> u64 {
        j.publish(sev, kind, &[("model", "digits_linear")])
    }

    #[test]
    fn ring_is_bounded_and_counts_evictions() {
        let j = Journal::new(4);
        for _ in 0..10 {
            ev(&j, Severity::Info, EventKind::SlowPromotion);
        }
        assert_eq!(j.published(), 10);
        assert_eq!(j.evicted(), 6);
        let recent = j.recent(16);
        assert_eq!(recent.len(), 4);
        // Newest first, dense seqs.
        assert_eq!(recent[0].seq, 10);
        assert_eq!(recent[3].seq, 7);
        assert!(recent[0].t_us >= recent[3].t_us, "monotonic timestamps");
    }

    #[test]
    fn subscription_filters_by_severity_and_kind() {
        let j = Journal::new(16);
        let warn_only = j.subscribe(Severity::Warn, vec![], 8);
        let kind_only = j.subscribe(Severity::Info, vec![EventKind::BackendDown], 8);
        ev(&j, Severity::Info, EventKind::SlowPromotion);
        ev(&j, Severity::Warn, EventKind::OverloadOnset);
        ev(&j, Severity::Error, EventKind::BackendDown);
        assert_eq!(warn_only.pending(), 2, "info filtered out");
        assert_eq!(kind_only.pending(), 1, "only backend_down passes");
        let line = kind_only.pop().unwrap();
        let (sub, event) = parse_event_line(&line).expect("watch line parses");
        assert_eq!(sub, kind_only.id());
        assert_eq!(event.kind, EventKind::BackendDown);
        assert_eq!(event.labels.get("model").map(String::as_str), Some("digits_linear"));
    }

    #[test]
    fn bounded_queue_drops_oldest_and_counts() {
        let j = Journal::new(16);
        let sub = j.subscribe(Severity::Info, vec![], 2);
        for _ in 0..5 {
            ev(&j, Severity::Info, EventKind::SlowPromotion);
        }
        assert_eq!(sub.pending(), 2);
        assert_eq!(sub.dropped(), 3);
        assert_eq!(j.dropped(), 3);
        // The survivors are the *newest* two events.
        let (_, first) = parse_event_line(&sub.pop().unwrap()).unwrap();
        assert_eq!(first.seq, 4, "oldest lines were shed");
    }

    #[test]
    fn unsubscribe_stops_delivery() {
        let j = Journal::new(16);
        let sub = j.subscribe(Severity::Info, vec![], 8);
        assert_eq!(j.subscribers(), 1);
        assert!(j.unsubscribe(sub.id()));
        assert!(!j.unsubscribe(sub.id()), "idempotent");
        ev(&j, Severity::Error, EventKind::WatchdogTimeout);
        assert_eq!(sub.pending(), 0);
        assert_eq!(j.subscribers(), 0);
    }

    #[test]
    fn event_json_round_trips() {
        let j = Journal::new(4);
        j.publish(
            Severity::Warn,
            EventKind::SchemeSwitch,
            &[("model", "fashion_mlp"), ("to_scheme", "sr2"), ("to_k", "4")],
        );
        let event = j.recent(1).pop().unwrap();
        let back = Event::from_json(&event.to_json()).expect("round trip");
        assert_eq!(back, event);
    }

    #[test]
    fn alert_transitions_publish_fire_and_clear_once() {
        let j = Journal::new(16);
        let labels = [("model", "digits_linear"), ("scheme", "dither"), ("k", "4")];
        assert!(j.set_alert("mse", &labels, true));
        assert!(!j.set_alert("mse", &labels, true), "already firing");
        assert_eq!(j.active_alerts().len(), 1);
        assert!(j.set_alert("mse", &labels, false));
        assert!(!j.set_alert("mse", &labels, false), "already clear");
        assert!(j.active_alerts().is_empty());
        let kinds: Vec<EventKind> = j.recent(8).iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![EventKind::AlertCleared, EventKind::AlertFired]);
    }

    #[test]
    fn prometheus_families_render_and_validate() {
        let j = Journal::new(16);
        j.set_alert("latency_p99", &[("budget_us", "1000")], true);
        ev(&j, Severity::Info, EventKind::ProcessStart);
        let mut p = PromText::new();
        j.append_prometheus(&mut p);
        append_build_info(&mut p, "4", "scalar", "deterministic,dither");
        let text = p.finish();
        check_exposition(&text).expect("well-formed");
        assert!(text.contains("dither_events_total 2"), "{text}");
        assert!(
            text.contains("dither_alert_active{alert=\"latency_p99\",budget_us=\"1000\"} 1"),
            "{text}"
        );
        assert!(text.contains("dither_build_info{version="), "{text}");
    }

    #[test]
    fn requeue_front_preserves_order() {
        let j = Journal::new(16);
        let sub = j.subscribe(Severity::Info, vec![], 8);
        ev(&j, Severity::Info, EventKind::SlowPromotion);
        ev(&j, Severity::Info, EventKind::SlowPromotion);
        let first = sub.pop().unwrap();
        sub.requeue_front(first.clone());
        assert_eq!(sub.pop().as_ref(), Some(&first));
        let (_, second) = parse_event_line(&sub.pop().unwrap()).unwrap();
        assert_eq!(second.seq, 2);
    }
}
