//! Backend health checking: periodic `stats` probes with timeout,
//! mark-down/mark-up, and exponential probe backoff for dead backends.
//!
//! One monitor thread owns every backend's health verdict. Each probe is a
//! short-lived connection issuing `{"cmd":"stats"}` and waiting (bounded)
//! for the reply — exercising the full accept → parse → scrape path, so a
//! process that is alive but wedged fails the probe too. A successful
//! probe (re)establishes the backend's pooled pipelined connection before
//! marking it up, so routed traffic always has somewhere to go the moment
//! the verdict flips. A failed probe marks the backend down immediately —
//! abandoning its pooled connection answers every pending reply with a
//! retryable `overloaded` line (sampled requests' proxy-side timelines
//! are still committed, with their upstream wait noted `abandoned`, so a
//! trace query shows where in-flight work died) — and doubles the probe
//! interval up to `max_backoff` so a long-dead backend is not hammered.
//!
//! Routing reacts through [`crate::cluster::ring::HashRing::route_where`]:
//! keys owned by a down backend deterministically fail over to the next
//! live member and return home on mark-up (minimal remapping both ways).

use crate::cluster::backend::Backend;
use crate::obs::{EventKind, Journal, Severity};
use crate::util::rng::{counter_hash, u64_to_unit_f64};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Probe cadence and bounds.
#[derive(Clone, Debug)]
pub struct HealthPolicy {
    /// Probe interval for healthy backends (and the backoff floor).
    pub interval: Duration,
    /// Per-probe connect + reply timeout.
    pub timeout: Duration,
    /// Backoff ceiling for dead backends.
    pub max_backoff: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            interval: Duration::from_millis(500),
            timeout: Duration::from_secs(2),
            max_backoff: Duration::from_secs(8),
        }
    }
}

/// Deterministic probe jitter: scale `base` by a factor in `[0.75, 1.25)`
/// derived from a counter hash, so a fleet of proxies (or one proxy's
/// backends after a mass outage) never converges on synchronized probe
/// storms. Counter-hash derivation keeps runs reproducible — the same
/// `(seed, probe index)` always yields the same schedule.
fn jittered(base: Duration, seed: u64, counter: u64) -> Duration {
    let unit = u64_to_unit_f64(counter_hash(seed, counter));
    base.mul_f64(0.75 + 0.5 * unit)
}

/// Hash seed for probe jitter; arbitrary but fixed so schedules are
/// stable across restarts.
const JITTER_SEED: u64 = 0x6a69_7474_6572; // "jitter"

/// Run the monitor until `stop` is set: probe each backend on its own
/// schedule, mark up/down, and back off on failures. Blocks — the proxy
/// runs it on a dedicated thread. Mark-down/mark-up transitions are
/// published to `journal` ([`EventKind::BackendDown`] /
/// [`EventKind::BackendUp`]) when one is supplied.
pub fn health_loop(
    backends: &[Arc<Backend>],
    policy: &HealthPolicy,
    stop: &AtomicBool,
    journal: Option<&Journal>,
) {
    let interval = policy.interval.max(Duration::from_millis(10));
    let mut next = vec![Instant::now(); backends.len()];
    let mut backoff = vec![interval; backends.len()];
    // Per-backend probe counters feed the jitter hash; offsetting by the
    // backend index de-phases the very first rescheduling too.
    let mut probes: Vec<u64> = (0..backends.len() as u64).collect();
    while !stop.load(Ordering::Acquire) {
        let now = Instant::now();
        for (i, backend) in backends.iter().enumerate() {
            if now < next[i] {
                continue;
            }
            probes[i] = probes[i].wrapping_add(backends.len() as u64);
            if backend.fetch_stats().is_some() && backend.ensure_connected() {
                let was_down = !backend.is_healthy();
                backend.mark_up();
                if was_down {
                    println!(
                        "dither-proxy: backend {} ({}) is up",
                        backend.id(),
                        backend.addr()
                    );
                    if let Some(journal) = journal {
                        journal.publish(
                            Severity::Info,
                            EventKind::BackendUp,
                            &[
                                ("backend", &backend.id().to_string()),
                                ("addr", backend.addr()),
                            ],
                        );
                    }
                }
                backoff[i] = interval;
                next[i] = now + jittered(interval, JITTER_SEED, probes[i]);
            } else {
                let was_up = backend.is_healthy();
                backend.mark_down();
                if was_up {
                    println!(
                        "dither-proxy: backend {} ({}) marked down",
                        backend.id(),
                        backend.addr()
                    );
                    if let Some(journal) = journal {
                        journal.publish(
                            Severity::Warn,
                            EventKind::BackendDown,
                            &[
                                ("backend", &backend.id().to_string()),
                                ("addr", backend.addr()),
                            ],
                        );
                    }
                }
                next[i] = now + jittered(backoff[i], JITTER_SEED, probes[i]);
                backoff[i] = backoff[i].saturating_mul(2).min(policy.max_backoff.max(interval));
            }
        }
        std::thread::sleep(Duration::from_millis(20).min(interval));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_is_sane() {
        let p = HealthPolicy::default();
        assert!(p.interval < p.max_backoff);
        assert!(p.timeout >= p.interval);
    }

    #[test]
    fn dead_backends_are_marked_down_with_backoff() {
        // Nothing listens on the address: the first sweep probes (and
        // fails) every backend, later sweeps respect the growing backoff.
        let stop = Arc::new(AtomicBool::new(false));
        let backends: Vec<Arc<Backend>> = (0..2)
            .map(|i| {
                Arc::new(Backend::new(
                    i,
                    "127.0.0.1:1".to_string(),
                    4,
                    Duration::from_millis(50),
                    stop.clone(),
                    Arc::new(crate::trace::Tracer::new(crate::trace::TraceConfig::default())),
                ))
            })
            .collect();
        let policy = HealthPolicy {
            interval: Duration::from_millis(20),
            timeout: Duration::from_millis(50),
            max_backoff: Duration::from_millis(100),
        };
        let stop2 = stop.clone();
        let list = backends.clone();
        // Backends start down; pre-mark them up so the monitor's first
        // failed probe is an up → down *transition* and hits the journal.
        for b in &backends {
            b.mark_up();
        }
        let journal = Arc::new(Journal::default());
        let journal2 = journal.clone();
        let monitor =
            std::thread::spawn(move || health_loop(&list, &policy, &stop2, Some(&journal2)));
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::Release);
        monitor.join().unwrap();
        for b in &backends {
            assert!(!b.is_healthy(), "unreachable backend must stay down");
        }
        // Each backend was pre-marked up, so its first failed probe is a
        // transition and must hit the journal exactly once.
        let downs = journal
            .recent(16)
            .iter()
            .filter(|e| e.kind == EventKind::BackendDown)
            .count();
        assert_eq!(downs, 2, "one BackendDown event per backend");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let base = Duration::from_millis(1_000);
        for c in 0..64u64 {
            let j = jittered(base, JITTER_SEED, c);
            assert_eq!(j, jittered(base, JITTER_SEED, c), "same inputs, same jitter");
            assert!(j >= Duration::from_millis(750), "floor is -25%: {j:?}");
            assert!(j < Duration::from_millis(1_250), "ceiling is +25%: {j:?}");
        }
        // The whole point: consecutive probes do not share a schedule.
        assert_ne!(jittered(base, JITTER_SEED, 1), jittered(base, JITTER_SEED, 2));
    }
}
